//! Quickstart: tune one ResNet18 conv layer on the simulated extended VTA
//! with ML²Tuner, then validate the best schedule bit-exactly against the
//! AOT-compiled JAX/Pallas golden model through PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ml2tuner::prelude::*;
use ml2tuner::runtime::{golden, Runtime};
use ml2tuner::tuner::{TuningEnv, TunerConfig};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::Tuner;
use ml2tuner::vta::{functional, layout};
use ml2tuner::workloads::synth;

fn main() -> anyhow::Result<()> {
    let layer = resnet18::layer("conv1").expect("conv1");
    println!(
        "tuning {} ({}x{}x{} -> {} filters, {} schedules in the space)",
        layer.name, layer.h, layer.w, layer.c, layer.kc,
        ml2tuner::compiler::schedule::candidates(&layer).len()
    );

    // 1. tune with ML²Tuner (N=10, α=1, paper defaults) on a parallel
    //    engine: profiling fans out over all cores, compiles are cached,
    //    and the trace is identical to a single-threaded run
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    let engine = Engine::default();
    let cfg = TunerConfig { max_trials: 200, seed: 1, ..Default::default() };
    let trace = Ml2Tuner::new(cfg).tune_with(&env, &engine);
    let cache = engine.cache().stats();
    println!(
        "engine: {} jobs, compile cache {} hits / {} lookups",
        engine.jobs(),
        cache.hits,
        cache.lookups()
    );
    let best_cycles = trace.best_cycles().expect("found a valid schedule");
    let best = trace
        .trials
        .iter()
        .find(|t| t.outcome.cycles() == Some(best_cycles))
        .unwrap();
    let sim = Simulator::new(VtaConfig::zcu102());
    println!(
        "best schedule: {}  ->  {:.3} ms (estimated @ {} MHz), \
         invalidity ratio {:.3}",
        best.schedule,
        sim.cycles_to_ms(best_cycles),
        sim.cfg.clock_mhz,
        trace.invalidity_ratio()
    );

    // 2. deploy-check: execute the winning program numerically and compare
    //    bit-for-bit with the AOT JAX/Pallas golden conv.
    let compiler = Compiler::new(VtaConfig::zcu102());
    let compiled = compiler.compile(&layer, &best.schedule);
    let x = synth::input_data(&layer, 7);
    let w = synth::weight_data(&layer, 7);
    let dram = functional::Dram {
        inp: layout::pack_input(&sim.cfg, &x, layer.h, layer.w, layer.c),
        wgt: layout::pack_weights(&sim.cfg, &w, layer.kh, layer.kw,
                                  layer.c, layer.kc),
        out_vecs: compiled.program.dram_out_vecs,
    };
    let out = sim
        .execute(&compiled.program, &dram)
        .map_err(|f| anyhow::anyhow!("{f:?}"))?;
    match Runtime::open_default() {
        Ok(mut rt) => {
            let gold = golden::golden_output(&mut rt, &layer, 7)?;
            assert_eq!(out, gold, "simulator vs golden mismatch");
            println!("deploy check: output BIT-EXACT vs AOT JAX/Pallas \
                      golden model (PJRT)");
        }
        Err(e) => {
            // artifacts not built: fall back to the pure-rust oracle
            let gold =
                golden::reference_conv(&layer, &x, &w, sim.cfg.shift);
            assert_eq!(out, gold, "simulator vs reference mismatch");
            println!("deploy check: BIT-EXACT vs rust reference (PJRT \
                      artifacts unavailable: {e})");
        }
    }
    Ok(())
}
