//! Ablation of the paper's two contributions: the validity model (V) and
//! the hidden-feature model (A). Four variants on two layers:
//!   ml2tuner       = P + V + A   (the paper's system)
//!   ml2tuner-noV   = P + A       (no validity filter)
//!   ml2tuner-noA   = P + V       (no hidden-feature re-rank)
//!   ml2tuner-Ponly = P           (valid-only P, still not TVM's penalty P)

use ml2tuner::prelude::*;
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::stats::mean;
use ml2tuner::util::table::{f, Table};

fn main() {
    let repeats = 3;
    let sim = Simulator::new(VtaConfig::zcu102());
    for layer_name in ["conv1", "conv4"] {
        let layer = resnet18::layer(layer_name).unwrap();
        let env = TuningEnv::new(VtaConfig::zcu102(), layer);
        let mut table = Table::new(&[
            "variant",
            "best (ms, avg)",
            "invalidity (avg)",
            "trials-to-best (avg)",
        ]);
        let build: Vec<(&str, Box<dyn Fn(TunerConfig) -> Ml2Tuner>)> = vec![
            ("ml2tuner", Box::new(Ml2Tuner::new)),
            ("ml2tuner-noV", Box::new(|c| Ml2Tuner::new(c).without_v())),
            ("ml2tuner-noA", Box::new(|c| Ml2Tuner::new(c).without_a())),
            ("ml2tuner-Ponly",
             Box::new(|c| Ml2Tuner::new(c).without_v().without_a())),
        ];
        for (name, mk) in build {
            let mut best = Vec::new();
            let mut inval = Vec::new();
            let mut to_best = Vec::new();
            for r in 0..repeats {
                let cfg = TunerConfig {
                    max_trials: 250,
                    seed: 100 + r,
                    ..Default::default()
                };
                let trace = mk(cfg).tune(&env);
                if let Some(c) = trace.best_cycles() {
                    best.push(sim.cycles_to_ms(c));
                    to_best.push(
                        trace.trials_to_reach(c as f64).unwrap() as f64,
                    );
                }
                inval.push(trace.invalidity_ratio());
            }
            table.row(&[
                name.to_string(),
                f(mean(&best), 3),
                f(mean(&inval), 3),
                f(mean(&to_best), 0),
            ]);
        }
        println!("--- ablation on {layer_name} ({repeats} repeats) ---");
        table.print();
        println!();
    }
}
