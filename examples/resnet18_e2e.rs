//! End-to-end driver (EXPERIMENTS.md §E2E): tune ALL 10 profiled ResNet18
//! conv layers with ML²Tuner on the simulated extended VTA, then "deploy"
//! the tuned network: execute every layer's winning schedule numerically
//! and verify each output bit-exactly against the AOT-compiled JAX/Pallas
//! golden model through PJRT (Python never runs here). Reports the paper's
//! headline metrics for the whole network.
//!
//! ```bash
//! make artifacts && cargo run --release --example resnet18_e2e
//! ```

use std::time::Instant;

use ml2tuner::prelude::*;
use ml2tuner::runtime::{golden, Runtime};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::stats::mean;
use ml2tuner::util::table::{f, Table};
use ml2tuner::vta::{functional, layout};
use ml2tuner::workloads::synth;

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let hw = VtaConfig::zcu102();
    let sim = Simulator::new(hw.clone());
    let compiler = Compiler::new(hw.clone());
    // one parallel engine for the whole network: profiling fans out over
    // all cores and compiled kernels are cached across layers/tuners
    let engine = Engine::default();
    let mut rt = Runtime::open_default()?;
    println!("== ResNet18 end-to-end tuning + deployment on simulated \
              extended VTA ==\n");

    let mut table = Table::new(&[
        "layer",
        "baseline (ms)",
        "tuned (ms)",
        "speedup",
        "trials vs tvm (%)",
        "invalid ratio",
        "deploy check",
    ]);
    let mut total_base = 0.0;
    let mut total_tuned = 0.0;
    let mut effs = Vec::new();
    let mut invals = Vec::new();
    for layer in resnet18::LAYERS {
        let env = TuningEnv::new(hw.clone(), layer);
        // baseline schedule: a safe conservative default (small tiles,
        // single thread) — what a non-tuned backend would pick
        let base_sched = Schedule { tile_h: 4, tile_w: 4, tile_oc: 16,
                                    tile_ic: 16, n_vthreads: 1,
                                    ..Default::default() };
        let base = compiler.compile(&layer, &base_sched);
        let base_cycles = match sim.check(&base.program) {
            ml2tuner::vta::Verdict::Valid { cycles } => cycles,
            v => panic!("baseline schedule invalid on {}: {v:?}",
                        layer.name),
        };

        // tune
        let cfg = TunerConfig { max_trials: 200, seed: 42,
                                ..Default::default() };
        let trace = Ml2Tuner::new(cfg.clone()).tune_with(&env, &engine);
        let tvm_trace =
            TvmTuner::new(cfg.with_trials(500)).tune_with(&env, &engine);
        let best_cycles = trace.best_cycles().expect("valid config");
        let best = trace
            .trials
            .iter()
            .find(|t| t.outcome.cycles() == Some(best_cycles))
            .unwrap();
        let eff = ml2tuner::experiments::data::sample_efficiency(
            &trace, &tvm_trace, 100,
        );

        // deploy: numeric execution of the winning program, verified
        // against the PJRT golden model
        let compiled = compiler.compile(&layer, &best.schedule);
        let x = synth::input_data(&layer, 99);
        let w = synth::weight_data(&layer, 99);
        let dram = functional::Dram {
            inp: layout::pack_input(&hw, &x, layer.h, layer.w, layer.c),
            wgt: layout::pack_weights(&hw, &w, layer.kh, layer.kw,
                                      layer.c, layer.kc),
            out_vecs: compiled.program.dram_out_vecs,
        };
        let out = sim
            .execute(&compiled.program, &dram)
            .map_err(|f| anyhow::anyhow!("{f:?}"))?;
        let gold = golden::golden_output(&mut rt, &layer, 99)?;
        let exact = out == gold;
        assert!(exact, "{}: deployed output differs from golden",
                layer.name);

        let (bm, tm) = (
            sim.cycles_to_ms(base_cycles),
            sim.cycles_to_ms(best_cycles),
        );
        total_base += bm;
        total_tuned += tm;
        invals.push(trace.invalidity_ratio());
        if let Some(e) = eff {
            effs.push(e * 100.0);
        }
        table.row(&[
            layer.name.to_string(),
            f(bm, 3),
            f(tm, 3),
            format!("{:.2}x", bm / tm),
            eff.map(|e| f(e * 100.0, 1)).unwrap_or("-".into()),
            f(trace.invalidity_ratio(), 3),
            if exact { "BIT-EXACT".into() } else { "FAIL".into() },
        ]);
    }
    table.print();
    println!(
        "\nnetwork conv total: baseline {:.2} ms -> tuned {:.2} ms \
         ({:.2}x speedup)",
        total_base,
        total_tuned,
        total_base / total_tuned
    );
    println!(
        "avg samples-to-TVM-parity: {:.1}% (paper: 12.3%)  |  avg \
         ML2Tuner invalidity: {:.3} (paper: 0.176 on conv1)",
        mean(&effs),
        mean(&invals)
    );
    let cache = engine.cache().stats();
    println!(
        "wall time: {:.1}s ({} jobs, compile cache {:.1}% hit rate over \
         {} lookups)",
        t0.elapsed().as_secs_f64(),
        engine.jobs(),
        cache.hit_rate() * 100.0,
        cache.lookups()
    );
    Ok(())
}
