//! Baseline comparison on one layer: ML²Tuner vs the TVM approach vs
//! random sampling — tuning curve, invalidity, convergence, estimated
//! board wall-clock (the quantity invalid-filtering saves).

use ml2tuner::prelude::*;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::report::ProfilingCostModel;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::table::{ascii_curve, f, Table};

fn main() {
    let layer_name = std::env::args().nth(1).unwrap_or("conv3".into());
    let layer = resnet18::layer(&layer_name).expect("layer name");
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    let cfg = TunerConfig { max_trials: 300, seed: 11, ..Default::default() };
    let cost = ProfilingCostModel::default();
    let sim = Simulator::new(VtaConfig::zcu102());

    let mut table = Table::new(&[
        "tuner",
        "best (ms)",
        "trials to converge",
        "invalidity",
        "est. board time (s)",
    ]);
    let tuners: Vec<Box<dyn Tuner>> = vec![
        Box::new(ml2tuner::tuner::ml2tuner::Ml2Tuner::new(cfg.clone())),
        Box::new(TvmTuner::new(cfg.clone())),
        Box::new(RandomTuner::new(cfg.clone())),
    ];
    for mut t in tuners {
        let trace = t.tune(&env);
        let conv = trace.convergence(100);
        table.row(&[
            trace.tuner.clone(),
            trace
                .best_cycles()
                .map(|c| f(sim.cycles_to_ms(c), 3))
                .unwrap_or("-".into()),
            conv.map(|(n, _)| n.to_string()).unwrap_or("-".into()),
            f(trace.invalidity_ratio(), 3),
            f(trace.estimated_wall_clock(&cost), 0),
        ]);
        if trace.tuner == "ml2tuner" {
            println!("{} best-so-far curve (ms):", trace.tuner);
            let ms: Vec<f64> = trace
                .best_curve()
                .iter()
                .map(|&c| sim.cycles_to_ms(c.min(1e12) as u64))
                .collect();
            println!("{}", ascii_curve(&ms, 60, 8));
        }
    }
    println!("--- {layer_name} ---");
    table.print();
}
