#!/usr/bin/env python3
"""Fold the benches' ML2_BENCH_JSON line stream into one machine-readable
medians file and (optionally) diff it against a committed baseline.

Usage (what CI's bench-regression job runs):

    ML2_BENCH_JSON=$PWD/bench_raw.jsonl cargo bench \
        --bench engine_bench --bench vta_sim_bench --bench tuner_bench
    python3 scripts/bench_report.py --raw bench_raw.jsonl \
        --out BENCH_5.json --baseline BENCH_baseline.json

Promoting a measured baseline (one command, from a downloaded
bench-medians CI artifact):

    python3 scripts/bench_report.py --update-baseline BENCH_5.json

Exit codes: 0 clean (or baseline still bootstrap-empty), 1 when any
shared benchmark's median regressed more than --threshold. The CI job is
advisory (continue-on-error), so a red result annotates the run without
blocking the merge — but the uploaded BENCH_*.json is what you promote
to BENCH_baseline.json to move the committed trajectory forward.
"""

import argparse
import json
import os
import sys


def fold(raw_path):
    """JSONL → {"suite/name": {median_ns, mean_ns, iters}} (last write
    wins if a bench ran twice)."""
    benches = {}
    with open(raw_path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            key = f"{rec['suite']}/{rec['name']}"
            benches[key] = {
                "median_ns": int(rec["median_ns"]),
                "mean_ns": int(rec["mean_ns"]),
                "iters": int(rec["iters"]),
            }
    return benches


def compare(current, baseline, threshold):
    """Return (regressions, improvements, compared) on shared keys."""
    regressions, improvements, compared = [], [], 0
    for key, cur in sorted(current.items()):
        base = baseline.get(key)
        if base is None or not base.get("median_ns"):
            continue
        compared += 1
        rel = cur["median_ns"] / base["median_ns"] - 1.0
        if rel > threshold:
            regressions.append((key, rel, base["median_ns"],
                                cur["median_ns"]))
        elif rel < -threshold:
            improvements.append((key, rel))
    return regressions, improvements, compared


def update_baseline(artifact_path, baseline_path):
    """Promote a downloaded BENCH_*.json artifact into the committed
    baseline file (the one-command promotion flow; baselines must be
    measured on the CI runner class, never a developer box).

    Merges into the existing baseline rather than replacing it: the
    bench suites ship in separate artifacts (BENCH_5.json from
    bench-regression, BENCH_7.json from smoke-serve), and promoting one
    must not drop the other's keys. The artifact wins on shared keys.
    """
    try:
        with open(artifact_path, encoding="utf-8") as f:
            artifact = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"error: cannot read artifact {artifact_path}: {e}",
              file=sys.stderr)
        return 1
    benches = artifact.get("benches", {})
    if not benches:
        print(f"error: {artifact_path} has no measured benches — "
              "download a bench-medians artifact from a green "
              "bench-regression run", file=sys.stderr)
        return 1
    merged = {}
    try:
        with open(baseline_path, encoding="utf-8") as f:
            merged = json.load(f).get("benches", {})
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    merged.update(benches)
    out = {
        "schema": 1,
        "note": (
            "Committed bench-median baseline for CI's bench-regression "
            "and smoke-serve jobs. Last promoted from "
            f"{os.path.basename(artifact_path)} via scripts/"
            "bench_report.py --update-baseline. PROMOTION FLOW: "
            "download a green run's 'bench-medians' (BENCH_5.json) or "
            "'smoke-serve-logs' (BENCH_7.json) artifact and re-run that "
            "command — it merges, so the two suites can be promoted "
            "independently. Baselines must be measured on the CI runner "
            "class, never a developer box."
        ),
        "benches": merged,
    }
    with open(baseline_path, "w", encoding="utf-8") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"promoted {len(benches)} benchmark medians from "
          f"{artifact_path} into {baseline_path}")
    return 0


def check_against_baseline(benches, baseline_path, threshold):
    """Diff folded medians against the committed baseline file.

    Returns the process exit code. A missing baseline file or a
    bootstrap-empty one (``"benches": {}`` — the state a fresh repo
    ships in) is an explicit advisory pass, not a vacuous comparison:
    nothing was compared, and the message says so.
    """
    try:
        with open(baseline_path, encoding="utf-8") as f:
            baseline = json.load(f).get("benches", {})
    except FileNotFoundError:
        print(f"no baseline — advisory pass ({baseline_path} does not "
              "exist; nothing compared)")
        return 0
    if not baseline:
        print(f"no baseline — advisory pass ({baseline_path} is still "
              "bootstrap-empty; promote a bench-medians artifact with "
              "scripts/bench_report.py --update-baseline to start the "
              "trajectory)")
        return 0

    regs, imps, compared = compare(benches, baseline, threshold)
    print(f"compared {compared} benchmarks against {baseline_path} "
          f"(threshold {threshold:.0%})")
    for key, rel in imps:
        print(f"  improved  {key}: {rel:+.1%}")
    for key, rel, base_ns, cur_ns in regs:
        print(f"  REGRESSED {key}: {rel:+.1%} "
              f"({base_ns} ns -> {cur_ns} ns median)")
    if regs:
        print(f"{len(regs)} median regression(s) beyond the threshold")
        return 1
    print("no median regressions beyond the threshold")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--raw",
                    help="ML2_BENCH_JSON line file written by the benches")
    ap.add_argument("--out",
                    help="folded medians JSON to write (the CI artifact)")
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    help="committed baseline to diff against / promote "
                         "into (default BENCH_baseline.json)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="relative median regression that fails "
                         "(default 0.20)")
    ap.add_argument("--filter", metavar="SUBSTR",
                    help="keep only folded keys containing SUBSTR "
                         "(splits one raw stream into per-PR medians "
                         "files, e.g. the 'train P' rows -> "
                         "BENCH_9.json)")
    ap.add_argument("--update-baseline", metavar="ARTIFACT",
                    help="promote a downloaded BENCH_*.json artifact "
                         "into --baseline and exit")
    ap.add_argument("--current", metavar="MEDIANS",
                    help="compare an already-folded medians file (e.g. "
                         "the BENCH_7.json the storm harness writes) "
                         "against --baseline, skipping the fold step")
    args = ap.parse_args()

    if args.update_baseline:
        return update_baseline(args.update_baseline, args.baseline)
    if args.current:
        try:
            with open(args.current, encoding="utf-8") as f:
                benches = json.load(f).get("benches", {})
        except (FileNotFoundError, json.JSONDecodeError) as e:
            print(f"error: cannot read {args.current}: {e}",
                  file=sys.stderr)
            return 1
        if not benches:
            print(f"error: no bench records in {args.current}",
                  file=sys.stderr)
            return 1
        return check_against_baseline(benches, args.baseline,
                                      args.threshold)
    if not args.raw or not args.out:
        ap.error("--raw and --out are required unless --update-baseline "
                 "or --current is given")

    benches = fold(args.raw)
    if args.filter:
        benches = {k: v for k, v in benches.items()
                   if args.filter in k}
    if not benches:
        where = (f"{args.raw} matching --filter '{args.filter}'"
                 if args.filter else args.raw)
        print(f"error: no bench records in {where}", file=sys.stderr)
        return 1
    out = {"schema": 1, "benches": benches}
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}: {len(benches)} benchmark medians")
    return check_against_baseline(benches, args.baseline, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
