#!/usr/bin/env python3
"""Tests for scripts/bench_report.py — the bench-regression gate's
folding and baseline-comparison logic.

Run with either of:

    python3 -m unittest scripts.test_bench_report
    python3 -m pytest scripts/test_bench_report.py

Focus: the bootstrap-empty-baseline advisory pass (a fresh repo ships
BENCH_baseline.json with "benches": {}) and partial-overlap
comparisons, per ISSUE 6.
"""

import io
import json
import os
import shutil
import sys
import tempfile
import unittest
from contextlib import redirect_stdout
from unittest import mock

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_report  # noqa: E402


def entry(median_ns):
    return {"median_ns": median_ns, "mean_ns": median_ns, "iters": 10}


class FoldTest(unittest.TestCase):
    def test_fold_last_write_wins(self):
        with tempfile.NamedTemporaryFile(
            "w", suffix=".jsonl", delete=False
        ) as f:
            for median in (100, 200):
                f.write(json.dumps({
                    "suite": "tuner_bench", "name": "sweep",
                    "median_ns": median, "mean_ns": median, "iters": 3,
                }) + "\n")
            f.write("\n")  # blank lines are skipped
            path = f.name
        try:
            benches = bench_report.fold(path)
        finally:
            os.unlink(path)
        self.assertEqual(list(benches), ["tuner_bench/sweep"])
        self.assertEqual(benches["tuner_bench/sweep"]["median_ns"], 200)


class CompareTest(unittest.TestCase):
    def test_partial_overlap_compares_shared_keys_only(self):
        current = {"a": entry(100), "b": entry(300), "new": entry(50)}
        baseline = {"a": entry(100), "b": entry(200), "gone": entry(10)}
        regs, imps, compared = bench_report.compare(
            current, baseline, 0.20
        )
        # "new" has no baseline, "gone" no longer runs: neither counts
        self.assertEqual(compared, 2)
        self.assertEqual([k for k, *_ in regs], ["b"])  # +50% > 20%
        self.assertEqual(imps, [])

    def test_zero_median_baseline_entry_is_skipped(self):
        # a hand-edited or corrupt baseline entry must not divide by zero
        current = {"a": entry(100)}
        baseline = {"a": entry(0)}
        regs, imps, compared = bench_report.compare(
            current, baseline, 0.20
        )
        self.assertEqual((regs, imps, compared), ([], [], 0))

    def test_improvement_is_reported_not_failed(self):
        current = {"a": entry(50)}
        baseline = {"a": entry(100)}
        regs, imps, compared = bench_report.compare(
            current, baseline, 0.20
        )
        self.assertEqual(regs, [])
        self.assertEqual([k for k, _ in imps], ["a"])
        self.assertEqual(compared, 1)


class BaselineGateTest(unittest.TestCase):
    def _run(self, benches, baseline_obj, threshold=0.20):
        """check_against_baseline with a temp baseline file (or a
        missing path when baseline_obj is None); returns (code, out)."""
        if baseline_obj is None:
            path = os.path.join(tempfile.mkdtemp(), "missing.json")
        else:
            with tempfile.NamedTemporaryFile(
                "w", suffix=".json", delete=False
            ) as f:
                json.dump(baseline_obj, f)
                path = f.name
        buf = io.StringIO()
        try:
            with redirect_stdout(buf):
                code = bench_report.check_against_baseline(
                    benches, path, threshold
                )
        finally:
            if baseline_obj is not None:
                os.unlink(path)
        return code, buf.getvalue()

    def test_bootstrap_empty_baseline_is_advisory_pass(self):
        code, out = self._run(
            {"a": entry(100)}, {"schema": 1, "benches": {}}
        )
        self.assertEqual(code, 0)
        self.assertIn("no baseline — advisory pass", out)
        self.assertNotIn("compared", out)

    def test_missing_baseline_file_is_advisory_pass(self):
        code, out = self._run({"a": entry(100)}, None)
        self.assertEqual(code, 0)
        self.assertIn("no baseline — advisory pass", out)

    def test_regression_beyond_threshold_fails(self):
        code, out = self._run(
            {"a": entry(150)},
            {"schema": 1, "benches": {"a": entry(100)}},
        )
        self.assertEqual(code, 1)
        self.assertIn("REGRESSED a", out)

    def test_within_threshold_passes_with_comparison_summary(self):
        code, out = self._run(
            {"a": entry(110), "only-current": entry(5)},
            {"schema": 1, "benches": {"a": entry(100)}},
        )
        self.assertEqual(code, 0)
        self.assertIn("compared 1 benchmarks", out)
        self.assertIn("no median regressions", out)


class FilterTest(unittest.TestCase):
    def test_filter_keeps_matching_keys_only(self):
        # the BENCH_9.json split: one raw stream, per-PR medians files
        tmp = tempfile.mkdtemp()
        try:
            raw = os.path.join(tmp, "raw.jsonl")
            out = os.path.join(tmp, "out.json")
            names = ("train P full refit (round 5, 50 rows)", "sweep")
            with open(raw, "w", encoding="utf-8") as f:
                for name in names:
                    f.write(json.dumps({
                        "suite": "tuner_bench", "name": name,
                        "median_ns": 10, "mean_ns": 10, "iters": 3,
                    }) + "\n")
            argv = ["bench_report.py", "--raw", raw, "--out", out,
                    "--baseline", os.path.join(tmp, "missing.json"),
                    "--filter", "train P"]
            with mock.patch.object(sys, "argv", argv), \
                    redirect_stdout(io.StringIO()):
                code = bench_report.main()
            self.assertEqual(code, 0)
            with open(out, encoding="utf-8") as f:
                keys = list(json.load(f)["benches"])
            self.assertEqual(
                keys,
                ["tuner_bench/train P full refit (round 5, 50 rows)"],
            )
        finally:
            shutil.rmtree(tmp)

    def test_filter_matching_nothing_is_an_error(self):
        tmp = tempfile.mkdtemp()
        try:
            raw = os.path.join(tmp, "raw.jsonl")
            with open(raw, "w", encoding="utf-8") as f:
                f.write(json.dumps({
                    "suite": "tuner_bench", "name": "sweep",
                    "median_ns": 10, "mean_ns": 10, "iters": 3,
                }) + "\n")
            argv = ["bench_report.py", "--raw", raw,
                    "--out", os.path.join(tmp, "out.json"),
                    "--filter", "no such row"]
            err = io.StringIO()
            with mock.patch.object(sys, "argv", argv), \
                    mock.patch.object(sys, "stderr", err):
                code = bench_report.main()
            self.assertEqual(code, 1)
            self.assertIn("no such row", err.getvalue())
        finally:
            shutil.rmtree(tmp)


if __name__ == "__main__":
    unittest.main()
