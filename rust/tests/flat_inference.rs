//! PR-5 guarantees for the flattened, batched, parallel inference path.
//!
//! 1. `FlatEnsemble::predict_batch` equals per-row `Booster::predict_row`
//!    **bit-for-bit** on real profiled data across both spaces, all four
//!    registered hardware targets, and all three model objectives — the
//!    invariant that keeps every golden trace pinned.
//! 2. The rewritten explorer (batched chunked sweep + incremental
//!    ε-pool) selects exactly what the pre-PR row-at-a-time
//!    implementation selected, for the same RNG stream — checked against
//!    a frozen verbatim copy of the old algorithm across ε, margin,
//!    V-present and worker-count combinations.
//! 3. The chunked scoring sweep is invariant in `jobs`.

use ml2tuner::compiler::schedule::{Schedule, SpaceKind};
use ml2tuner::gbdt::{
    Booster, Dataset, FeatureMatrix, GbdtParams, Objective, TrainOpts,
};
use ml2tuner::tuner::database::{Database, Fidelity, Outcome, TrialRecord};
use ml2tuner::tuner::explorer::{score_candidates, Explorer};
use ml2tuner::tuner::models::{FitOpts, ModelP, ModelV};
use ml2tuner::tuner::space::SearchSpace;
use ml2tuner::tuner::train::{Provenance, TrainSet};
use ml2tuner::tuner::TuningEnv;
use ml2tuner::util::rng::Rng;
use ml2tuner::vta::targets;
use ml2tuner::workloads::resnet18;

// ---- 1. flat batch == per-row, bitwise --------------------------------

#[test]
fn flat_batch_equals_per_row_bitwise_across_targets_spaces_objectives() {
    let layer = resnet18::layer("conv5").unwrap();
    for kind in [SpaceKind::Paper, SpaceKind::Extended] {
        for name in targets::TARGET_NAMES {
            let hw = targets::target(name).unwrap();
            let env = TuningEnv::with_space(hw, layer, kind);
            // real labels: profile a strided sample on this target
            let step = (env.space.len() / 64).max(1);
            let mut xs: Vec<Vec<f64>> = Vec::new();
            let mut perf: Vec<f64> = Vec::new();
            let mut validity: Vec<f64> = Vec::new();
            for k in 0..64 {
                let r = env.profile(k * step);
                match r.outcome {
                    Outcome::Valid { cycles } => {
                        perf.push((cycles as f64).log2());
                        validity.push(1.0);
                    }
                    _ => {
                        perf.push(30.0);
                        validity.push(0.0);
                    }
                }
                xs.push(r.visible);
            }
            let m = FeatureMatrix::from_rows(&xs);
            for obj in [
                Objective::SquaredError,
                Objective::Hinge,
                Objective::RankPairwise,
            ] {
                let ys =
                    if obj == Objective::Hinge { &validity } else { &perf };
                let params = GbdtParams::model_p()
                    .with_rounds(40)
                    .with_objective(obj)
                    .with_seed(7);
                let b = Booster::fit(&params,
                                     &Dataset::from_rows(&xs, ys),
                                     &TrainOpts::default());
                let batch = b.flatten().predict_batch(&m);
                assert_eq!(batch.len(), xs.len());
                for (row, &got) in xs.iter().zip(&batch) {
                    assert_eq!(
                        b.predict_row(row).to_bits(),
                        got.to_bits(),
                        "{kind:?}/{name}/{obj:?}"
                    );
                }
            }
        }
    }
}

// ---- 2. explorer equivalence against the frozen pre-PR algorithm ------

/// Verbatim copy of the pre-PR-5 `Explorer::select` (row-at-a-time
/// scoring, per-hit rebuild of the ε free list). Do not modernize: this
/// is the reference the rewritten explorer must replay exactly.
fn legacy_select(
    space: &SearchSpace,
    p: &ModelP,
    v: Option<&ModelV>,
    epsilon: f64,
    v_margin: f64,
    count: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let n_left = space.n_unmeasured();
    if n_left <= count {
        return space.unmeasured();
    }
    let unmeasured = space.unmeasured();
    let mut scored: Vec<(f64, f64, usize)> = unmeasured
        .iter()
        .map(|&i| {
            let feats = space.visible(i);
            let tie = v.map_or(0.0, |m| -m.margin(&feats));
            (p.predict(&feats), tie, i)
        })
        .collect();
    scored.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
    let scored: Vec<(f64, usize)> =
        scored.into_iter().map(|(s, _, i)| (s, i)).collect();
    let mut picked: Vec<usize> = Vec::with_capacity(count);
    let mut taken = vec![false; scored.len()];
    let mut skipped: Vec<usize> = Vec::new();
    let mut pos = 0usize;
    while picked.len() < count && pos < scored.len() {
        if rng.bool(epsilon) {
            let free: Vec<usize> =
                (0..scored.len()).filter(|&k| !taken[k]).collect();
            if let Some(&k) = free.get(rng.below(free.len())) {
                taken[k] = true;
                picked.push(scored[k].1);
            }
            continue;
        }
        while pos < scored.len() && taken[pos] {
            pos += 1;
        }
        if pos >= scored.len() {
            break;
        }
        let idx = scored[pos].1;
        taken[pos] = true;
        let vetoed = v.is_some_and(|m| {
            !m.predict_valid(&space.visible(idx), v_margin)
        });
        if vetoed {
            skipped.push(pos);
        } else {
            picked.push(idx);
        }
        pos += 1;
    }
    for k in skipped {
        if picked.len() >= count {
            break;
        }
        picked.push(scored[k].1);
    }
    if picked.len() < count {
        for k in 0..scored.len() {
            if picked.len() >= count {
                break;
            }
            if !taken[k] {
                taken[k] = true;
                picked.push(scored[k].1);
            }
        }
    }
    picked
}

/// P/V trained on a synthetic labelling of the real conv5 space (same
/// setup as the explorer's unit tests), in the given kind's feature
/// layout.
fn trained_models(kind: SpaceKind) -> (SearchSpace, ModelP, ModelV) {
    let layer = resnet18::layer("conv5").unwrap();
    let space = SearchSpace::with_kind(&layer, kind);
    let mut db = Database::new("conv5");
    for i in (0..space.len()).step_by(3) {
        let s: Schedule = space.schedule(i);
        let valid = s.tile_h * s.n_vthreads <= 28;
        let cycles = (1_000_000 / (s.tile_h * s.tile_w)
            + 5_000 * s.n_vthreads) as u64;
        db.push(TrialRecord {
            space_index: i,
            schedule: s,
            visible: space.visible(i),
            hidden: vec![],
            outcome: if valid {
                Outcome::Valid { cycles }
            } else {
                Outcome::Crash
            },
            fidelity: Fidelity::Full,
        });
    }
    let opts = FitOpts::new(60, 1);
    let mut pset = TrainSet::new();
    pset.extend_p(&db, Provenance::Cold);
    let mut vset = TrainSet::new();
    vset.extend_v(&db, Provenance::Cold);
    let p = ModelP::fit(&pset, &opts).unwrap();
    let v = ModelV::fit(&vset, &opts).unwrap();
    (space, p, v)
}

#[test]
fn rewritten_explorer_replays_the_frozen_legacy_selection() {
    let (space, p, v) = trained_models(SpaceKind::Paper);
    for seed in [1u64, 9, 42] {
        for epsilon in [0.0f64, 0.05, 0.3, 1.0] {
            for (v_opt, margin) in [
                (Some(&v), ml2tuner::tuner::DEFAULT_V_MARGIN),
                (Some(&v), 2.0),  // veto-all: skipped-best fallback
                (None, ml2tuner::tuner::DEFAULT_V_MARGIN),
            ] {
                let mut legacy_rng = Rng::new(seed);
                let want = legacy_select(&space, &p, v_opt, epsilon,
                                         margin, 25, &mut legacy_rng);
                // post-selection stream position, for the lockstep check
                let want_next = legacy_rng.next_u64();
                for jobs in [1usize, 4] {
                    let mut rng = Rng::new(seed);
                    let got = Explorer::new(epsilon)
                        .with_v_margin(margin)
                        .with_jobs(jobs)
                        .select(&space, &p, v_opt, 25, &mut rng);
                    assert_eq!(
                        got, want,
                        "seed={seed} eps={epsilon} margin={margin} \
                         v={} jobs={jobs}",
                        v_opt.is_some()
                    );
                    // and the rng streams stayed in lockstep
                    assert_eq!(rng.next_u64(), want_next,
                               "rng stream diverged");
                }
            }
        }
    }
}

// ---- 3. sweep jobs-invariance on the extended space -------------------

#[test]
fn extended_space_sweep_is_jobs_invariant() {
    let (space, p, v) = trained_models(SpaceKind::Extended);
    // strided extended-space candidate list crossing many chunk
    // boundaries
    let idx: Vec<usize> = (0..space.len()).step_by(3).collect();
    let baseline = score_candidates(&space, &p, Some(&v), &idx, 1, None);
    for jobs in [2usize, 8] {
        let par =
            score_candidates(&space, &p, Some(&v), &idx, jobs, None);
        assert_eq!(baseline.len(), par.len());
        for (a, b) in baseline.iter().zip(&par) {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "jobs={jobs}");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "jobs={jobs}");
            assert_eq!(a.2, b.2, "jobs={jobs}");
        }
    }
}
