//! Integration: the extended knob set and the lazy ConfigSpace.
//!
//! * size — the extended space is ≥ 5× the paper space on EVERY layer
//!   (acceptance criterion; actual factor is 6: 2 load-slot × 3 unroll
//!   values) and both new primitives appear in the visible names;
//! * laziness — `SearchSpace` holds no materialized point list: resident
//!   bookkeeping stays flat as the cross product grows by orders of
//!   magnitude;
//! * semantics — the new primitives genuinely flow through codegen,
//!   the timing model, and the validity structure (the double-buffer
//!   toggle moves the validity boundary, unroll moves compute time);
//! * tuning — ML²Tuner runs end-to-end on the extended space,
//!   deterministically and jobs-invariantly, and transfer logs cross
//!   space versions in both directions.

use ml2tuner::compiler::schedule::{
    space_for, ConfigSpace, Knob, Schedule, SpaceKind,
};
use ml2tuner::engine::Engine;
use ml2tuner::tuner::database::{Database, TransferDb};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::space::SearchSpace;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::vta::Simulator;
use ml2tuner::workloads::{self, vgg16, NETWORKS};

#[test]
fn extended_space_is_at_least_5x_on_every_layer_of_every_network() {
    for net in &NETWORKS {
        for layer in net.layers {
            let paper = space_for(layer, SpaceKind::Paper).len();
            let ext = space_for(layer, SpaceKind::Extended).len();
            assert!(ext >= 5 * paper, "{}/{}: {ext} < 5 × {paper}",
                    net.name, layer.name);
            assert_eq!(ext, 6 * paper, "{}/{}", net.name, layer.name);
        }
    }
}

#[test]
fn both_new_primitives_are_visible_features() {
    let names = SpaceKind::Extended.visible_names();
    assert!(names.contains(&"nLoadSlots".to_string()), "{names:?}");
    assert!(names.contains(&"kernelUnroll".to_string()), "{names:?}");
    // and the paper layout is untouched (prefix property)
    assert_eq!(&names[..11], &SpaceKind::Paper.visible_names()[..]);
}

#[test]
fn search_space_memory_stays_flat_as_the_space_grows() {
    // the old implementation materialized Vec<Schedule> up front —
    // resident memory scaled with len(). The lazy space stores only the
    // candidate lists; growing the cross product by ~300× must not grow
    // the bookkeeping.
    let small = workloads::network("resnet18")
        .unwrap()
        .layer("conv5")
        .unwrap();
    let big = vgg16::layer("conv2_2").unwrap();
    let s_paper = SearchSpace::new(&small);
    let b_ext = SearchSpace::with_kind(&big, SpaceKind::Extended);
    assert!(b_ext.len() > 100 * s_paper.len(),
            "premise: {} vs {}", b_ext.len(), s_paper.len());
    assert!(s_paper.resident_entries() < 200);
    assert!(b_ext.resident_entries() < 200,
            "resident bookkeeping grew with the space: {}",
            b_ext.resident_entries());
}

#[test]
fn config_space_indexing_is_lazy_up_to_astronomic_sizes() {
    // a synthetic 10-billion-point space: construction and point access
    // must be O(knob values), which would be impossible with any
    // up-front materialization
    let knobs = ["TH", "TW", "tileOC", "tileIC", "nVirtualThread"]
        .into_iter()
        .map(|name| Knob { name, values: (1..=100).collect() })
        .collect::<Vec<_>>();
    let space = ConfigSpace::new(SpaceKind::Paper, knobs);
    assert_eq!(space.len(), 100usize.pow(5));
    assert_eq!(space.stored_values(), 500);
    for i in [0usize, 1, 99, 1_234_567_891, space.len() - 1] {
        let c = space.nth(i);
        assert_eq!(space.index_of(&c), Some(i));
    }
}

#[test]
fn double_buffer_toggle_shifts_the_validity_boundary() {
    // inp halo 30·30·4 = 3600 vectors: two slots (7200) overflow the
    // 4096-vector scratchpad — a register-error crash — while one slot
    // fits and runs validly. Exactly the boundary shift model V has to
    // learn in the extended space.
    let cfg = VtaConfig::zcu102();
    let layer = workloads::network("resnet18")
        .unwrap()
        .layer("conv1")
        .unwrap();
    let compiler = ml2tuner::compiler::Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    let base = Schedule { tile_h: 28, tile_w: 28, tile_oc: 16,
                          tile_ic: 64, n_vthreads: 1,
                          ..Default::default() };
    let double = base; // n_load_slots = 2 (paper default)
    let single = Schedule { n_load_slots: 1, ..base };
    let vd = sim.check(&compiler.compile(&layer, &double).program);
    let vs = sim.check(&compiler.compile(&layer, &single).program);
    assert!(!vd.is_valid(), "double-buffered must overflow: {vd:?}");
    assert!(vs.is_valid(), "single-buffered must fit: {vs:?}");
}

#[test]
fn double_buffering_buys_cycles_when_it_fits() {
    // where both fit, the paper's double buffering must be faster (the
    // single-slot variant serializes every load group against compute)
    let cfg = VtaConfig::zcu102();
    let layer = workloads::network("resnet18")
        .unwrap()
        .layer("conv1")
        .unwrap();
    let compiler = ml2tuner::compiler::Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    let base = Schedule { tile_h: 8, tile_w: 8, tile_oc: 64,
                          tile_ic: 64, n_vthreads: 1,
                          ..Default::default() };
    let fast = sim.check(&compiler.compile(&layer, &base).program);
    let slow = sim.check(
        &compiler
            .compile(&layer, &Schedule { n_load_slots: 1, ..base })
            .program,
    );
    assert!(fast.is_valid() && slow.is_valid(),
            "{fast:?} / {slow:?}");
    assert!(slow.cycles() > fast.cycles(),
            "single-buffering must cost cycles: {} vs {}",
            slow.cycles(),
            fast.cycles());
}

#[test]
fn kernel_unroll_cuts_compute_issue_overhead() {
    let cfg = VtaConfig::zcu102();
    let layer = workloads::network("resnet18")
        .unwrap()
        .layer("conv1")
        .unwrap();
    let compiler = ml2tuner::compiler::Compiler::new(cfg.clone());
    let base = Schedule { tile_h: 8, tile_w: 8, tile_oc: 64,
                          tile_ic: 64, n_vthreads: 1,
                          ..Default::default() };
    let c1 = compiler.compile(&layer, &base);
    let c4 =
        compiler.compile(&layer, &Schedule { k_unroll: 4, ..base });
    let busy = |c: &ml2tuner::compiler::Compiled| {
        ml2tuner::vta::timing::simulate_schedule(&cfg, &c.program)
            .unwrap()
            .busy[1] // COMPUTE module
    };
    assert!(busy(&c4) < busy(&c1),
            "unroll must shrink compute busy time");
    // both remain valid and compute the same MACs
    let sim = Simulator::new(cfg);
    assert!(sim.check(&c1.program).is_valid());
    assert!(sim.check(&c4.program).is_valid());
    assert_eq!(c1.program.gemm_block_ops(), c4.program.gemm_block_ops());
}

#[test]
fn extended_tuning_runs_end_to_end_and_is_jobs_invariant() {
    let layer = workloads::network("resnet18")
        .unwrap()
        .layer("conv5")
        .unwrap();
    let env =
        TuningEnv::with_space(VtaConfig::zcu102(), layer,
                              SpaceKind::Extended);
    let cfg = TunerConfig { max_trials: 40, seed: 11,
                            ..Default::default() };
    let t1 = Ml2Tuner::new(cfg.clone())
        .tune_with(&env, &Engine::with_jobs(1));
    let t4 = Ml2Tuner::new(cfg).tune_with(&env, &Engine::with_jobs(4));
    assert_eq!(t1.len(), 40);
    assert_eq!(format!("{:?}", t1.trials), format!("{:?}", t4.trials));
    let mut idx: Vec<usize> =
        t1.trials.iter().map(|t| t.space_index).collect();
    idx.sort_unstable();
    idx.dedup();
    assert_eq!(idx.len(), 40, "no config profiled twice");
    for t in &t1.trials {
        assert_eq!(t.visible.len(), SpaceKind::Extended.n_visible());
        assert_eq!(
            t.hidden.len(),
            ml2tuner::compiler::features::hidden_len(SpaceKind::Extended)
        );
    }
    assert!(t1.best_cycles().is_some(),
            "extended space still contains valid configs");
}

#[test]
fn transfer_crosses_space_versions_end_to_end() {
    // a paper-space tuning log warm-starts an extended-space run (and
    // the run stays deterministic)
    let net = workloads::network("mobilenet").unwrap();
    let pw4 = net.layer("pw4").unwrap();
    let pw5 = net.layer("pw5").unwrap();
    let paper_env = TuningEnv::new(VtaConfig::zcu102(), pw4);
    let engine = Engine::default();
    let mut log = Database::for_layer(&pw4);
    let batch: Vec<usize> =
        (0..60).map(|i| i * (paper_env.space.len() / 60).max(1)).collect();
    for r in engine.profile_batch(&paper_env, &batch) {
        log.push(r);
    }
    let mut store = TransferDb::new();
    store.add(log);
    let warm = store
        .warm_start_for(&pw5, SpaceKind::Extended, &VtaConfig::zcu102(),
                        100)
        .expect("paper logs must transfer into extended runs");
    assert_eq!(warm.kind, SpaceKind::Extended);
    assert!(warm
        .records
        .iter()
        .all(|r| r.visible.len() == SpaceKind::Extended.n_visible()));

    let env = TuningEnv::with_space(VtaConfig::zcu102(), pw5,
                                    SpaceKind::Extended);
    let cfg = TunerConfig { max_trials: 30, seed: 3,
                            ..Default::default() };
    let a = Ml2Tuner::new(cfg.clone())
        .with_warm_start(warm.clone())
        .tune_with(&env, &engine);
    let b = Ml2Tuner::new(cfg)
        .with_warm_start(warm)
        .tune_with(&env, &engine);
    assert_eq!(a.tuner, "ml2tuner-warm");
    assert_eq!(a.len(), 30);
    assert_eq!(format!("{:?}", a.trials), format!("{:?}", b.trials));
}
