//! Frozen pre-rewrite simulator check path, copied verbatim from the tree
//! before the scratch-arena/sweep rewrite of `vta::timing` and
//! `vta::functional`. Tests and benches pin the rewritten hot path against
//! this reference: [`legacy_check`] must produce bit-identical verdicts and
//! cycle counts, and [`legacy_schedule`] bit-identical serialized orders.
//!
//! Do NOT "fix" or modernise this file — its whole value is that it does not
//! change when the library does. Shared public types (`VtaConfig`, the ISA,
//! `Schedule`, `Fault`, `Verdict`) are imported from the library; only the
//! *algorithms* are frozen here.
#![allow(dead_code)]

use ml2tuner::vta::config::VtaConfig;
use ml2tuner::vta::isa::{buf_bytes, Buffer, Instr, Module, Program};
use ml2tuner::vta::timing::Schedule;
use ml2tuner::vta::{Fault, Verdict};

// ------------------------------------------------------------------ check

/// Frozen equivalent of the old `Simulator::check`: timing co-simulation,
/// then address bounds, then the pending-set hazard pass — same fault
/// precedence as the rewritten `Simulator::check_with`.
pub fn legacy_check(cfg: &VtaConfig, prog: &Program) -> Verdict {
    let schedule = match simulate_schedule(cfg, prog) {
        Ok(s) => s,
        Err(f) => return Verdict::Invalid { fault: f, cycles: 0 },
    };
    if let Err(fault) = check_addresses(cfg, prog) {
        return Verdict::Invalid { fault, cycles: schedule.cycles };
    }
    if let Err(fault) = check_hazards(cfg, prog, &schedule) {
        return Verdict::Invalid { fault, cycles: schedule.cycles };
    }
    Verdict::Valid { cycles: schedule.cycles }
}

/// Frozen timing model entry point (old `timing::simulate_schedule`).
pub fn legacy_schedule(
    cfg: &VtaConfig,
    prog: &Program,
) -> Result<Schedule, Fault> {
    simulate_schedule(cfg, prog)
}

// ----------------------------------------------------------------- timing

/// Duration of one instruction in cycles (old 3-argument signature — the
/// `prog` parameter was never used; the rewrite dropped it).
fn instr_cycles(cfg: &VtaConfig, prog: &Program, ins: &Instr) -> u64 {
    match ins {
        Instr::Load { buf, dma, .. } => {
            let bytes = (dma.elems() * buf_bytes(cfg, *buf)) as u64;
            cfg.dma_latency
                + bytes.div_ceil(cfg.dma_bytes_per_cycle)
                + dma.rows as u64 * cfg.dma_row_overhead
        }
        Instr::Memset { count, .. } => {
            8 + *count as u64 * cfg.memset_cycles_per_vec
        }
        Instr::LoadUop { uop_begin, uop_end, .. } => {
            let bytes = ((uop_end - uop_begin) * cfg.uop_bytes()) as u64;
            cfg.dma_latency + bytes.div_ceil(cfg.dma_bytes_per_cycle)
        }
        Instr::Gemm { ubuf_begin, ubuf_end, lp0, lp1, .. } => {
            // MXU issues one block-op per cycle once streaming.
            let _ = prog; // uop table not needed for the op count
            let ops = (ubuf_end - ubuf_begin) as u64
                * lp0.extent.max(1) as u64
                * lp1.extent.max(1) as u64;
            cfg.gemm_overhead + ops
        }
        Instr::Alu { count, .. } => {
            cfg.alu_overhead + *count as u64 * cfg.alu_cycles_per_vec
        }
        Instr::Store { dma, .. } => {
            // store path writes int8 lanes: block bytes per vector
            let bytes = (dma.elems() * cfg.block()) as u64;
            cfg.dma_latency
                + bytes.div_ceil(cfg.dma_bytes_per_cycle)
                + dma.rows as u64 * cfg.dma_row_overhead
        }
        Instr::Finish => cfg.finish_cycles,
    }
}

/// The four token FIFOs, as (queue of push-times).
#[derive(Default)]
struct Queues {
    l2g: std::collections::VecDeque<u64>, // load → compute (data ready)
    g2l: std::collections::VecDeque<u64>, // compute → load (buffer free)
    g2s: std::collections::VecDeque<u64>, // compute → store (data ready)
    s2g: std::collections::VecDeque<u64>, // store → compute (buffer free)
}

/// Run the co-simulation; returns the schedule or a deadlock fault.
fn simulate_schedule(
    cfg: &VtaConfig,
    prog: &Program,
) -> Result<Schedule, Fault> {
    // split instruction indices per module (order preserved)
    let mut streams: [Vec<usize>; 3] = Default::default();
    for (i, ins) in prog.instrs.iter().enumerate() {
        streams[ins.module() as usize].push(i);
    }
    let mut ptr = [0usize; 3]; // next instruction per module
    let mut free = [0u64; 3]; // module-ready times
    let mut busy = [0u64; 3];
    let mut q = Queues::default();
    let mut order: Vec<(u64, usize)> = Vec::with_capacity(prog.instrs.len());
    let mut done = 0usize;
    let total = prog.instrs.len();
    while done < total {
        let mut advanced = false;
        // pick, among runnable modules, the one that can start earliest
        let mut best: Option<(u64, usize)> = None; // (start, module)
        for m in 0..3 {
            if ptr[m] >= streams[m].len() {
                continue;
            }
            let idx = streams[m][ptr[m]];
            let dep = prog.instrs[idx].dep();
            // peek required tokens
            let mut start = free[m];
            let mut ok = true;
            let (prev_q, next_q): (
                Option<&std::collections::VecDeque<u64>>,
                Option<&std::collections::VecDeque<u64>>,
            ) = match module_of(m) {
                Module::Load => (None, Some(&q.g2l)),
                Module::Compute => (Some(&q.l2g), Some(&q.s2g)),
                Module::Store => (Some(&q.g2s), None),
            };
            if dep.pop_prev {
                match prev_q.and_then(|qq| qq.front()) {
                    Some(&t) => start = start.max(t),
                    None => ok = false,
                }
            }
            if dep.pop_next {
                match next_q.and_then(|qq| qq.front()) {
                    Some(&t) => start = start.max(t),
                    None => ok = false,
                }
            }
            let earliest = match best {
                None => true,
                Some((s, _)) => start < s,
            };
            if ok && earliest {
                best = Some((start, m));
            }
        }
        if let Some((start, m)) = best {
            let idx = streams[m][ptr[m]];
            let ins = &prog.instrs[idx];
            let dep = ins.dep();
            // consume tokens
            match module_of(m) {
                Module::Load => {
                    if dep.pop_next {
                        q.g2l.pop_front();
                    }
                }
                Module::Compute => {
                    if dep.pop_prev {
                        q.l2g.pop_front();
                    }
                    if dep.pop_next {
                        q.s2g.pop_front();
                    }
                }
                Module::Store => {
                    if dep.pop_prev {
                        q.g2s.pop_front();
                    }
                }
            }
            let dur = instr_cycles(cfg, prog, ins);
            let end = start + dur;
            free[m] = end;
            busy[m] += dur;
            // publish tokens at end time
            match module_of(m) {
                Module::Load => {
                    if dep.push_next {
                        q.l2g.push_back(end);
                    }
                }
                Module::Compute => {
                    if dep.push_prev {
                        q.g2l.push_back(end);
                    }
                    if dep.push_next {
                        q.g2s.push_back(end);
                    }
                }
                Module::Store => {
                    if dep.push_prev {
                        q.s2g.push_back(end);
                    }
                }
            }
            order.push((start, idx));
            ptr[m] += 1;
            done += 1;
            advanced = true;
        }
        if !advanced {
            let stuck: Vec<String> = (0..3)
                .filter(|&m| ptr[m] < streams[m].len())
                .map(|m| format!("{:?}@{}", module_of(m), ptr[m]))
                .collect();
            return Err(Fault::Deadlock(format!(
                "dependency tokens never arrive: {}",
                stuck.join(", ")
            )));
        }
    }
    // serialized order = (start, program index); stable tie-break on index
    order.sort();
    let cycles = free.iter().copied().max().unwrap_or(0);
    Ok(Schedule { cycles, order, busy })
}

fn module_of(m: usize) -> Module {
    match m {
        0 => Module::Load,
        1 => Module::Compute,
        _ => Module::Store,
    }
}

// ------------------------------------------------------------------ bounds

/// Address-bounds pass: first crash or ACC-wrap corruption, program order.
fn check_addresses(cfg: &VtaConfig, prog: &Program) -> Result<(), Fault> {
    let mut corruption: Option<Fault> = None;
    let windows = uop_windows(prog);
    for (idx, ins) in prog.instrs.iter().enumerate() {
        match ins {
            Instr::Load { buf, dma, .. } => {
                let cap = capacity(cfg, *buf);
                let dram_cap = match buf {
                    Buffer::Inp => prog.dram_inp_vecs,
                    Buffer::Wgt => prog.dram_wgt_blocks,
                    Buffer::Acc => prog.dram_inp_vecs, // acc loads read inp space
                };
                if dma.dram_end() > dram_cap {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: load DMA reads past DRAM \
                         ({} > {dram_cap})",
                        dma.dram_end()
                    )));
                }
                if dma.sram_end() > cap {
                    match buf {
                        Buffer::Acc => hold_corruption(
                            &mut corruption,
                            format!(
                                "instr {idx}: ACC load wraps ({} > {cap})",
                                dma.sram_end()
                            ),
                        ),
                        _ => {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: {buf:?} load overflows \
                                 scratchpad ({} > {cap})",
                                dma.sram_end()
                            )))
                        }
                    }
                }
            }
            Instr::Memset { buf, sram_base, count, .. } => {
                let cap = capacity(cfg, *buf);
                if sram_base + count > cap {
                    match buf {
                        Buffer::Acc => hold_corruption(
                            &mut corruption,
                            format!("instr {idx}: ACC memset wraps"),
                        ),
                        _ => {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: {buf:?} memset overflows \
                                 scratchpad ({} > {cap})",
                                sram_base + count
                            )))
                        }
                    }
                }
            }
            Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => {
                if *uop_end > prog.uops.len() || uop_begin > uop_end {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: uop table range [{uop_begin},{uop_end}) \
                         out of bounds"
                    )));
                }
                if sram_base + (uop_end - uop_begin) > cfg.uop_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: uop buffer overflow \
                         ({} > {})",
                        sram_base + (uop_end - uop_begin),
                        cfg.uop_capacity()
                    )));
                }
            }
            Instr::Gemm { reset, .. } => {
                let r = gemm_ranges(prog, ins, idx, &windows)?;
                if !reset && r.inp.1 > cfg.inp_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM reads INP past scratchpad \
                         ({} > {})",
                        r.inp.1,
                        cfg.inp_capacity()
                    )));
                }
                if !reset && r.wgt.1 > cfg.wgt_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM reads WGT past scratchpad \
                         ({} > {})",
                        r.wgt.1,
                        cfg.wgt_capacity()
                    )));
                }
                if r.ubuf.1 > cfg.uop_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM uop range past uop buffer"
                    )));
                }
                if r.acc.1 > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!(
                            "instr {idx}: GEMM ACC index wraps ({} > {})",
                            r.acc.1,
                            cfg.acc_capacity()
                        ),
                    );
                }
            }
            Instr::Alu { acc_base, count, .. } => {
                if acc_base + count > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!("instr {idx}: ALU ACC range wraps"),
                    );
                }
            }
            Instr::Store { dma, .. } => {
                if dma.dram_end() > prog.dram_out_vecs {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: store DMA writes past DRAM \
                         ({} > {})",
                        dma.dram_end(),
                        prog.dram_out_vecs
                    )));
                }
                if dma.sram_end() > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!("instr {idx}: store reads wrapped ACC"),
                    );
                }
            }
            Instr::Finish => {}
        }
    }
    match corruption {
        Some(f) => Err(f),
        None => Ok(()),
    }
}

fn hold_corruption(slot: &mut Option<Fault>, msg: String) {
    if slot.is_none() {
        *slot = Some(Fault::Corruption(msg));
    }
}

fn capacity(cfg: &VtaConfig, buf: Buffer) -> usize {
    match buf {
        Buffer::Inp => cfg.inp_capacity(),
        Buffer::Wgt => cfg.wgt_capacity(),
        Buffer::Acc => cfg.acc_capacity(),
    }
}

// ----------------------------------------------------------------- ranges

/// Address spaces for hazard tracking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Space {
    Inp,
    Wgt,
    Acc,
    Ubuf,
}

/// One access: half-open element range with a write flag.
#[derive(Clone, Copy, Debug)]
struct Access {
    space: Space,
    lo: usize,
    hi: usize,
    write: bool,
}

struct GemmRanges {
    acc: (usize, usize),
    inp: (usize, usize),
    wgt: (usize, usize),
    ubuf: (usize, usize),
}

/// Uop-buffer windows established by LoadUop instructions, in program
/// order: `(instr_idx, sram_base, uop_begin, uop_end)`.
type UopWindows = Vec<(usize, usize, usize, usize)>;

fn uop_windows(prog: &Program) -> UopWindows {
    prog.instrs
        .iter()
        .enumerate()
        .filter_map(|(i, ins)| match ins {
            Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => {
                Some((i, *sram_base, *uop_begin, *uop_end))
            }
            _ => None,
        })
        .collect()
}

/// Bounding element ranges a GEMM instruction touches (exact for the dense
/// loops our compiler emits).
fn gemm_ranges(
    prog: &Program,
    ins: &Instr,
    idx: usize,
    windows: &UopWindows,
) -> Result<GemmRanges, Fault> {
    let Instr::Gemm {
        ubuf_begin, ubuf_end, lp0, lp1, acc_base, inp_base, wgt_base, ..
    } = ins
    else {
        unreachable!()
    };
    // The uop-buffer contents are whatever the last covering LoadUop put
    // there (our compiler emits one LoadUop up front).
    let table = windows
        .iter()
        .rev()
        .filter(|(i, ..)| *i < idx)
        .find(|(_, sram, b, e)| {
            *sram <= *ubuf_begin && *ubuf_end <= sram + (e - b)
        })
        .map(|(_, sram, b, e)| (*sram, *b, *e));
    let Some((sram, tb, _te)) = table else {
        return Err(Fault::RegisterError(format!(
            "instr {idx}: GEMM reads uop buffer range \
             [{ubuf_begin},{ubuf_end}) never loaded"
        )));
    };
    let uops = &prog.uops[tb + (ubuf_begin - sram)..tb + (ubuf_end - sram)];
    if uops.is_empty() || lp0.extent == 0 || lp1.extent == 0 {
        return Ok(GemmRanges {
            acc: (*acc_base, *acc_base),
            inp: (*inp_base, *inp_base),
            wgt: (*wgt_base, *wgt_base),
            ubuf: (*ubuf_begin, *ubuf_end),
        });
    }
    let span0 = |off: usize| (lp0.extent - 1) * off;
    let span1 = |off: usize| (lp1.extent - 1) * off;
    // single pass over the (small) uop window for all six extrema
    let mut mins = [usize::MAX; 3];
    let mut maxs = [0usize; 3];
    for u in uops {
        for (k, v) in [u.acc, u.inp, u.wgt].into_iter().enumerate() {
            mins[k] = mins[k].min(v);
            maxs[k] = maxs[k].max(v);
        }
    }
    Ok(GemmRanges {
        acc: (
            acc_base + mins[0],
            acc_base + maxs[0] + span0(lp0.acc_off) + span1(lp1.acc_off)
                + 1,
        ),
        inp: (
            inp_base + mins[1],
            inp_base + maxs[1] + span0(lp0.inp_off) + span1(lp1.inp_off)
                + 1,
        ),
        wgt: (
            wgt_base + mins[2],
            wgt_base + maxs[2] + span0(lp0.wgt_off) + span1(lp1.wgt_off)
                + 1,
        ),
        ubuf: (*ubuf_begin, *ubuf_end),
    })
}

fn accesses(prog: &Program, idx: usize, windows: &UopWindows) -> Vec<Access> {
    match &prog.instrs[idx] {
        Instr::Load { buf, dma, .. } => vec![Access {
            space: space_of(*buf),
            lo: dma.sram_base,
            hi: dma.sram_end(),
            write: true,
        }],
        Instr::Memset { buf, sram_base, count, .. } => vec![Access {
            space: space_of(*buf),
            lo: *sram_base,
            hi: sram_base + count,
            write: true,
        }],
        Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => vec![Access {
            space: Space::Ubuf,
            lo: *sram_base,
            hi: sram_base + (uop_end - uop_begin),
            write: true,
        }],
        ins @ Instr::Gemm { reset, .. } => match gemm_ranges(prog, ins, idx, windows)
        {
            // reset-mode GEMM only zero-fills ACC: no INP/WGT reads.
            Ok(r) if *reset => vec![
                Access { space: Space::Acc, lo: r.acc.0, hi: r.acc.1,
                         write: true },
                Access { space: Space::Ubuf, lo: r.ubuf.0, hi: r.ubuf.1,
                         write: false },
            ],
            Ok(r) => vec![
                Access { space: Space::Acc, lo: r.acc.0, hi: r.acc.1,
                         write: true },
                Access { space: Space::Inp, lo: r.inp.0, hi: r.inp.1,
                         write: false },
                Access { space: Space::Wgt, lo: r.wgt.0, hi: r.wgt.1,
                         write: false },
                Access { space: Space::Ubuf, lo: r.ubuf.0, hi: r.ubuf.1,
                         write: false },
            ],
            Err(_) => Vec::new(), // bounds pass reports this as a crash
        },
        Instr::Alu { acc_base, count, .. } => vec![Access {
            space: Space::Acc,
            lo: *acc_base,
            hi: acc_base + count,
            write: true,
        }],
        Instr::Store { dma, .. } => vec![Access {
            space: Space::Acc,
            lo: dma.sram_base,
            hi: dma.sram_end(),
            write: false,
        }],
        Instr::Finish => Vec::new(),
    }
}

fn space_of(buf: Buffer) -> Space {
    match buf {
        Buffer::Inp => Space::Inp,
        Buffer::Wgt => Space::Wgt,
        Buffer::Acc => Space::Acc,
    }
}

// ----------------------------------------------------------------- hazard

/// Frozen pending-set hazard pass. `schedule.order` is the serialized
/// execution order (by start time) from the timing model; any conflicting
/// pair that executes out of *program* order corrupts data.
fn check_hazards(
    _cfg: &VtaConfig,
    prog: &Program,
    schedule: &Schedule,
) -> Result<(), Fault> {
    // pending = program-earlier instructions that have not yet executed.
    // When instruction k executes while j < k is pending, (j, k) runs out of
    // program order: conflict ⇒ corruption.
    let mut executed = vec![false; prog.instrs.len()];
    let mut frontier = 0usize; // all idx < frontier executed
    let mut pending: Vec<usize> = Vec::new();
    let windows = uop_windows(prog);
    let acc_cache: Vec<Vec<Access>> = (0..prog.instrs.len())
        .map(|i| accesses(prog, i, &windows))
        .collect();
    for &(_, k) in &schedule.order {
        // instructions k jumps over become pending FIRST — k itself may
        // invert against them
        if k >= frontier {
            for j in frontier..k {
                if !executed[j] {
                    pending.push(j);
                }
            }
            frontier = k + 1;
        }
        for &j in &pending {
            if j < k
                && conflicts(acc_cache[j].as_slice(),
                             acc_cache[k].as_slice())
            {
                return Err(Fault::Corruption(format!(
                    "instr {k} executes before conflicting instr {j} \
                     (cross-thread/double-buffer scratchpad aliasing)"
                )));
            }
        }
        executed[k] = true;
        pending.retain(|&j| !executed[j]);
    }
    Ok(())
}

fn conflicts(a: &[Access], b: &[Access]) -> bool {
    for x in a {
        for y in b {
            if x.space == y.space
                && (x.write || y.write)
                && x.lo < y.hi
                && y.lo < x.hi
            {
                return true;
            }
        }
    }
    false
}
