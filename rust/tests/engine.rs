//! Integration: the parallel tuning engine.
//!
//! * determinism — a tuning trace is byte-identical for `jobs=1` vs
//!   `jobs=4` (and matches the legacy sequential `TuningEnv::profile`
//!   path record-for-record);
//! * compile-cache — pool candidates compiled in the ML²Tuner A-stage
//!   are not recompiled when the re-ranked winners are profiled in the
//!   same round;
//! * `tune-net` — the network scheduler spends exactly the global
//!   budget, covers every layer, and is itself jobs-invariant.

use ml2tuner::engine::{
    Engine, EngineConfig, NetworkConfig, NetworkTuner, TunerKind,
};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::{self, resnet18, ConvLayer};

fn env(layer: &str) -> TuningEnv {
    TuningEnv::new(VtaConfig::zcu102(), resnet18::layer(layer).unwrap())
}

/// Byte-exact trace fingerprint (schedule, features, outcome — all of it).
fn fingerprint(trace: &ml2tuner::tuner::report::TuningTrace) -> String {
    format!("{:?}", trace.trials)
}

#[test]
fn ml2tuner_trace_is_identical_for_1_and_4_jobs() {
    let e = env("conv5");
    let cfg = TunerConfig { max_trials: 60, seed: 11, ..Default::default() };
    let t1 = Ml2Tuner::new(cfg.clone()).tune_with(&e, &Engine::with_jobs(1));
    let t4 = Ml2Tuner::new(cfg).tune_with(&e, &Engine::with_jobs(4));
    assert_eq!(t1.len(), 60);
    assert_eq!(fingerprint(&t1), fingerprint(&t4));
}

#[test]
fn ml2tuner_trace_is_identical_for_1_2_and_8_jobs() {
    // PR 5: `--jobs` now also shards the explorer's scoring sweep, so
    // worker-count invariance covers candidate *selection*, not just
    // profiling order
    let e = env("conv3");
    let cfg = TunerConfig { max_trials: 50, seed: 17, ..Default::default() };
    let traces: Vec<String> = [1usize, 2, 8]
        .iter()
        .map(|&j| {
            fingerprint(
                &Ml2Tuner::new(cfg.clone())
                    .tune_with(&e, &Engine::with_jobs(j)),
            )
        })
        .collect();
    assert_eq!(traces[0], traces[1]);
    assert_eq!(traces[0], traces[2]);
}

#[test]
fn extended_space_trace_is_jobs_invariant() {
    use ml2tuner::compiler::schedule::SpaceKind;
    // the 6x extended space exercises multi-chunk parallel sweeps
    let e = TuningEnv::with_space(
        VtaConfig::zcu102(),
        resnet18::layer("conv5").unwrap(),
        SpaceKind::Extended,
    );
    let cfg = TunerConfig { max_trials: 40, seed: 5, ..Default::default() };
    let t1 = Ml2Tuner::new(cfg.clone()).tune_with(&e, &Engine::with_jobs(1));
    let t8 = Ml2Tuner::new(cfg).tune_with(&e, &Engine::with_jobs(8));
    assert_eq!(t1.len(), 40);
    assert_eq!(fingerprint(&t1), fingerprint(&t8));
}

#[test]
fn baseline_traces_are_identical_for_1_and_4_jobs() {
    let e = env("conv3");
    let cfg = TunerConfig { max_trials: 40, seed: 3, ..Default::default() };
    let r1 = RandomTuner::new(cfg.clone())
        .tune_with(&e, &Engine::with_jobs(1));
    let r4 = RandomTuner::new(cfg.clone())
        .tune_with(&e, &Engine::with_jobs(4));
    assert_eq!(fingerprint(&r1), fingerprint(&r4));
    let v1 = TvmTuner::new(cfg.clone()).tune_with(&e, &Engine::with_jobs(1));
    let v4 = TvmTuner::new(cfg).tune_with(&e, &Engine::with_jobs(4));
    assert_eq!(fingerprint(&v1), fingerprint(&v4));
}

#[test]
fn engine_trace_matches_legacy_sequential_profiling() {
    // the cached/parallel profile path must agree with TuningEnv::profile
    let e = env("conv5");
    let cfg = TunerConfig { max_trials: 30, seed: 7, ..Default::default() };
    let trace = RandomTuner::new(cfg).tune_with(&e, &Engine::with_jobs(4));
    for t in &trace.trials {
        let seq = e.profile(t.space_index);
        assert_eq!(format!("{t:?}"), format!("{seq:?}"));
    }
}

#[test]
fn a_stage_pool_is_not_recompiled_when_profiled() {
    let e = env("conv5");
    // unbounded cache so the miss-accounting below is exact
    let engine = Engine::new(EngineConfig {
        jobs: 2,
        max_cache_entries: usize::MAX,
        max_cache_cost: usize::MAX,
    });
    let cfg = TunerConfig { max_trials: 60, seed: 5, ..Default::default() };
    let trace = Ml2Tuner::new(cfg).tune_with(&e, &engine);
    assert_eq!(trace.len(), 60);
    let stats = engine.cache().stats();
    // model-guided rounds compile a 20-candidate pool and then profile 10
    // of them: those profiles must be cache hits, so misses (= actual
    // compilations) stay strictly below lookups
    assert!(stats.hits > 0, "no cache hit in a full ML²Tuner run");
    assert!(stats.misses < stats.lookups());
    // misses are real compilations: one per distinct schedule (plus at
    // most a handful of benign same-key races between two workers)
    let distinct = engine.cache().len() as u64;
    assert!(stats.misses >= distinct);
    assert!(stats.misses <= distinct + 4,
            "recompilation beyond racing duplicates: {} misses for {} \
             distinct schedules", stats.misses, distinct);
}

#[test]
fn tune_net_smoke_under_small_budget() {
    let layers: Vec<ConvLayer> = vec![
        resnet18::layer("conv1").unwrap(),
        resnet18::layer("conv5").unwrap(),
    ];
    let cfg = NetworkConfig {
        tuner: TunerKind::Ml2,
        total_trials: 80,
        round_trials: 10,
        base: TunerConfig { seed: 1, ..Default::default() },
        ..Default::default()
    };
    let engine = Engine::with_jobs(2);
    let out = NetworkTuner::new(cfg).tune(&engine, &layers);
    let report = &out.report;
    assert_eq!(report.total_trials, 80, "global budget fully spent");
    assert_eq!(
        report.layers.iter().map(|l| l.trials).sum::<usize>(),
        80,
        "per-layer trials account for the whole budget"
    );
    assert!(report.layers.iter().all(|l| l.rounds >= 1),
            "round-robin warmup covered every layer");
    assert!(report.tuned_layers() >= 1,
            "at least one layer found a valid schedule");
    for l in &report.layers {
        if let Some(s) = &l.best_schedule {
            assert!(l.best_cycles.is_some(), "{}: schedule w/o cycles {s}",
                    l.layer);
        }
    }
    let rendered = report.render();
    assert!(rendered.contains("conv1") && rendered.contains("conv5"));
    // per-layer databases mirror the traces
    assert_eq!(out.databases.len(), 2);
    for (db, tr) in out.databases.iter().zip(&out.traces) {
        assert_eq!(db.len(), tr.len());
        assert_eq!(db.layer, tr.layer);
    }
}

#[test]
fn tune_net_is_deterministic_and_jobs_invariant() {
    let layers: Vec<ConvLayer> = vec![
        resnet18::layer("conv2").unwrap(),
        resnet18::layer("conv4").unwrap(),
    ];
    let cfg = NetworkConfig {
        tuner: TunerKind::Random,
        total_trials: 40,
        round_trials: 10,
        base: TunerConfig { seed: 9, ..Default::default() },
        ..Default::default()
    };
    let a = NetworkTuner::new(cfg.clone())
        .tune(&Engine::with_jobs(1), &layers);
    let b = NetworkTuner::new(cfg)
        .tune(&Engine::with_jobs(4), &layers);
    assert_eq!(a.traces.len(), b.traces.len());
    for (x, y) in a.traces.iter().zip(&b.traces) {
        assert_eq!(format!("{:?}", x.trials), format!("{:?}", y.trials));
    }
    assert_eq!(a.report.render(), b.report.render());
}

#[test]
fn tune_net_is_jobs_invariant_on_a_non_resnet_network() {
    // registry-routed layers: the scheduler must behave identically on
    // any registered network, with the full ML² policy in the loop
    let net = workloads::network("mobilenet").unwrap();
    let layers: Vec<ConvLayer> =
        vec![net.layer("pw4").unwrap(), net.layer("red2").unwrap()];
    let cfg = NetworkConfig {
        tuner: TunerKind::Ml2,
        total_trials: 60,
        round_trials: 10,
        base: TunerConfig { seed: 3, ..Default::default() },
        ..Default::default()
    };
    let a = NetworkTuner::new(cfg.clone())
        .tune(&Engine::with_jobs(1), &layers);
    let b = NetworkTuner::new(cfg)
        .tune(&Engine::with_jobs(4), &layers);
    assert_eq!(a.report.total_trials, 60);
    for (x, y) in a.traces.iter().zip(&b.traces) {
        assert_eq!(format!("{:?}", x.trials), format!("{:?}", y.trials));
    }
    assert_eq!(a.report.render(), b.report.render());
    assert!(a.report.render().contains("pw4"));
}

#[test]
fn tune_net_saves_one_database_per_layer() {
    let layers = vec![resnet18::layer("conv5").unwrap()];
    let cfg = NetworkConfig {
        tuner: TunerKind::Random,
        total_trials: 20,
        round_trials: 10,
        base: TunerConfig { seed: 2, ..Default::default() },
        ..Default::default()
    };
    let out = NetworkTuner::new(cfg).tune(&Engine::with_jobs(2), &layers);
    let dir = std::env::temp_dir().join("ml2tuner_tune_net_test");
    let paths = out.save_databases(&dir).unwrap();
    assert_eq!(paths.len(), 1);
    assert!(paths[0].ends_with("conv5.json"));
    let back =
        ml2tuner::tuner::database::Database::load(&paths[0]).unwrap();
    assert_eq!(back.len(), 20);
    std::fs::remove_dir_all(&dir).ok();
}
