//! Property tests over coordinator/substrate invariants (in-tree harness;
//! see `util::prop`): random schedules on random layers must never break
//! the simulator's internal consistency.

use ml2tuner::compiler::{passes, schedule::Schedule, Compiler};
use ml2tuner::runtime::golden::reference_conv;
use ml2tuner::util::prop::{self, assert_prop};
use ml2tuner::vta::{config::VtaConfig, functional, layout, Simulator};
use ml2tuner::workloads::synth;

fn random_schedule(g: &mut prop::Gen) -> Schedule {
    Schedule {
        tile_h: g.usize_in(1, 32),
        tile_w: g.usize_in(1, 32),
        tile_oc: 16 * g.usize_in(1, 8),
        tile_ic: 16 * g.usize_in(1, 8),
        n_vthreads: [1, 2, 4, 8][g.usize_in(0, 3)],
        // extension knobs: the invariants below (compile never panics,
        // no deadlock, valid ⇒ bit-exact vs the reference conv) must
        // hold across the whole extended space too
        n_load_slots: g.usize_in(1, 2),
        k_unroll: [1, 2, 4][g.usize_in(0, 2)],
    }
}

#[test]
fn prop_compile_never_panics_and_check_terminates() {
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    prop::check(60, |g| {
        let layer = synth::random_layer(g.rng());
        let sched = random_schedule(g);
        let compiled = compiler.compile(&layer, &sched);
        let verdict = sim.check(&compiled.program);
        assert_prop(
            !compiled.program.is_empty(),
            "program must not be empty",
        )?;
        // cycle model must be positive for any program that timed out fine
        if verdict.is_valid() {
            assert_prop(verdict.cycles() > 0, "zero-cycle program")?;
        }
        Ok(())
    });
}

#[test]
fn prop_compiled_programs_never_deadlock() {
    // the dep-token emission must be deadlock-free for ANY schedule
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    prop::check(60, |g| {
        let layer = synth::random_layer(g.rng());
        let sched = random_schedule(g);
        let compiled = compiler.compile(&layer, &sched);
        match ml2tuner::vta::timing::simulate(&cfg, &compiled.program) {
            Err(ml2tuner::vta::Fault::Deadlock(m)) => {
                Err(format!("deadlock: {m} ({sched})"))
            }
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_valid_verdict_implies_reference_exact_output() {
    // THE invariant the whole tuning loop rests on: if check() says valid,
    // numeric execution matches the (pure-rust) golden reference bit-for-
    // bit — tiling never changes integer results.
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    prop::check(25, |g| {
        let layer = synth::random_layer(g.rng());
        let sched = random_schedule(g);
        let compiled = compiler.compile(&layer, &sched);
        if !sim.check(&compiled.program).is_valid() {
            return Ok(()); // only valid configs carry the guarantee
        }
        let seed = g.u64();
        let x = synth::input_data(&layer, seed);
        let w = synth::weight_data(&layer, seed);
        let dram = functional::Dram {
            inp: layout::pack_input(&cfg, &x, layer.h, layer.w, layer.c),
            wgt: layout::pack_weights(&cfg, &w, layer.kh, layer.kw,
                                      layer.c, layer.kc),
            out_vecs: compiled.program.dram_out_vecs,
        };
        let out = sim
            .execute(&compiled.program, &dram)
            .map_err(|f| format!("valid program crashed: {f:?}"))?;
        let want = reference_conv(&layer, &x, &w, cfg.shift);
        assert_prop(out == want,
                    &format!("{} {sched}: output mismatch", layer.name))
    });
}

#[test]
fn prop_legalized_geometry_is_consistent() {
    let cfg = VtaConfig::zcu102();
    prop::check(200, |g| {
        let layer = synth::random_layer(g.rng());
        let sched = random_schedule(g);
        let a = passes::analyze(&cfg, &layer, &sched);
        assert_prop(a.th <= layer.oh && a.tw <= layer.ow, "tile clamp")?;
        assert_prop(layer.c % a.tic == 0, "tic divides C")?;
        assert_prop(a.tiles_h * a.th >= layer.oh, "tiles cover OH")?;
        assert_prop((a.tiles_h - 1) * a.th < layer.oh, "no empty tiles")?;
        assert_prop(a.th_last <= a.th && a.th_last >= 1, "remainder")?;
        assert_prop(
            a.nbc_last <= a.nbc && a.nbc * a.tiles_oc >= a.kcb,
            "oc tiling covers KC",
        )
    });
}

#[test]
fn prop_verdict_deterministic() {
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    prop::check(30, |g| {
        let layer = synth::random_layer(g.rng());
        let sched = random_schedule(g);
        let c1 = compiler.compile(&layer, &sched);
        let c2 = compiler.compile(&layer, &sched);
        assert_prop(
            sim.check(&c1.program) == sim.check(&c2.program),
            "verdict must be deterministic",
        )
    });
}

#[test]
fn prop_gbdt_predictions_bounded_by_labels() {
    // leaves are weighted averages: an ensemble over [lo, hi] labels stays
    // within [lo-ε, hi+ε] (no-extrapolation property the explorer relies
    // on, see tuner::explorer docs)
    use ml2tuner::gbdt::{Booster, Dataset, GbdtParams, TrainOpts};
    prop::check(20, |g| {
        let n = g.usize_in(20, 120);
        let rng = g.rng();
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.range_f64(0.0, 10.0),
                          rng.range_f64(0.0, 10.0)])
            .collect();
        let labels: Vec<f64> =
            rows.iter().map(|r| r[0] + 2.0 * r[1]).collect();
        let lo = labels.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = labels.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let params = GbdtParams { boost_rounds: 40, max_depth: 4,
                                  learning_rate: 0.3,
                                  ..Default::default() };
        let b = Booster::fit(&params,
                             &Dataset::from_rows(&rows, &labels),
                             &TrainOpts::default());
        for _ in 0..20 {
            let probe =
                vec![rng.range_f64(-20.0, 30.0), rng.range_f64(-20.0, 30.0)];
            let p = b.predict_row(&probe);
            if p < lo - 1.0 || p > hi + 1.0 {
                return Err(format!("extrapolated: {p} outside [{lo},{hi}]"));
            }
        }
        Ok(())
    });
}
