//! Pin: the scratch-arena profiling hot path (`simulate_into` +
//! sweep-based hazard checking + `check_with`) is bit-identical to the
//! frozen pre-rewrite implementation in `tests/common/legacy_sim.rs` —
//! verdicts, cycle counts, fault messages, and serialized execution
//! orders — across both search spaces, all four targets, and arbitrary
//! scratch reuse. Plus the check-vs-execute equivalence sweep: a
//! `check`-valid program's pipelined execution matches program-order
//! execution bit-for-bit (no hazard slipped through).

#[path = "common/legacy_sim.rs"]
mod legacy_sim;

use ml2tuner::compiler::schedule::{space_for, SpaceKind};
use ml2tuner::compiler::Compiler;
use ml2tuner::tuner::TuningEnv;
use ml2tuner::util::prop::{self, assert_prop};
use ml2tuner::util::rng::Rng;
use ml2tuner::vta::{
    config::VtaConfig, functional, layout, targets, SimScratch, Simulator,
};
use ml2tuner::workloads::{resnet18, synth};

/// Deterministic schedule-index corpus over a space (with replacement —
/// duplicates deliberately re-exercise a warmed scratch on the same
/// program).
fn corpus(rng: &mut Rng, space_len: usize, n: usize) -> Vec<usize> {
    (0..n).map(|_| rng.below(space_len)).collect()
}

#[test]
fn check_with_matches_legacy_across_spaces_and_targets() {
    let mut rng = Rng::new(0x5C12A7C4);
    // ONE scratch reused across every target, space, layer, and program:
    // arena reuse must be semantically invisible even across hardware
    // configs with different buffer capacities.
    let mut scratch = SimScratch::new();
    let mut checked = 0usize;
    let mut faults = 0usize;
    for cfg in targets::all() {
        let compiler = Compiler::new(cfg.clone());
        let sim = Simulator::new(cfg.clone());
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            for name in ["conv2", "conv5"] {
                let layer = resnet18::layer(name).unwrap();
                let space = space_for(&layer, kind);
                for i in corpus(&mut rng, space.len(), 10) {
                    let s = space.schedule(i);
                    let prog = &compiler.compile(&layer, &s).program;
                    let legacy = legacy_sim::legacy_check(&cfg, prog);
                    let fresh = sim.check(prog);
                    let reused = sim.check_with(prog, &mut scratch);
                    assert_eq!(legacy, fresh,
                               "{name} {kind:?} {s}: fresh-scratch \
                                verdict diverged from legacy");
                    assert_eq!(legacy, reused,
                               "{name} {kind:?} {s}: reused-scratch \
                                verdict diverged from legacy");
                    if let Ok(sched) =
                        legacy_sim::legacy_schedule(&cfg, prog)
                    {
                        assert_eq!(sched.order.as_slice(),
                                   scratch.timing.order(),
                                   "{name} {kind:?} {s}: execution \
                                    order diverged");
                        assert_eq!(sched.cycles, scratch.timing.cycles());
                        assert_eq!(sched.busy, scratch.timing.busy());
                    }
                    if !legacy.is_valid() {
                        faults += 1;
                    }
                    checked += 1;
                }
            }
        }
    }
    assert_eq!(checked, 4 * 2 * 2 * 10);
    // the corpus must actually exercise the fault paths, not just Valid
    assert!(faults > 0, "corpus never hit a fault path");
}

#[test]
fn prop_check_with_matches_legacy_on_random_layers() {
    // random layers × random extended-space schedules: same three-way
    // agreement as the frozen corpus, beyond the resnet18 geometry
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let mut scratch = SimScratch::new();
    prop::check(60, |g| {
        let layer = synth::random_layer(g.rng());
        let space = space_for(&layer, SpaceKind::Extended);
        let s = space.schedule(g.usize_in(0, space.len() - 1));
        let prog = &compiler.compile(&layer, &s).program;
        let legacy = legacy_sim::legacy_check(&cfg, prog);
        let reused = sim.check_with(prog, &mut scratch);
        assert_prop(
            legacy == reused,
            &format!("{} {s}: {legacy:?} != {reused:?}", layer.name),
        )
    });
}

#[test]
fn prop_check_valid_implies_pipelined_equals_program_order() {
    // verdict-equivalence: if the hazard sweep says Valid, executing in
    // the pipelined (serialized) order must produce the same bits as
    // executing in program order — i.e. the sweep missed nothing that
    // actually corrupts data.
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let mut scratch = SimScratch::new();
    prop::check(25, |g| {
        let layer = synth::random_layer(g.rng());
        let space = space_for(&layer, SpaceKind::Extended);
        let s = space.schedule(g.usize_in(0, space.len() - 1));
        let prog = &compiler.compile(&layer, &s).program;
        if !sim.check_with(prog, &mut scratch).is_valid() {
            return Ok(()); // only Valid carries the guarantee
        }
        let seed = g.u64();
        let x = synth::input_data(&layer, seed);
        let w = synth::weight_data(&layer, seed);
        let dram = functional::Dram {
            inp: layout::pack_input(&cfg, &x, layer.h, layer.w, layer.c),
            wgt: layout::pack_weights(&cfg, &w, layer.kh, layer.kw,
                                      layer.c, layer.kc),
            out_vecs: prog.dram_out_vecs,
        };
        let pipelined = functional::execute(&cfg, prog, &dram)
            .map_err(|f| format!("valid program crashed: {f:?}"))?;
        let serial = functional::execute_program_order(&cfg, prog, &dram)
            .map_err(|f| format!("program-order run crashed: {f:?}"))?;
        assert_prop(
            pipelined == serial,
            &format!("{} {s}: pipelined output differs from \
                      program order", layer.name),
        )
    });
}

#[test]
fn profile_batch_is_jobs_invariant_with_per_worker_scratch() {
    use ml2tuner::engine::Engine;
    // per-worker scratch arenas must not leak into records: the same
    // batch profiled with 1 and 4 workers is record-for-record identical
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        resnet18::layer("conv4").unwrap(),
        SpaceKind::Extended,
    );
    let mut rng = Rng::new(0xBA7C);
    let batch = corpus(&mut rng, env.space.len(), 48);
    let r1 = Engine::with_jobs(1).profile_batch(&env, &batch);
    let r4 = Engine::with_jobs(4).profile_batch(&env, &batch);
    assert_eq!(format!("{r1:?}"), format!("{r4:?}"));
}
