//! Integration pins for the incremental/meta training paths: warm
//! continuation appends trees without changing budget or determinism,
//! `--retrain-every 1` degenerates bit-identically to the
//! non-incremental loop, unchanged-prefix continuation is bit-identical
//! to a full refit, and a corpus-trained meta artifact makes the run
//! model-guided from its very first batch (and survives the
//! save/load roundtrip unchanged).

use ml2tuner::compiler::schedule::SpaceKind;
use ml2tuner::engine::Engine;
use ml2tuner::obs::Counter;
use ml2tuner::tuner::database::{Database, TransferDb};
use ml2tuner::tuner::meta::{MetaArtifact, MetaStore};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::models::{FitOpts, ModelP};
use ml2tuner::tuner::report::TuningTrace;
use ml2tuner::tuner::train::{Provenance, TrainSet};
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::resnet18;

fn env() -> TuningEnv {
    TuningEnv::new(VtaConfig::zcu102(),
                   resnet18::layer("conv5").unwrap())
}

fn indices(t: &TuningTrace) -> Vec<usize> {
    t.trials.iter().map(|r| r.space_index).collect()
}

/// A profiled conv5 corpus log (stamped for the env's target, so the
/// meta V bucket matches the run's capacity signature).
fn corpus_db(e: &TuningEnv, n: usize) -> Database {
    let mut db =
        Database::for_layer_on(&e.layer, SpaceKind::Paper, e.hw());
    for i in 0..n {
        db.push(e.profile((i * 31) % e.space.len()));
    }
    db
}

#[test]
fn retrain_every_one_matches_non_incremental_bitwise() {
    // --retrain-every 1 forces a full refit every round: the
    // incremental loop must degenerate to the stock one exactly
    let e = env();
    let base = TunerConfig { max_trials: 60, seed: 5,
                             ..Default::default() };
    let plain = Ml2Tuner::new(base.clone()).tune(&e);
    let fallback = Ml2Tuner::new(TunerConfig {
        incremental: true,
        retrain_every: 1,
        ..base
    })
    .tune(&e);
    assert_eq!(indices(&plain), indices(&fallback),
               "retrain-every=1 must fall back to full refits \
                bit-identically");
}

#[test]
fn incremental_run_appends_trees_and_stays_deterministic() {
    let e = env();
    let cfg = TunerConfig { max_trials: 60, seed: 5, incremental: true,
                            ..Default::default() };
    let engine = Engine::single_threaded();
    let mut t = Ml2Tuner::new(cfg.clone());
    let a = t.tune_with(&e, &engine);
    assert_eq!(a.len(), 60, "continuation must not eat the budget");
    let appended =
        engine.recorder().snapshot().counter(Counter::TreesAppended);
    assert!(appended > 0,
            "later rounds must continue the previous ensembles");
    let mut t2 = Ml2Tuner::new(cfg);
    let b = t2.tune_with(&e, &Engine::single_threaded());
    assert_eq!(indices(&a), indices(&b),
               "incremental runs are deterministic per seed");
}

#[test]
fn continuation_on_unchanged_rows_is_bit_identical_to_full_refit() {
    // the model-level pin behind `--incremental`: fitting R1+R2 rounds
    // cold equals fitting R1 then appending R2 on the same rows
    let e = env();
    let db = corpus_db(&e, 60);
    let mut set = TrainSet::new();
    set.extend_p(&db, Provenance::Cold);
    let full = ModelP::fit(&set, &FitOpts::new(40, 3)).unwrap();
    let base = ModelP::fit(&set, &FitOpts::new(28, 3)).unwrap();
    let cont = ModelP::fit(
        &set,
        &FitOpts::new(12, 3).with_base(&base.booster),
    )
    .unwrap();
    assert_eq!(full.booster.trees.len(), cont.booster.trees.len());
    for i in (0..e.space.len()).step_by(97) {
        let f = e.space.visible(i);
        assert_eq!(full.predict(&f).to_bits(),
                   cont.predict(&f).to_bits(),
                   "unchanged-prefix continuation must be bit-identical");
    }
}

#[test]
fn meta_adapted_run_is_model_guided_from_round_one() {
    let e = env();
    let src = corpus_db(&e, 80);
    let art = MetaArtifact::build(SpaceKind::Paper, &[&src], 60);
    assert!(art.p.is_some(), "corpus must train a meta P");
    let cfg = TunerConfig { max_trials: 30, seed: 9,
                            ..Default::default() };
    let cold = Ml2Tuner::new(cfg.clone()).tune(&e);
    let engine = Engine::single_threaded();
    let mut t = Ml2Tuner::new(cfg.clone()).with_meta(art.clone());
    let a = t.tune_with(&e, &engine);
    assert_eq!(a.tuner, "ml2tuner-meta");
    assert_eq!(a.len(), 30);
    assert!(
        engine.recorder().snapshot().counter(Counter::MetaAdapted) > 0,
        "per-round fits must adapt the meta base"
    );
    // the cold run burns its first rounds on random sampling (the
    // min_train gate); the meta run ranks candidates from round 1
    assert_ne!(indices(&cold)[..10], indices(&a)[..10],
               "meta run must be model-guided from the first batch");
    let mut t2 = Ml2Tuner::new(cfg).with_meta(art);
    let b = t2.tune_with(&e, &Engine::single_threaded());
    assert_eq!(indices(&a), indices(&b),
               "meta-adapted runs are deterministic per seed");
}

#[test]
fn meta_store_roundtrip_preserves_tuning_behaviour() {
    let e = env();
    let mut corpus = TransferDb::new();
    corpus.add(corpus_db(&e, 60));
    let store = MetaStore::build_with(&corpus, 40);
    let dir = std::env::temp_dir().join("ml2tuner_meta_training_test");
    std::fs::remove_dir_all(&dir).ok();
    store.save(&dir).unwrap();
    let mut loaded = MetaStore::load(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let mut built = store.clone();
    let cfg = TunerConfig { max_trials: 20, seed: 11,
                            ..Default::default() };
    let a = Ml2Tuner::new(cfg.clone())
        .with_meta(built.take_kind(SpaceKind::Paper).unwrap())
        .tune(&e);
    let b = Ml2Tuner::new(cfg)
        .with_meta(loaded.take_kind(SpaceKind::Paper).unwrap())
        .tune(&e);
    assert_eq!(indices(&a), indices(&b),
               "saved+loaded artifacts must drive the exact same run");
}
