//! Golden-trace guarantee for `--space paper`.
//!
//! The knob-based `ConfigSpace` refactor must leave cold paper-space
//! tuning runs byte-identical to the pre-refactor implementation. A
//! tuning trace is a pure function of
//!
//!   (candidate lists, enumeration order, visible-feature vectors,
//!    compiler output, RNG streams, model code)
//!
//! — the last three are untouched by the refactor (codegen's `unroll==1`
//! path is the original lowering, RNG salts and call sequences are
//! unchanged, GBDT is unchanged), so pinning the first three pins the
//! trace. This file freezes the ORIGINAL hard-coded space implementation
//! (copied verbatim from the pre-refactor `compiler::schedule`) as a
//! reference and checks the new lazy space against it on every layer of
//! every registered network: same size, same enumeration order, same
//! schedules, bit-identical visible features.
//!
//! On top of that, an end-to-end check runs all three tuners on the
//! paper space and verifies every profiled trial matches the frozen
//! reference point-for-point (index → schedule → features).

use ml2tuner::compiler::schedule::{space_for, Schedule, SpaceKind};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::{resnet18, ConvLayer, NETWORKS};

// ---- frozen pre-refactor reference (do not modernize!) ----------------

struct LegacySpace {
    tile_h: Vec<usize>,
    tile_w: Vec<usize>,
    tile_oc: Vec<usize>,
    tile_ic: Vec<usize>,
    n_vthreads: Vec<usize>,
}

fn legacy_spatial(n: usize) -> Vec<usize> {
    let mut v: Vec<usize> =
        (1..=n).filter(|d| n % d == 0 || d % 4 == 0).collect();
    v.dedup();
    v
}

fn legacy_oc(kc: usize) -> Vec<usize> {
    (1..=kc / 16)
        .map(|b| b * 16)
        .filter(|&v| v <= 64 || v % 32 == 0)
        .collect()
}

fn legacy_ic(c: usize) -> Vec<usize> {
    (1..=c / 16).map(|b| b * 16).filter(|v| c % v == 0).collect()
}

fn legacy_candidates(layer: &ConvLayer) -> LegacySpace {
    LegacySpace {
        tile_h: legacy_spatial(layer.oh),
        tile_w: legacy_spatial(layer.ow),
        tile_oc: legacy_oc(layer.kc),
        tile_ic: legacy_ic(layer.c),
        n_vthreads: vec![1, 2, 4, 8, 16],
    }
}

impl LegacySpace {
    fn len(&self) -> usize {
        self.tile_h.len()
            * self.tile_w.len()
            * self.tile_oc.len()
            * self.tile_ic.len()
            * self.n_vthreads.len()
    }

    /// The original enumeration: row-major over the candidate lists,
    /// virtual threads fastest.
    fn nth(&self, i: usize) -> Schedule {
        let mut r = i;
        let pick = |r: &mut usize, xs: &[usize]| {
            let v = xs[*r % xs.len()];
            *r /= xs.len();
            v
        };
        let n_vthreads = pick(&mut r, &self.n_vthreads);
        let tile_ic = pick(&mut r, &self.tile_ic);
        let tile_oc = pick(&mut r, &self.tile_oc);
        let tile_w = pick(&mut r, &self.tile_w);
        let tile_h = pick(&mut r, &self.tile_h);
        Schedule {
            tile_h,
            tile_w,
            tile_oc,
            tile_ic,
            n_vthreads,
            ..Default::default()
        }
    }
}

/// The original hand-written visible-feature formula.
fn legacy_visible(s: &Schedule) -> Vec<f64> {
    let (tw, th) = (s.tile_w as f64, s.tile_h as f64);
    let (ic, oc) = (s.tile_ic as f64, s.tile_oc as f64);
    let vt = s.n_vthreads as f64;
    vec![
        tw,
        th,
        ic,
        oc,
        vt,
        tw * th,
        tw * th * oc,
        tw * th * oc * vt,
        ic * vt,
        tw * th * ic * vt,
        oc * ic * vt,
    ]
}

// ---- space equivalence ------------------------------------------------

#[test]
fn paper_space_is_byte_identical_to_the_legacy_space_on_every_layer() {
    for net in &NETWORKS {
        for layer in net.layers {
            let legacy = legacy_candidates(layer);
            let space = space_for(layer, SpaceKind::Paper);
            assert_eq!(space.len(), legacy.len(), "{}/{}", net.name,
                       layer.name);
            // full sweep on small spaces, strided on large ones — the
            // mixed-radix decode makes any index failure systematic,
            // not local, so a stride cannot miss a real divergence
            let step = (space.len() / 4096).max(1);
            let mut i = 0;
            while i < space.len() {
                let got = space.schedule(i);
                let want = legacy.nth(i);
                assert_eq!(got, want, "{}/{} index {i}", net.name,
                           layer.name);
                // bit-identical features (products of exact integers)
                assert_eq!(
                    SpaceKind::Paper.visible_features(&got),
                    legacy_visible(&want),
                    "{}/{} index {i}",
                    net.name,
                    layer.name
                );
                i += step;
            }
            // boundary indices always checked exactly
            for &i in &[0, space.len() - 1] {
                assert_eq!(space.schedule(i), legacy.nth(i));
            }
        }
    }
}

#[test]
fn paper_visible_names_match_the_legacy_hand_written_list() {
    assert_eq!(
        SpaceKind::Paper.visible_names(),
        vec![
            "TW",
            "TH",
            "tileIC",
            "tileOC",
            "nVirtualThread",
            "TW*TH",
            "TW*TH*tileOC",
            "TW*TH*tileOC*nVT",
            "tileIC*nVT",
            "TW*TH*tileIC*nVT",
            "tileOC*tileIC*nVT",
        ]
    );
}

// ---- end-to-end: traces stay on the frozen reference ------------------

#[test]
fn paper_traces_visit_only_legacy_reference_points() {
    let layer = resnet18::layer("conv5").unwrap();
    let legacy = legacy_candidates(&layer);
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    assert_eq!(env.kind(), SpaceKind::Paper, "default env is paper");
    let cfg = TunerConfig { max_trials: 60, seed: 7, ..Default::default() };
    let tuners: Vec<Box<dyn Tuner>> = vec![
        Box::new(Ml2Tuner::new(cfg.clone())),
        Box::new(TvmTuner::new(cfg.clone())),
        Box::new(RandomTuner::new(cfg)),
    ];
    for mut t in tuners {
        let trace = t.tune(&env);
        assert_eq!(trace.len(), 60);
        for trial in &trace.trials {
            let want = legacy.nth(trial.space_index);
            assert_eq!(trial.schedule, want, "{}", trace.tuner);
            assert_eq!(trial.visible, legacy_visible(&want),
                       "{}", trace.tuner);
            assert_eq!((trial.schedule.n_load_slots,
                        trial.schedule.k_unroll),
                       (2, 1),
                       "paper space must pin the paper-fixed lowering");
        }
    }
}

#[test]
fn paper_traces_are_deterministic_per_seed() {
    // same seed → byte-identical trace; the refactor must not have
    // introduced any hidden iteration-order dependence (HashSet is used
    // for the measured mask, but never iterated)
    let layer = resnet18::layer("conv3").unwrap();
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    let cfg = TunerConfig { max_trials: 50, seed: 13,
                            ..Default::default() };
    let a = Ml2Tuner::new(cfg.clone()).tune(&env);
    let b = Ml2Tuner::new(cfg).tune(&env);
    assert_eq!(format!("{:?}", a.trials), format!("{:?}", b.trials));
}
