//! Counting-global-allocator pin for the allocation-free profiling hot
//! path: a warmed [`SimScratch`] runs `Simulator::check_with` on valid
//! programs with ZERO heap allocations, and a warmed single-worker
//! `Engine::profile_batch` steady state stays within a small constant
//! allocation budget per trial (the `TrialRecord` feature vectors are
//! the only remaining per-trial allocations).
//!
//! Everything lives in one `#[test]` on purpose: the allocation counter
//! is process-global and the libtest harness runs `#[test]`s on
//! concurrent threads, so two counting tests would pollute each other's
//! deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use ml2tuner::compiler::schedule::{space_for, SpaceKind};
use ml2tuner::compiler::Compiler;
use ml2tuner::engine::Engine;
use ml2tuner::tuner::TuningEnv;
use ml2tuner::util::rng::Rng;
use ml2tuner::vta::{config::VtaConfig, SimScratch, Simulator};
use ml2tuner::workloads::resnet18;

/// System allocator with a global allocation counter (frees are not
/// counted — only acquiring fresh memory breaks the steady state).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn warmed_hot_path_allocation_budget() {
    // ---- part 1: check_with on a warmed scratch allocates NOTHING ----
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let layer = resnet18::layer("conv5").unwrap();
    let space = space_for(&layer, SpaceKind::Extended);
    let mut rng = Rng::new(0xA110C);
    let mut progs = Vec::new();
    let mut tries = 0;
    while progs.len() < 8 && tries < 500 {
        tries += 1;
        let s = space.schedule(rng.below(space.len()));
        let c = compiler.compile(&layer, &s);
        // only Valid programs: fault verdicts carry freshly formatted
        // message Strings by design, so zero-alloc applies to the
        // (overwhelmingly common in steady state) valid path
        if sim.check(&c.program).is_valid() {
            progs.push(c.program);
        }
    }
    assert!(progs.len() >= 4, "corpus too small ({} valid)", progs.len());
    let mut scratch = SimScratch::new();
    for _ in 0..2 {
        for p in &progs {
            assert!(sim.check_with(p, &mut scratch).is_valid());
        }
    }
    let before = allocs();
    let mut cycles = 0u64;
    for _ in 0..3 {
        for p in &progs {
            cycles += sim.check_with(p, &mut scratch).cycles();
        }
    }
    let grew = allocs() - before;
    assert!(cycles > 0);
    assert_eq!(
        grew, 0,
        "warmed check_with heap-allocated {grew} times over {} calls",
        3 * progs.len()
    );

    // ---- part 2: warmed profile_batch steady state is O(1) per trial --
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        resnet18::layer("conv5").unwrap(),
        SpaceKind::Extended,
    );
    let engine = Engine::with_jobs(1);
    let batch: Vec<usize> =
        (0..64).map(|_| rng.below(env.space.len())).collect();
    // two warm passes: fill the compile cache, grow the worker scratch
    for _ in 0..2 {
        let recs = engine.profile_batch(&env, &batch);
        assert_eq!(recs.len(), batch.len());
    }
    let before = allocs();
    let recs = engine.profile_batch(&env, &batch);
    let grew = allocs() - before;
    assert_eq!(recs.len(), batch.len());
    // per trial: the visible-feature vector (plus its term registry),
    // the hidden-feature clone, and (for invalid trials) the
    // fault-message String — everything else (simulator, order, hazard
    // sweep, result slots) reuses warm storage. The pre-rewrite path
    // allocated one Vec per *instruction* per trial (hundreds), so 12
    // per trial still catches any regression by an order of magnitude.
    let per_trial = grew as f64 / batch.len() as f64;
    assert!(
        per_trial <= 12.0,
        "warmed profile_batch allocated {grew} times for {} trials \
         ({per_trial:.1}/trial)",
        batch.len()
    );
}
