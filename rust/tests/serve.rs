//! ISSUE-7 guarantees for tuning-as-a-service.
//!
//! 1. **Store**: `ScheduleDb` entries survive a reopen byte-faithfully;
//!    promotion is versioned and strictly better-only; concurrent
//!    appenders never lose the minimum.
//! 2. **Daemon**: hit / miss / miss-with-fallback answer correctly end
//!    to end over the line protocol, and the hit path compiles and
//!    profiles *nothing* (counter-pinned).
//! 3. **Determinism**: the same query script produces identical stored
//!    schedules for any worker count — job seeds derive from the query
//!    key, never from arrival order.

use std::io::Write;
use std::sync::{Arc, Mutex};

use ml2tuner::compiler::schedule::{Schedule, SpaceKind};
use ml2tuner::obs::Counter;
use ml2tuner::serve::{
    Daemon, Promotion, ScheduleDb, ScheduleEntry, ScheduleKey,
    ServeConfig, ServeExit,
};
use ml2tuner::util::json::Json;
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads;

/// `Write` into a shared buffer, so the test can hand an owned response
/// sink to the daemon and still read everything it wrote.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    fn into_string(self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn fresh_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(name);
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    dir
}

fn gemm_layer(name: &str) -> ml2tuner::workloads::ConvLayer {
    workloads::network("synth-gemm").unwrap().layer(name).unwrap()
}

fn entry_for(layer_name: &str, cycles: u64) -> ScheduleEntry {
    let layer = gemm_layer(layer_name);
    ScheduleEntry {
        key: ScheduleKey::for_layer_on(
            &layer,
            SpaceKind::Paper,
            &VtaConfig::zcu102(),
        ),
        version: 0,
        cycles,
        schedule: Schedule::default(),
        layer: layer_name.to_string(),
        target: "zcu102".to_string(),
        tuner: "test".to_string(),
        trials: 10,
    }
}

/// Responses keyed by id, in arrival order per id.
fn responses_by_id(output: &str) -> Vec<(u64, Json)> {
    output
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = Json::parse(l).expect("response line parses");
            (j.get("id").and_then(Json::as_u64).unwrap_or(0), j)
        })
        .collect()
}

fn status_of(j: &Json) -> &str {
    j.get("status").and_then(Json::as_str).unwrap()
}

#[test]
fn schedule_db_round_trips_through_reopen() {
    let dir = fresh_dir("ml2tuner_serve_roundtrip");
    {
        let db = ScheduleDb::open(&dir).unwrap();
        assert!(db.is_empty());
        assert_eq!(
            db.promote(entry_for("gemm_256x256x128", 5000)).unwrap(),
            Promotion::Inserted
        );
        assert_eq!(
            db.promote(entry_for("dense_512x1024", 7000)).unwrap(),
            Promotion::Inserted
        );
        assert_eq!(db.len(), 2);
    }
    let db = ScheduleDb::open(&dir).unwrap();
    assert_eq!((db.len(), db.skipped()), (2, 0));
    let found = db
        .lookup(&entry_for("gemm_256x256x128", 0).key)
        .expect("reopened entry");
    assert_eq!(found.cycles, 5000);
    assert_eq!(found.version, 1);
    assert_eq!(found.schedule, Schedule::default());
    assert_eq!(found.tuner, "test");
    // a different space is a different key — never answered by this entry
    let ext_key = ScheduleKey {
        space: SpaceKind::Extended,
        ..entry_for("gemm_256x256x128", 0).key
    };
    assert!(db.lookup(&ext_key).is_none());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn promotion_is_versioned_and_better_only() {
    let dir = fresh_dir("ml2tuner_serve_promotion");
    let db = ScheduleDb::open(&dir).unwrap();
    let key = entry_for("gemm_256x256x128", 0).key;
    assert_eq!(
        db.promote(entry_for("gemm_256x256x128", 100)).unwrap(),
        Promotion::Inserted
    );
    // worse and equal candidates leave the store untouched
    assert_eq!(
        db.promote(entry_for("gemm_256x256x128", 120)).unwrap(),
        Promotion::Kept { best_cycles: 100 }
    );
    assert_eq!(
        db.promote(entry_for("gemm_256x256x128", 100)).unwrap(),
        Promotion::Kept { best_cycles: 100 }
    );
    assert_eq!(db.lookup(&key).unwrap().version, 1);
    // strictly better replaces and bumps the version
    assert_eq!(
        db.promote(entry_for("gemm_256x256x128", 80)).unwrap(),
        Promotion::Promoted { prev_cycles: 100 }
    );
    let stored = db.lookup(&key).unwrap();
    assert_eq!((stored.cycles, stored.version), (80, 2));
    drop(db);
    // the reopened store sees exactly the promoted state
    let db = ScheduleDb::open(&dir).unwrap();
    let stored = db.lookup(&key).unwrap();
    assert_eq!((stored.cycles, stored.version), (80, 2));
    assert_eq!(db.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_promotes_keep_the_minimum() {
    let dir = fresh_dir("ml2tuner_serve_concurrent");
    let db = Arc::new(ScheduleDb::open(&dir).unwrap());
    let key = entry_for("gemm_256x256x128", 0).key;
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let db = Arc::clone(&db);
            s.spawn(move || {
                for i in 0..20u64 {
                    // interleaved descending/ascending offers from every
                    // thread; global minimum is 301 (t=7, i=19)
                    let cycles = 1000 - t * 13 - i * 32;
                    db.promote(entry_for("gemm_256x256x128", cycles))
                        .unwrap();
                }
            });
        }
    });
    let stored = db.lookup(&key).unwrap();
    assert_eq!(stored.cycles, 1000 - 7 * 13 - 19 * 32);
    assert_eq!(db.len(), 1);
    drop(db);
    // one key → one entry file, and it reloads to the same minimum
    let files = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().path().extension().is_some_and(|x| x == "json")
        })
        .count();
    assert_eq!(files, 1);
    let db = ScheduleDb::open(&dir).unwrap();
    assert_eq!(db.lookup(&key).unwrap().cycles, 1000 - 7 * 13 - 19 * 32);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_answers_hit_miss_and_tunes_fallback() {
    let dir = fresh_dir("ml2tuner_serve_e2e");
    let db = ScheduleDb::open(&dir).unwrap();
    db.promote(entry_for("gemm_256x256x128", 123_456)).unwrap();
    let daemon = Daemon::new(ServeConfig::default(), Arc::new(db));
    let script = r#"{"op":"query","id":1,"network":"synth-gemm","layer":"gemm_256x256x128","target":"zcu102"}
{"op":"query","id":2,"network":"synth-gemm","layer":"gemm_4096x64x64","target":"zcu102"}
{"op":"query","id":3,"network":"synth-gemm","layer":"gemm_4096x64x64","target":"zcu102","tune_on_miss":true,"trials":40}
{"op":"stats","id":4}
{"op":"query","id":5,"network":"nope","layer":"x","target":"zcu102"}
{"op":"shutdown"}
"#;
    let out = SharedBuf::default();
    let exit = daemon.run(script.as_bytes(), out.clone()).unwrap();
    assert_eq!(exit, ServeExit::Shutdown);
    let responses = responses_by_id(&out.into_string());

    let hit = &responses.iter().find(|(id, _)| *id == 1).unwrap().1;
    assert_eq!(status_of(hit), "hit");
    assert_eq!(hit.get("cycles").and_then(Json::as_u64), Some(123_456));
    assert_eq!(hit.get("version").and_then(Json::as_u64), Some(1));
    assert!(hit.at(&["knobs", "TH"]).is_some());

    let miss = &responses.iter().find(|(id, _)| *id == 2).unwrap().1;
    assert_eq!(status_of(miss), "miss");

    // the fallback job answers twice: queued synchronously, tuned when
    // the worker finishes (run() joins its workers before returning)
    let fallback: Vec<&Json> = responses
        .iter()
        .filter(|(id, _)| *id == 3)
        .map(|(_, j)| j)
        .collect();
    assert_eq!(fallback.len(), 2);
    assert!(fallback.iter().any(|j| status_of(j) == "queued"));
    let tuned = fallback
        .iter()
        .find(|j| status_of(j) == "tuned")
        .expect("tuned response");
    assert_eq!(
        tuned.get("promotion").and_then(Json::as_str),
        Some("inserted")
    );
    assert_eq!(tuned.get("version").and_then(Json::as_u64), Some(1));
    let tuned_cycles = tuned.get("cycles").and_then(Json::as_u64).unwrap();
    assert!(tuned_cycles > 0);

    let stats = &responses.iter().find(|(id, _)| *id == 4).unwrap().1;
    assert_eq!(status_of(stats), "stats");
    assert_eq!(
        stats.get("schedule_db_hits").and_then(Json::as_u64),
        Some(1)
    );
    assert_eq!(
        stats.get("schedule_db_misses").and_then(Json::as_u64),
        Some(2)
    );

    let err = &responses.iter().find(|(id, _)| *id == 5).unwrap().1;
    assert_eq!(status_of(err), "error");

    // the tuned result is now served from the store
    let key = ScheduleKey::for_layer_on(
        &gemm_layer("gemm_4096x64x64"),
        SpaceKind::Paper,
        &VtaConfig::zcu102(),
    );
    let stored = daemon.db().lookup(&key).expect("promoted entry");
    assert_eq!(stored.cycles, tuned_cycles);
    assert_eq!(daemon.recorder().get(Counter::ServeJobsTuned), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hits_answer_without_compiling_or_profiling() {
    let dir = fresh_dir("ml2tuner_serve_hitpath");
    let db = ScheduleDb::open(&dir).unwrap();
    let net = workloads::network("synth-gemm").unwrap();
    for l in net.layers {
        db.promote(entry_for(l.name, 1 + l.macs())).unwrap();
    }
    let daemon = Daemon::new(ServeConfig::default(), Arc::new(db));
    let mut script = String::new();
    for i in 0..20 {
        let l = net.layers[i % net.layers.len()];
        script.push_str(&format!(
            "{{\"op\":\"query\",\"id\":{i},\"network\":\"synth-gemm\",\
             \"layer\":\"{}\",\"target\":\"zcu102\"}}\n",
            l.name
        ));
    }
    let out = SharedBuf::default();
    let exit = daemon.run(script.as_bytes(), out.clone()).unwrap();
    assert_eq!(exit, ServeExit::Eof);
    let responses = responses_by_id(&out.into_string());
    assert_eq!(responses.len(), 20);
    assert!(responses.iter().all(|(_, j)| status_of(j) == "hit"));
    // the acceptance pin: a db hit answers with zero compilation and
    // zero profiling — the whole point of serving from the store
    let rec = daemon.recorder();
    assert_eq!(rec.get(Counter::ScheduleDbHit), 20);
    assert_eq!(rec.get(Counter::ScheduleDbMiss), 0);
    assert_eq!(rec.get(Counter::TrialsProfiled), 0);
    assert_eq!(rec.get(Counter::CompileCacheHit), 0);
    assert_eq!(rec.get(Counter::CompileCacheMiss), 0);
    assert_eq!(rec.get(Counter::ServeJobsTuned), 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Run one fallback-tuning script against a fresh store with `workers`
/// worker threads; return the resulting store entries.
fn tuned_entries(dir_name: &str, workers: usize) -> Vec<ScheduleEntry> {
    let dir = fresh_dir(dir_name);
    let db = ScheduleDb::open(&dir).unwrap();
    let cfg = ServeConfig { workers, ..ServeConfig::default() };
    let daemon = Daemon::new(cfg, Arc::new(db));
    let script = r#"{"op":"query","id":1,"network":"synth-gemm","layer":"gemm_1024x128x256","target":"zcu102","tune_on_miss":true,"trials":25}
{"op":"query","id":2,"network":"synth-gemm","layer":"dense_512x1024","target":"zcu102","tune_on_miss":true,"trials":25}
{"op":"shutdown"}
"#;
    let out = SharedBuf::default();
    daemon.run(script.as_bytes(), out).unwrap();
    let entries = daemon.db().entries();
    std::fs::remove_dir_all(&dir).ok();
    entries
}

#[test]
fn tuned_schedules_are_identical_for_any_worker_count() {
    // job seeds derive from the query key, warm starts only from the
    // startup transfer store, and the shared compile cache stores pure
    // functions — so worker count and interleaving must not change what
    // gets stored
    let serial = tuned_entries("ml2tuner_serve_det_w1", 1);
    let parallel = tuned_entries("ml2tuner_serve_det_w4", 4);
    assert_eq!(serial, parallel);
    assert!(!serial.is_empty());
}
