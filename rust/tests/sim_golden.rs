//! Integration: the VTA simulator must agree bit-exactly with the
//! AOT-compiled JAX/Pallas golden model (via PJRT) on every `check`-valid
//! schedule, and the check() verdict must predict numeric behaviour.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works in a fresh checkout).

use ml2tuner::compiler::{schedule, Compiler};
use ml2tuner::runtime::{golden, Runtime};
use ml2tuner::util::rng::Rng;
use ml2tuner::vta::{config::VtaConfig, functional, layout, Simulator};
use ml2tuner::workloads::{resnet18, synth};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            None
        }
    }
}

fn numeric_output(
    sim: &Simulator,
    layer: &resnet18::ConvLayer,
    prog: &ml2tuner::vta::isa::Program,
    seed: u64,
) -> Result<Vec<i8>, ml2tuner::vta::Fault> {
    let x = synth::input_data(layer, seed);
    let w = synth::weight_data(layer, seed);
    let dram = functional::Dram {
        inp: layout::pack_input(&sim.cfg, &x, layer.h, layer.w, layer.c),
        wgt: layout::pack_weights(&sim.cfg, &w, layer.kh, layer.kw,
                                  layer.c, layer.kc),
        out_vecs: prog.dram_out_vecs,
    };
    sim.execute(prog, &dram)
}

#[test]
fn valid_schedules_are_bit_exact_against_golden() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    let mut rng = Rng::new(0xE2E);
    let mut checked = 0;
    for layer in resnet18::LAYERS.iter().step_by(2) {
        rt.check_layer(layer).unwrap();
        let space = schedule::candidates(layer);
        let mut found = 0;
        let mut attempts = 0;
        while found < 3 && attempts < 200 {
            attempts += 1;
            let s = space.schedule(rng.below(space.len()));
            let compiled = compiler.compile(layer, &s);
            if !sim.check(&compiled.program).is_valid() {
                continue;
            }
            found += 1;
            checked += 1;
            let out = numeric_output(&sim, layer, &compiled.program,
                                     7 + found)
                .expect("check-valid program must not crash numerically");
            let gold =
                golden::golden_output(&mut rt, layer, 7 + found).unwrap();
            assert_eq!(out, gold, "{} {s}: output differs from golden",
                       layer.name);
        }
        assert!(found > 0, "{}: no valid schedule found", layer.name);
    }
    assert!(checked >= 9);
}

#[test]
fn golden_matches_pure_rust_reference() {
    // triangulation: PJRT golden (JAX/Pallas int8 conv) == rust oracle
    let Some(mut rt) = runtime_or_skip() else { return };
    for name in ["conv2", "conv5"] {
        let layer = resnet18::layer(name).unwrap();
        let gold = golden::golden_output(&mut rt, &layer, 3).unwrap();
        let x = synth::input_data(&layer, 3);
        let w = synth::weight_data(&layer, 3);
        let reference = golden::reference_conv(&layer, &x, &w,
                                               rt.shift());
        assert_eq!(gold, reference, "{name}: PJRT vs rust oracle");
    }
}

#[test]
fn corrupt_verdicts_usually_produce_wrong_output() {
    // The fast-path Corruption verdict claims "runs but output differs".
    // Statistically confirm: most corruption-flagged configs that execute
    // without crashing produce non-golden output.
    let Some(mut rt) = runtime_or_skip() else { return };
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    let layer = resnet18::layer("conv4").unwrap();
    let space = schedule::candidates(&layer);
    let mut rng = Rng::new(77);
    let mut corrupt_checked = 0;
    let mut wrong = 0;
    let mut attempts = 0;
    while corrupt_checked < 6 && attempts < 3000 {
        attempts += 1;
        let s = space.schedule(rng.below(space.len()));
        let compiled = compiler.compile(&layer, &s);
        match sim.check(&compiled.program) {
            ml2tuner::vta::Verdict::Invalid {
                fault: ml2tuner::vta::Fault::Corruption(_), ..
            } => {}
            _ => continue,
        }
        let Ok(out) = numeric_output(&sim, &layer, &compiled.program, 5)
        else {
            continue; // corruption may coincide with a crash
        };
        corrupt_checked += 1;
        let gold = golden::golden_output(&mut rt, &layer, 5).unwrap();
        if out != gold {
            wrong += 1;
        }
    }
    assert!(corrupt_checked >= 3, "not enough corrupt configs found");
    assert!(
        wrong * 2 > corrupt_checked,
        "only {wrong}/{corrupt_checked} corrupt configs mismatched"
    );
}

#[test]
fn crash_verdicts_crash_numerically() {
    let cfg = VtaConfig::zcu102();
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg);
    let layer = resnet18::layer("conv1").unwrap();
    let space = schedule::candidates(&layer);
    let mut rng = Rng::new(13);
    let mut found = 0;
    let mut attempts = 0;
    while found < 5 && attempts < 1000 {
        attempts += 1;
        let s = space.schedule(rng.below(space.len()));
        let compiled = compiler.compile(&layer, &s);
        match sim.check(&compiled.program) {
            ml2tuner::vta::Verdict::Invalid { fault, .. }
                if fault.is_crash() => {}
            _ => continue,
        }
        found += 1;
        let res = numeric_output(&sim, &layer, &compiled.program, 1);
        assert!(res.is_err(),
                "crash-verdict config executed cleanly: {s}");
    }
    assert!(found >= 5);
}
