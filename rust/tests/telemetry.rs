//! ISSUE-6 guarantees for the telemetry subsystem.
//!
//! 1. **Non-interference**: a run with `--metrics-out` attached produces
//!    the byte-identical tuning trace of a sink-less run — on both knob
//!    spaces, for the standalone tuner and the network scheduler.
//!    Telemetry observes; it never touches an rng stream or reorders
//!    work.
//! 2. **Schema**: every emitted line passes the strict `report`
//!    validator (the same code CI runs as its schema check), events
//!    arrive in deterministic order (`run_start`, rounds, `run_end`),
//!    and malformed lines are rejected with a file:line context.
//! 3. **Aggregation**: `report::aggregate` folds a real event stream
//!    into totals consistent with the trace that produced it, and folds
//!    a hand-written fixture into exactly the expected numbers.

use std::io::Write;
use std::sync::{Arc, Mutex};

use ml2tuner::compiler::schedule::SpaceKind;
use ml2tuner::engine::{Engine, NetworkConfig, NetworkTuner, TunerKind};
use ml2tuner::obs::report::{aggregate, validate_line};
use ml2tuner::obs::{Counter, EventSink};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::json::Json;
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::resnet18;

/// `Write` into a shared buffer, so the test can hand an owned sink to
/// the recorder and still read everything it wrote.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    fn into_string(self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// One standalone ml2tuner run on conv5; returns the trace fingerprint
/// and (when `sink`) the emitted JSONL.
fn ml2_run(kind: SpaceKind, sink: bool) -> (Vec<(usize, Option<u64>)>, String) {
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        resnet18::layer("conv5").unwrap(),
        kind,
    );
    let engine = Engine::with_jobs(2);
    let buf = SharedBuf::default();
    if sink {
        engine
            .recorder()
            .attach_sink(EventSink::from_writer(Box::new(buf.clone())));
        engine.recorder().emit_run_start(
            "tune",
            vec![
                ("layer", Json::Str("conv5".to_string())),
                ("seed", Json::Num(3.0)),
            ],
        );
    }
    let cfg = TunerConfig { seed: 3, max_trials: 60, ..Default::default() };
    let trace = Ml2Tuner::new(cfg).tune_with(&env, &engine);
    engine.recorder().emit_run_end();
    let fp = trace
        .trials
        .iter()
        .map(|t| (t.space_index, t.outcome.cycles()))
        .collect();
    (fp, buf.into_string())
}

#[test]
fn metrics_sink_does_not_perturb_traces_on_either_space() {
    for kind in [SpaceKind::Paper, SpaceKind::Extended] {
        let (bare, _) = ml2_run(kind, false);
        let (observed, events) = ml2_run(kind, true);
        assert_eq!(bare, observed,
                   "telemetry changed the {kind:?} trace");
        assert!(!events.is_empty());
    }
}

#[test]
fn emitted_stream_is_schema_valid_and_deterministically_ordered() {
    let (trace, events) = ml2_run(SpaceKind::Paper, true);
    let lines: Vec<&str> = events.lines().collect();
    assert!(lines.len() >= 3, "expected start + rounds + end");
    let kinds: Vec<String> = lines
        .iter()
        .map(|l| {
            let j = validate_line(l).expect("schema-valid line");
            assert_eq!(j.get("schema").unwrap().as_i64(), Some(1));
            j.get("event").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    assert_eq!(kinds.first().map(String::as_str), Some("run_start"));
    assert_eq!(kinds.last().map(String::as_str), Some("run_end"));
    let rounds = kinds.iter().filter(|k| *k == "round").count();
    assert!(rounds >= 2, "one event per tuning round, got {rounds}");
    assert_eq!(kinds.len(), rounds + 2, "only start/round/end events");
    // round numbers strictly increase: emission is coordinator-ordered
    let mut last = 0i64;
    let mut trials_total = 0i64;
    for l in &lines {
        let j = Json::parse(l).unwrap();
        if j.get("event").unwrap().as_str() == Some("round") {
            let r = j.get("round").unwrap().as_i64().unwrap();
            assert!(r > last, "round {r} after {last}");
            last = r;
            trials_total += j.get("trials_new").unwrap().as_i64().unwrap();
        }
    }
    assert_eq!(trials_total as usize, trace.len(),
               "round events must account for every profiled trial");
}

#[test]
fn run_counters_match_the_trace() {
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        resnet18::layer("conv5").unwrap(),
        SpaceKind::Paper,
    );
    let engine = Engine::with_jobs(2);
    let cfg = TunerConfig { seed: 5, max_trials: 40, ..Default::default() };
    let trace = Ml2Tuner::new(cfg).tune_with(&env, &engine);
    let rec = engine.recorder();
    assert_eq!(rec.get(Counter::TrialsProfiled), trace.len() as u64);
    let valid = trace.trials.iter().filter(|t| t.outcome.is_valid()).count();
    assert_eq!(rec.get(Counter::TrialsValid), valid as u64);
    assert_eq!(
        rec.get(Counter::TrialsCrash) + rec.get(Counter::TrialsWrongOutput),
        (trace.len() - valid) as u64
    );
    // the scoring sweep ran and the cache saw the A-stage compiles
    assert!(rec.get(Counter::SweepCandidates) > 0);
    let stats = engine.cache().stats();
    assert_eq!(stats.hits, rec.get(Counter::CompileCacheHit));
    assert_eq!(stats.misses, rec.get(Counter::CompileCacheMiss));
}

fn network_fingerprint(sink: bool) -> Vec<(usize, Option<u64>)> {
    let layers = vec![
        resnet18::layer("conv4").unwrap(),
        resnet18::layer("conv5").unwrap(),
    ];
    let engine = Engine::with_jobs(2);
    if sink {
        engine
            .recorder()
            .attach_sink(EventSink::from_writer(Box::new(std::io::sink())));
        engine.recorder().emit_run_start("tune-net", vec![]);
    }
    let cfg = NetworkConfig {
        vta: VtaConfig::zcu102(),
        tuner: TunerKind::Ml2,
        total_trials: 60,
        round_trials: 10,
        base: TunerConfig { seed: 7, ..Default::default() },
        ..Default::default()
    };
    let outcome = NetworkTuner::new(cfg).tune(&engine, &layers);
    engine.recorder().emit_run_end();
    outcome
        .traces
        .iter()
        .flat_map(|t| {
            t.trials.iter().map(|r| (r.space_index, r.outcome.cycles()))
        })
        .collect()
}

#[test]
fn network_scheduler_traces_are_sink_invariant() {
    assert_eq!(network_fingerprint(false), network_fingerprint(true));
}

#[test]
fn aggregate_folds_a_real_run_consistently() {
    let (trace, events) = ml2_run(SpaceKind::Paper, true);
    let dir = std::env::temp_dir().join("ml2tuner_telemetry_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("events.jsonl");
    std::fs::write(&path, &events).unwrap();
    let report = aggregate(&[&path]).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(report.files, 1);
    assert_eq!(report.runs, 1);
    assert!(report.rounds >= 2);
    let agg = report.targets.get("zcu102").expect("zcu102 rollup");
    assert_eq!(agg.trials as usize, trace.len());
    assert_eq!(
        agg.valid as usize,
        trace.iter().filter(|(_, cycles)| cycles.is_some()).count()
    );
    // run_end lifetime totals are authoritative for the cache line
    assert!(report.cache_from_run_end);
    assert!(report.cache_lookups() > 0);
    let rendered = report.render();
    for needle in [
        "per-stage time breakdown",
        "compile cache",
        "model quality",
        "zcu102",
    ] {
        assert!(rendered.contains(needle), "missing {needle:?}");
    }
}

#[test]
fn aggregate_computes_model_quality_from_a_fixture() {
    let mk_round = |round: u64, layer: &str, with_v: bool| {
        let mut o = Json::obj();
        o.set("schema", 1)
            .set("event", "round")
            .set("target", "zcu102")
            .set("layer", layer)
            .set("tuner", "ml2tuner")
            .set("space", "paper")
            .set("round", round)
            .set("trials_new", 10)
            .set("trials_total", 10 * round)
            .set("valid_new", 8)
            .set("crash_new", 2)
            .set("wrong_new", 0)
            .set("select_ns", 400)
            .set("train_ns", 100)
            .set("sweep_ns", 150)
            .set("sweep_chunks", 4)
            .set("compile_ns", 50)
            .set("profile_ns", 600)
            .set("cache_hits", 5)
            .set("cache_misses", 15)
            .set("best_cycles", 9000)
            .set("trials_to_best", 4 + round);
        if with_v {
            o.set("vetoes", 12)
                .set("v_tp", 6)
                .set("v_fp", 2)
                .set("v_tn", 1)
                .set("v_fn", 1)
                .set("v_margin", 0.25);
        }
        o.to_string()
    };
    let mut start = Json::obj();
    start.set("schema", 1).set("event", "run_start").set("cmd", "tune");
    let fixture = format!(
        "{}\n{}\n{}\n",
        start,
        mk_round(1, "conv1", false),
        mk_round(2, "conv1", true),
    );
    let dir = std::env::temp_dir().join("ml2tuner_telemetry_fixture");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fixture.jsonl");
    std::fs::write(&path, &fixture).unwrap();
    let report = aggregate(&[&path]).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!((report.runs, report.rounds), (1, 2));
    assert_eq!(report.select_ns, 800);
    assert_eq!(report.train_ns, 200);
    assert_eq!(report.total_ns(), 800 + 1200);
    // select-other = select − train − sweep − compile
    assert_eq!(report.select_other_ns(), 800 - 200 - 300 - 100);
    // no run_end in the fixture: cache totals are summed round deltas
    assert!(!report.cache_from_run_end);
    assert_eq!((report.cache_hits, report.cache_misses), (10, 30));
    let agg = &report.targets["zcu102"];
    assert_eq!(agg.v_rounds, 1);
    assert_eq!(agg.precision(), Some(6.0 / 8.0));
    assert_eq!(agg.recall(), Some(6.0 / 7.0));
    assert_eq!(agg.npv(), 0.5);
    assert_eq!(agg.invalid_avoided(), 6.0);
    // last round's samples-to-best wins
    assert_eq!(agg.per_layer_best["conv1"], (Some(6), Some(9000)));
    assert_eq!(agg.mean_trials_to_best(), Some(6.0));
}

#[test]
fn malformed_events_are_rejected_with_line_context() {
    assert!(validate_line("not json").is_err());
    assert!(validate_line("{\"event\": \"round\"}").is_err(),
            "missing schema field must fail");
    assert!(
        validate_line("{\"schema\": 99, \"event\": \"run_start\", \
                       \"cmd\": \"tune\"}")
        .is_err(),
        "unknown schema version must fail"
    );
    let dir = std::env::temp_dir().join("ml2tuner_telemetry_bad");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.jsonl");
    std::fs::write(
        &path,
        "{\"schema\": 1, \"event\": \"run_start\", \"cmd\": \"tune\"}\n\
         {\"schema\": 1, \"event\": \"nonsense\"}\n",
    )
    .unwrap();
    let err = aggregate(&[&path]).unwrap_err();
    std::fs::remove_file(&path).ok();
    let msg = format!("{err:#}");
    assert!(msg.contains(":2"), "error should carry file:line: {msg}");
}
