//! Golden guarantees for the hardware target registry.
//!
//! A tuning trace is a pure function of (hardware config, space
//! enumeration, compiler output, RNG streams, model code). `--space
//! paper` enumeration is pinned by `tests/space_golden.rs`; this file
//! pins the *hardware* axis introduced with the registry:
//!
//! 1. the four registered targets' capacity parameters are frozen as
//!    literals (silent drift would silently change every trace);
//! 2. per-target engine traces (zcu104, edge-small — alongside the
//!    zcu102 trace space_golden exercises) must match, trial for trial,
//!    an independent *uncached, sequential* reference profile of the
//!    same configurations — the strongest guard against the new failure
//!    mode this PR introduces: compile-cache aliasing across targets;
//! 3. traces are deterministic and worker-count invariant on non-default
//!    targets;
//! 4. the static validity boundary moves monotonically with capacity
//!    (provable: the tile analysis is capacity-independent, the check
//!    compares it against per-target capacities).

use ml2tuner::compiler::schedule::{Schedule, SpaceKind};
use ml2tuner::compiler::Compiler;
use ml2tuner::engine::Engine;
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::report::TuningTrace;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::vta::targets;
use ml2tuner::workloads::resnet18;

/// Frozen registry parameters: (name, log_uop, log_inp, log_wgt,
/// log_acc buffer sizes, dma_bytes_per_cycle, dma_latency). Do not
/// "fix" these to match a changed config — changing a registered
/// target's capacities is a trace-breaking event and needs a new name.
const FROZEN: [(&str, u32, u32, u32, u32, u64, u64); 4] = [
    ("zcu102", 16, 16, 19, 18, 16, 144),
    ("zcu104", 15, 15, 18, 17, 16, 144),
    ("edge-small", 14, 14, 17, 16, 8, 192),
    ("hiband", 17, 16, 19, 18, 32, 96),
];

#[test]
fn registry_parameters_are_frozen() {
    assert_eq!(targets::TARGET_NAMES.len(), FROZEN.len());
    for (name, uop, inp, wgt, acc, dma_bpc, dma_lat) in FROZEN {
        let cfg = targets::target(name)
            .unwrap_or_else(|| panic!("'{name}' must be registered"));
        assert_eq!(cfg.target, name);
        assert_eq!(cfg.log_uop_buff_size, uop, "{name} uop");
        assert_eq!(cfg.log_inp_buff_size, inp, "{name} inp");
        assert_eq!(cfg.log_wgt_buff_size, wgt, "{name} wgt");
        assert_eq!(cfg.log_acc_buff_size, acc, "{name} acc");
        assert_eq!(cfg.dma_bytes_per_cycle, dma_bpc, "{name} dma width");
        assert_eq!(cfg.dma_latency, dma_lat, "{name} dma latency");
        // geometry every target shares (paper Table 1)
        assert_eq!((cfg.log_batch, cfg.log_block), (0, 4), "{name}");
        assert_eq!(cfg.shift, 8, "{name}");
    }
    // derived golden capacities of the two non-default tuning targets
    let z104 = targets::target("zcu104").unwrap();
    assert_eq!(
        (z104.inp_capacity(), z104.wgt_capacity(), z104.acc_capacity(),
         z104.uop_capacity()),
        (2048, 1024, 2048, 8192)
    );
    let edge = targets::target("edge-small").unwrap();
    assert_eq!(
        (edge.inp_capacity(), edge.wgt_capacity(), edge.acc_capacity(),
         edge.uop_capacity()),
        (1024, 512, 1024, 4096)
    );
}

#[test]
fn default_config_is_still_the_paper_zcu102() {
    // `VtaConfig::default()` feeds every pre-registry code path; it must
    // keep producing the paper's Table-1 machine byte-for-byte
    assert_eq!(VtaConfig::default(), VtaConfig::zcu102());
    assert_eq!(VtaConfig::default(), targets::target("zcu102").unwrap());
}

fn ml2_trace(hw: &VtaConfig, trials: usize, seed: u64,
             engine: &Engine) -> (TuningEnv, TuningTrace) {
    let layer = resnet18::layer("conv5").unwrap();
    let env = TuningEnv::new(hw.clone(), layer);
    let cfg = TunerConfig { max_trials: trials, seed,
                            ..TunerConfig::default() };
    let trace = Ml2Tuner::new(cfg).tune_with(&env, engine);
    (env, trace)
}

#[test]
fn per_target_traces_match_uncached_sequential_reference() {
    // 40 trials crosses min_train: the model-guided rounds (incl. the
    // cache-heavy A-stage) are exercised, not just the random warmup
    for name in ["zcu104", "edge-small"] {
        let hw = targets::target(name).unwrap();
        let engine = Engine::single_threaded();
        let (env, trace) = ml2_trace(&hw, 40, 7, &engine);
        assert_eq!(trace.len(), 40, "{name}");
        for t in &trace.trials {
            // the uncached, engine-free reference path
            let r = env.profile(t.space_index);
            assert_eq!(t.schedule, r.schedule, "{name}");
            assert_eq!(t.outcome, r.outcome,
                       "{name}: engine outcome diverged from the \
                        uncached reference (cross-target cache \
                        aliasing?)");
            assert_eq!(t.visible, r.visible, "{name}");
            assert_eq!(t.hidden, r.hidden, "{name}");
        }
        // determinism: the same run replays byte-identically
        let (_, again) = ml2_trace(&hw, 40, 7, &Engine::single_threaded());
        assert_eq!(format!("{:?}", trace.trials),
                   format!("{:?}", again.trials), "{name}");
    }
}

#[test]
fn jobs_invariance_on_non_default_target() {
    let hw = targets::target("zcu104").unwrap();
    let (_, t1) = ml2_trace(&hw, 40, 11, &Engine::with_jobs(1));
    let (_, t4) = ml2_trace(&hw, 40, 11, &Engine::with_jobs(4));
    assert_eq!(format!("{:?}", t1.trials), format!("{:?}", t4.trials),
               "zcu104 traces must be worker-count invariant");
}

#[test]
fn shared_engine_multi_target_runs_equal_isolated_runs() {
    // the fleet shares one compile cache across targets; a shared-cache
    // run must replay the fresh-cache run of every target exactly
    let z102 = targets::target("zcu102").unwrap();
    let z104 = targets::target("zcu104").unwrap();
    let shared = Engine::single_threaded();
    let (_, a102) = ml2_trace(&z102, 30, 3, &shared);
    let (_, a104) = ml2_trace(&z104, 30, 3, &shared);
    let (_, b102) = ml2_trace(&z102, 30, 3, &Engine::single_threaded());
    let (_, b104) = ml2_trace(&z104, 30, 3, &Engine::single_threaded());
    assert_eq!(format!("{:?}", a102.trials), format!("{:?}", b102.trials),
               "zcu102 trace changed when sharing a cache with zcu104");
    assert_eq!(format!("{:?}", a104.trials), format!("{:?}", b104.trials),
               "zcu104 trace changed when sharing a cache with zcu102");
}

#[test]
fn static_validity_boundary_moves_monotonically_with_capacity() {
    let conv1 = resnet18::layer("conv1").unwrap();
    // hand-computed flip: tile (28,28,16,64,1) on conv1 has an input
    // halo of 30·30·(64/16) = 3600 vectors — ≤ 4096 (zcu102-plausible)
    // but > 1024 (edge-small-Hopeless); its ACC tile 28·28·1 = 784 fits
    // everywhere
    let flip = Schedule { tile_h: 28, tile_w: 28, tile_oc: 16,
                          tile_ic: 64, n_vthreads: 1,
                          ..Default::default() };
    let check = |hw: &VtaConfig, s: &Schedule| {
        Compiler::new(hw.clone()).static_check(&conv1, s).is_plausible()
    };
    let z102 = targets::target("zcu102").unwrap();
    let z104 = targets::target("zcu104").unwrap();
    let edge = targets::target("edge-small").unwrap();
    assert!(check(&z102, &flip), "plausible on the big-buffer target");
    assert!(!check(&edge, &flip), "Hopeless once buffers shrink 4x");

    // sweep: hopelessness is monotone in capacity (the tile analysis is
    // capacity-independent; only the thresholds move)
    let space = ml2tuner::compiler::schedule::space_for(
        &conv1, SpaceKind::Paper,
    );
    let mut counts = [0usize; 3];
    for i in (0..space.len()).step_by(131) {
        let s = space.schedule(i);
        for (k, hw) in [&z102, &z104, &edge].into_iter().enumerate() {
            if !check(hw, &s) {
                counts[k] += 1;
            }
        }
        // per-config monotonicity: anything Hopeless on a larger
        // target stays Hopeless on every smaller one
        if !check(&z102, &s) {
            assert!(!check(&z104, &s),
                    "zcu102-Hopeless config plausible on zcu104: {s}");
        }
        if !check(&z104, &s) {
            assert!(!check(&edge, &s),
                    "zcu104-Hopeless config plausible on edge-small: {s}");
        }
    }
    assert!(counts[0] <= counts[1] && counts[1] <= counts[2],
            "Hopeless counts must grow as capacity shrinks: {counts:?}");
    // strict movement is already proven by the hand-computed flip
    // config above; the sweep's job is the monotonicity residue
}
