//! Integration: tuning-log persistence and the transfer warm-start path.
//!
//! * round-trip — `TrialRecord` → JSON tuning log on disk → [`TransferDb`]
//!   directory load preserves schedules, outcomes, features, and shape;
//! * warm-start — a `TransferDb` built from one network's logs
//!   warm-starts tuning on another layer, end to end through both the
//!   standalone tuner and the network scheduler.

use ml2tuner::compiler::features;
use ml2tuner::compiler::schedule::{Schedule, SpaceKind};
use ml2tuner::engine::{Engine, NetworkConfig, NetworkTuner, TunerKind};
use ml2tuner::tuner::database::{
    Database, Fidelity, LayerMeta, Outcome, TransferDb, TrialRecord,
};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::{self, ConvLayer};

fn rec(i: usize, outcome: Outcome) -> TrialRecord {
    let schedule = Schedule { tile_h: 1 + i, tile_w: 2, tile_oc: 16,
                              tile_ic: 16, n_vthreads: 1,
                              ..Default::default() };
    TrialRecord {
        space_index: i,
        schedule,
        visible: SpaceKind::Paper.visible_features(&schedule),
        hidden: vec![0.5; features::hidden_len(SpaceKind::Paper)],
        outcome,
        fidelity: Fidelity::Full,
    }
}

#[test]
fn tuning_logs_round_trip_through_a_transfer_db_directory() {
    let dir = std::env::temp_dir().join("ml2tuner_transfer_roundtrip");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let pw4 = workloads::network("mobilenet").unwrap().layer("pw4").unwrap();
    let conv1 = workloads::network("resnet18").unwrap().layer("conv1")
        .unwrap();
    let mut a = Database::for_layer(&pw4);
    a.push(rec(0, Outcome::Valid { cycles: 123_456 }));
    a.push(rec(3, Outcome::Crash));
    a.push(rec(7, Outcome::WrongOutput));
    a.save(dir.join("pw4.json")).unwrap();
    let mut b = Database::for_layer(&conv1);
    b.push(rec(1, Outcome::Valid { cycles: 999 }));
    b.save(dir.join("conv1.json")).unwrap();
    // an unparseable .json and a non-json file must both be tolerated
    std::fs::write(dir.join("zz_bogus.json"), "{not json").unwrap();
    std::fs::write(dir.join("notes.txt"), "not a log").unwrap();

    let store = TransferDb::load_dir(&dir).unwrap();
    assert_eq!(store.n_layers(), 2);
    assert_eq!(store.total_records(), 4);
    assert_eq!(store.skipped, 1, "only the bogus .json is skipped");

    let back = store.sources.iter().find(|d| d.layer == "pw4").unwrap();
    assert_eq!(back.meta, Some(LayerMeta::of(&pw4)));
    assert_eq!(back.len(), 3);
    for (orig, got) in a.records.iter().zip(&back.records) {
        assert_eq!(orig.space_index, got.space_index);
        assert_eq!(orig.schedule, got.schedule);
        assert_eq!(orig.outcome, got.outcome);
        assert_eq!(orig.hidden, got.hidden);
        assert_eq!(orig.visible, got.visible);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Profile a spread of a layer's space into a shape-stamped log.
fn profiled_log(layer: &ConvLayer, n: usize) -> Database {
    let env = TuningEnv::new(VtaConfig::zcu102(), *layer);
    let engine = Engine::default();
    let stride = (env.space.len() / n).max(1);
    let batch: Vec<usize> = (0..n).map(|i| i * stride).collect();
    let mut db = Database::for_layer(layer);
    for r in engine.profile_batch(&env, &batch) {
        db.push(r);
    }
    db
}

#[test]
fn warm_start_flows_through_the_network_scheduler() {
    let net = workloads::network("mobilenet").unwrap();
    let pw5 = net.layer("pw5").unwrap();
    let pw4 = net.layer("pw4").unwrap();
    let mut store = TransferDb::new();
    store.add(profiled_log(&pw5, 80));
    assert!(store
        .warm_start_for(&pw4, SpaceKind::Paper, &VtaConfig::zcu102(), 200)
        .is_some(),
            "pw5 must be a transfer source for pw4");
    let cfg = NetworkConfig {
        tuner: TunerKind::Ml2,
        total_trials: 40,
        round_trials: 10,
        base: TunerConfig { seed: 5, ..TunerConfig::default() },
        transfer: Some(store),
        transfer_cap: 200,
        ..NetworkConfig::default()
    };
    let out = NetworkTuner::new(cfg).tune(&Engine::with_jobs(2),
                                          &[pw4]);
    assert_eq!(out.report.total_trials, 40, "budget fully spent");
    assert_eq!(out.databases.len(), 1);
    assert_eq!(out.databases[0].len(), 40,
               "transferred records never enter the persisted log");
    assert!(out.databases[0].meta.is_some(),
            "persisted logs are shape-stamped");
}

#[test]
fn warm_started_tuner_is_jobs_invariant() {
    let net = workloads::network("mobilenet").unwrap();
    let pw5 = net.layer("pw5").unwrap();
    let pw4 = net.layer("pw4").unwrap();
    let mut store = TransferDb::new();
    store.add(profiled_log(&pw4, 60));
    let warm = store
        .warm_start_for(&pw5, SpaceKind::Paper, &VtaConfig::zcu102(), 100)
        .unwrap();
    let env = TuningEnv::new(VtaConfig::zcu102(), pw5);
    let cfg = TunerConfig { max_trials: 30, seed: 11,
                            ..TunerConfig::default() };
    let t1 = Ml2Tuner::new(cfg.clone())
        .with_warm_start(warm.clone())
        .tune_with(&env, &Engine::with_jobs(1));
    let t4 = Ml2Tuner::new(cfg)
        .with_warm_start(warm)
        .tune_with(&env, &Engine::with_jobs(4));
    assert_eq!(t1.len(), 30);
    assert_eq!(format!("{:?}", t1.trials), format!("{:?}", t4.trials));
}
