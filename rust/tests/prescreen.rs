//! Multi-fidelity prescreen guarantees (ISSUE 8).
//!
//! 1. disabled prescreen values (0 and 1) share one code path: traces
//!    are byte-identical across both spaces and all four registered
//!    targets — together with `tests/space_golden.rs` /
//!    `tests/target_golden.rs` (which pin the default config, now
//!    carrying `prescreen_factor: 0`) this freezes cold traces against
//!    the pre-multi-fidelity seed;
//! 2. with the prescreen on, traces are deterministic and worker-count
//!    invariant (tier-0 ranking is batched over the `--jobs` pool with
//!    an ordered merge);
//! 3. tier-0 estimates are consistent with the static capacity check
//!    (Hopeless ⟺ statically impossible), so a statically-Hopeless
//!    config can never out-rank a finite estimate — and a prescreened
//!    run never spends full profiling on one;
//! 4. on a pinned deterministic sample, finite tier-0 estimates
//!    rank-concordant with full three-timeline timing well above
//!    chance (the estimator's job is ordering, not cycle accuracy).

use ml2tuner::compiler::schedule::SpaceKind;
use ml2tuner::compiler::Compiler;
use ml2tuner::engine::Engine;
use ml2tuner::tuner::database::Outcome;
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::report::TuningTrace;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::coarse::{self, CoarseEstimate};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::vta::targets;
use ml2tuner::workloads::resnet18;

fn trace_with(
    env: &TuningEnv,
    trials: usize,
    seed: u64,
    factor: usize,
    engine: &Engine,
) -> TuningTrace {
    let cfg = TunerConfig {
        max_trials: trials,
        seed,
        prescreen_factor: factor,
        ..TunerConfig::default()
    };
    Ml2Tuner::new(cfg).tune_with(env, engine)
}

#[test]
fn disabled_prescreen_values_share_one_code_path_everywhere() {
    let layer = resnet18::layer("conv5").unwrap();
    for name in targets::TARGET_NAMES {
        let hw = targets::target(name).unwrap();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let env = TuningEnv::with_space(hw.clone(), layer, kind);
            let t0 = trace_with(&env, 24, 9, 0,
                                &Engine::single_threaded());
            let t1 = trace_with(&env, 24, 9, 1,
                                &Engine::single_threaded());
            assert_eq!(
                format!("{:?}", t0.trials),
                format!("{:?}", t1.trials),
                "{name}/{}: factor 0 and 1 must both be the unmodified \
                 single-fidelity path",
                kind.name()
            );
        }
    }
}

#[test]
fn prescreened_traces_are_jobs_invariant_and_deterministic() {
    let layer = resnet18::layer("conv5").unwrap();
    let env = TuningEnv::with_space(
        VtaConfig::zcu102(),
        layer,
        SpaceKind::Extended,
    );
    let t1 = trace_with(&env, 40, 5, 4, &Engine::with_jobs(1));
    let t4 = trace_with(&env, 40, 5, 4, &Engine::with_jobs(4));
    assert_eq!(
        format!("{:?}", t1.trials),
        format!("{:?}", t4.trials),
        "prescreened traces must be worker-count invariant"
    );
    let again = trace_with(&env, 40, 5, 4, &Engine::with_jobs(1));
    assert_eq!(
        format!("{:?}", t1.trials),
        format!("{:?}", again.trials),
        "prescreened traces must replay byte-identically"
    );
}

#[test]
fn prescreened_runs_never_profile_statically_hopeless_configs() {
    let layer = resnet18::layer("conv5").unwrap();
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    let trace = trace_with(&env, 60, 5, 4, &Engine::single_threaded());
    assert_eq!(trace.len(), 60);
    let compiler = Compiler::new(env.hw().clone());
    for t in &trace.trials {
        assert!(
            compiler.static_check(&env.layer, &t.schedule).is_plausible(),
            "statically-Hopeless config survived the tier-0 prescreen \
             into full profiling: {}",
            t.schedule
        );
    }
}

#[test]
fn coarse_estimates_match_static_check_and_rank_correlate_with_timing() {
    let layer = resnet18::layer("conv5").unwrap();
    let env = TuningEnv::new(VtaConfig::zcu102(), layer);
    let compiler = Compiler::new(env.hw().clone());
    let mut pts: Vec<(u64, u64)> = Vec::new(); // (tier-0, tier-1)
    for i in (0..env.space.len()).step_by(7) {
        let sched = env.space.schedule(i);
        let plausible =
            compiler.static_check(&env.layer, &sched).is_plausible();
        match coarse::estimate(env.hw(), &env.layer, &sched) {
            CoarseEstimate::Hopeless => assert!(
                !plausible,
                "tier-0 Hopeless but statically plausible: {sched}"
            ),
            CoarseEstimate::Cycles(c) => {
                assert!(
                    plausible,
                    "finite tier-0 estimate for a statically impossible \
                     config: {sched}"
                );
                assert!(c > 0);
                if let Outcome::Valid { cycles } =
                    env.profile(i).outcome
                {
                    pts.push((c, cycles));
                }
            }
        }
    }
    assert!(
        pts.len() >= 30,
        "pinned sample too small to test concordance: {}",
        pts.len()
    );
    let (mut agree, mut total) = (0usize, 0usize);
    for a in 0..pts.len() {
        for b in (a + 1)..pts.len() {
            let (ca, ma) = pts[a];
            let (cb, mb) = pts[b];
            if ca == cb || ma == mb {
                continue;
            }
            total += 1;
            if (ca < cb) == (ma < mb) {
                agree += 1;
            }
        }
    }
    let concordance = agree as f64 / total as f64;
    assert!(
        concordance > 0.55,
        "tier-0 estimates must rank-correlate with full timing: \
         concordance {concordance:.3} over {total} pairs"
    );
}
