//! Integration: end-to-end tuning behaviour and experiment harness smoke
//! (quick mode). No PJRT dependency — pure simulator path.

use ml2tuner::experiments::{self, ExpConfig};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::vta::config::VtaConfig;
use ml2tuner::workloads::resnet18;

fn env(layer: &str) -> TuningEnv {
    TuningEnv::new(VtaConfig::zcu102(), resnet18::layer(layer).unwrap())
}

#[test]
fn ml2tuner_filters_invalids_better_than_random() {
    let e = env("conv1");
    let cfg = TunerConfig { max_trials: 200, seed: 5, ..Default::default() };
    let ml2 = Ml2Tuner::new(cfg.clone()).tune(&e);
    let rnd = RandomTuner::new(cfg).tune(&e);
    assert!(
        ml2.invalidity_ratio() < rnd.invalidity_ratio() * 0.7,
        "ml2 {:.3} vs random {:.3}",
        ml2.invalidity_ratio(),
        rnd.invalidity_ratio()
    );
}

#[test]
fn ml2tuner_at_least_matches_random_on_best_found() {
    // averaged over 3 seeds: model-guided search must find an optimum at
    // least as good as random's (tiny slack for single-budget variance)
    let e = env("conv3");
    let mut ml2_best = Vec::new();
    let mut rnd_best = Vec::new();
    for seed in [9, 19, 29] {
        let cfg =
            TunerConfig { max_trials: 200, seed, ..Default::default() };
        ml2_best.push(
            Ml2Tuner::new(cfg.clone()).tune(&e).best_cycles().unwrap()
                as f64,
        );
        rnd_best.push(
            RandomTuner::new(cfg).tune(&e).best_cycles().unwrap() as f64,
        );
    }
    let m = ml2tuner::util::stats::mean(&ml2_best);
    let r = ml2tuner::util::stats::mean(&rnd_best);
    assert!(m <= r * 1.01, "ml2 {m} vs random {r}");
}

#[test]
fn all_three_tuners_find_the_same_ballpark_optimum() {
    let e = env("conv5");
    let cfg = TunerConfig { max_trials: 250, seed: 2, ..Default::default() };
    let b1 = Ml2Tuner::new(cfg.clone()).tune(&e).best_cycles().unwrap();
    let b2 = TvmTuner::new(cfg.clone()).tune(&e).best_cycles().unwrap();
    let b3 = RandomTuner::new(cfg).tune(&e).best_cycles().unwrap();
    let lo = b1.min(b2).min(b3) as f64;
    for b in [b1, b2, b3] {
        assert!((b as f64) < lo * 1.5, "outlier optimum: {b} vs {lo}");
    }
}

#[test]
fn tuners_only_propose_enumerable_schedules() {
    let e = env("conv2");
    let cfg = TunerConfig { max_trials: 60, seed: 1, ..Default::default() };
    let trace = Ml2Tuner::new(cfg).tune(&e);
    for t in &trace.trials {
        assert!(t.space_index < e.space.len());
        assert_eq!(e.space.schedule(t.space_index), t.schedule);
    }
}

// ---- experiment harness smoke (quick mode) ---------------------------

#[test]
fn experiment_table2_quick_runs() {
    let report =
        experiments::run("table2", &ExpConfig::quick()).unwrap();
    assert!(report.contains("conv1"));
    assert!(report.contains("conv10"));
    assert!(report.contains("0.8264")); // paper column present
}

#[test]
fn experiment_fig3_quick_shows_ratio() {
    let report = experiments::run("fig3", &ExpConfig::quick()).unwrap();
    assert!(report.contains("average ratio"));
    assert!(report.contains("0.919")); // paper reference
}

#[test]
fn experiment_transfer_quick_runs() {
    let report =
        experiments::run("transfer", &ExpConfig::quick()).unwrap();
    assert!(report.contains("transfer warm-start"));
    assert!(report.contains("cold best"));
    assert!(report.contains("warm best"));
    assert!(report.contains("final best (mean)"));
}

#[test]
fn experiment_unknown_id_errors() {
    assert!(experiments::run("fig99", &ExpConfig::quick()).is_err());
}
