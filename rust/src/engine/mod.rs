//! Parallel tuning engine: batched profiling executor, compile cache,
//! and network-level tuning scheduler.
//!
//! The paper's loop profiles `N` configurations per round and compiles
//! the whole `(α+1)·N` candidate pool for hidden-feature extraction —
//! work that is embarrassingly parallel and, in the seed implementation,
//! ran strictly sequentially and compiled every profiled candidate twice.
//! This subsystem makes the compile+simulate hot path scale with cores
//! while leaving every trace byte-identical to a sequential run:
//!
//! * [`executor`] — [`Engine`]: a `std::thread`-scoped worker pool that
//!   fans a candidate batch out (`--jobs` workers, default all cores) and
//!   collects results in batch order, so worker count never changes a
//!   tuning trace.
//! * [`cache`] — [`CompileCache`]: memoizes `(layer, schedule) →
//!   compiled kernel + hidden features`, shared across rounds; the
//!   ML²Tuner A-stage pool compile is reused when the re-ranked winners
//!   are profiled (no double compilation).
//! * [`scheduler`] — [`NetworkTuner`]: tunes all layers of a network
//!   under one global trial budget with a round-robin warmup + UCB1
//!   budget allocator, one tuning database per layer, and a
//!   network-level report (total cycles, per-layer best schedules).
//! * [`fleet`] — [`FleetTuner`]: one network across a *list of hardware
//!   targets* (`tune-fleet`), smallest capacity first, chaining each
//!   target's logs into the next target's transfer warm start and
//!   sharing the compile cache wherever codegen signatures agree.
//!
//! Thread-safety audit: [`crate::compiler::Compiler`] and
//! [`crate::vta::Simulator`] are plain-data facades over the hardware
//! config with no interior mutability, and
//! `Simulator::check` takes `&self` — both are `Send + Sync` (asserted
//! at compile time in `executor`'s tests), which is what lets one
//! [`crate::tuner::TuningEnv`] be shared by every worker.

pub mod cache;
pub mod executor;
pub mod fleet;
pub mod scheduler;

pub use cache::{CacheStats, CachedCompile, CompileCache};
pub use executor::{default_jobs, Engine, EngineConfig};
pub use fleet::{FleetConfig, FleetOutcome, FleetTargetRun, FleetTuner};
pub use scheduler::{
    LayerResult, LayerSession, NetworkConfig, NetworkOutcome,
    NetworkReport, NetworkTuner, TunerKind,
};
