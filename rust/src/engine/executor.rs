//! Batch profiling executor — fans compile+simulate work across a scoped
//! worker pool while keeping per-seed determinism.
//!
//! Workers pull batch positions from a shared atomic cursor and write
//! results into per-position slots, so the collected vector is always in
//! batch order: a run with `jobs = 8` produces the byte-identical tuning
//! trace of a run with `jobs = 1` (enforced by `tests/engine.rs`). All
//! candidate selection and model training stay on the caller's thread —
//! only the embarrassingly parallel compile+check hot path fans out.

use std::sync::Arc;

use super::cache::{
    CachedCompile, CompileCache, DEFAULT_MAX_ENTRIES,
    DEFAULT_MAX_TOTAL_COST,
};
use crate::obs::{Counter, Recorder, Stage};
use crate::tuner::database::{Database, Fidelity, Outcome, TrialRecord};
use crate::tuner::report::TuningTrace;
use crate::tuner::space::SearchSpace;
use crate::tuner::{outcome_of, TuningEnv};
use crate::util::par::{par_map, par_map_with};
use crate::vta::coarse::{self, CoarseEstimate};
use crate::vta::SimScratch;

/// Worker count when `--jobs` is not given: all available cores.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Executor knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads for batched compile/profile work (≥ 1).
    pub jobs: usize,
    /// Compile-cache entry bound (see [`CompileCache::with_capacity`]).
    pub max_cache_entries: usize,
    /// Compile-cache instruction budget; 0 disables caching (for
    /// one-shot sweeps that never re-profile a schedule).
    pub max_cache_cost: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            jobs: default_jobs(),
            max_cache_entries: DEFAULT_MAX_ENTRIES,
            max_cache_cost: DEFAULT_MAX_TOTAL_COST,
        }
    }
}

/// The parallel tuning engine: a worker-pool batch executor plus the
/// compile cache shared by every batch it runs.
///
/// One `Engine` is meant to live for a whole tuning run (or a whole
/// network-level run — see [`super::scheduler`]), so compilations paid
/// during hidden-feature extraction are never repaid at profiling time or
/// in later rounds.
pub struct Engine {
    /// Executor knobs this engine was built with.
    pub cfg: EngineConfig,
    /// Shared-ownership compile cache: single-run engines own theirs
    /// exclusively, while the serve daemon hands one cache to every
    /// per-job engine ([`Engine::with_shared_cache`]) so concurrent jobs
    /// compile each `(layer, schedule)` once.
    cache: Arc<CompileCache>,
    /// Telemetry recorder shared with the cache (and handed to the
    /// tuning loops via [`Engine::recorder`]): stage spans, outcome
    /// counters, and the optional `--metrics-out` event sink.
    recorder: Arc<Recorder>,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new(EngineConfig::default())
    }
}

impl Engine {
    /// Engine with a fresh private recorder.
    pub fn new(cfg: EngineConfig) -> Self {
        Engine::with_recorder(cfg, Arc::new(Recorder::new()))
    }

    /// Engine recording onto a caller-supplied recorder (how the CLI
    /// attaches one `--metrics-out` sink to a whole run). The compile
    /// cache counts its hits/misses on the same recorder.
    pub fn with_recorder(cfg: EngineConfig, recorder: Arc<Recorder>) -> Self {
        let cache = Arc::new(CompileCache::with_recorder(
            cfg.max_cache_entries,
            cfg.max_cache_cost,
            Arc::clone(&recorder),
        ));
        Engine { cfg, cache, recorder }
    }

    /// Engine borrowing an existing compile cache — the serve daemon's
    /// session shape: each tuning job gets its own engine (and recorder,
    /// so per-job round events stay separable) over the one daemon-wide
    /// cache. Cache hit/miss telemetry lands on the recorder the *cache*
    /// was built with, not `recorder` — cache traffic is a property of
    /// the shared resource, not of any one job.
    pub fn with_shared_cache(
        cfg: EngineConfig,
        cache: Arc<CompileCache>,
        recorder: Arc<Recorder>,
    ) -> Self {
        Engine { cfg, cache, recorder }
    }

    /// Engine with `jobs` workers and default cache sizing.
    pub fn with_jobs(jobs: usize) -> Self {
        Engine::new(EngineConfig {
            jobs: jobs.max(1),
            ..EngineConfig::default()
        })
    }

    /// Sequential engine (no worker threads; still caches compiles).
    pub fn single_threaded() -> Self {
        Engine::with_jobs(1)
    }

    /// Effective worker count (≥ 1).
    pub fn jobs(&self) -> usize {
        self.cfg.jobs.max(1)
    }

    /// The engine's compile cache (shared view).
    pub fn cache(&self) -> &CompileCache {
        &self.cache
    }

    /// Owning handle to the compile cache, for building further engines
    /// over the same cache ([`Engine::with_shared_cache`]).
    pub fn cache_handle(&self) -> Arc<CompileCache> {
        Arc::clone(&self.cache)
    }

    /// The engine's telemetry recorder (always present; sink optional).
    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// Compile one space index through the cache.
    pub fn compile_one(
        &self,
        env: &TuningEnv,
        space_index: usize,
    ) -> Arc<CachedCompile> {
        let sched = env.space.schedule(space_index);
        self.cache.get_or_compile(&env.compiler, &env.layer, sched)
    }

    /// "Run on hardware" through the cache: compile (or reuse), simulate,
    /// classify. Equivalent to [`TuningEnv::profile`] record-for-record.
    ///
    /// Allocating wrapper over [`Engine::profile_one_with`]; batch
    /// profiling threads one scratch per worker instead.
    pub fn profile_one(
        &self,
        env: &TuningEnv,
        space_index: usize,
    ) -> TrialRecord {
        self.profile_one_with(env, space_index, &mut SimScratch::new())
    }

    /// [`Engine::profile_one`] against a caller-owned simulator scratch
    /// arena, and the unit of work [`Engine::profile_batch`] hands each
    /// worker. Also records the `Timing`/`Hazard` sub-spans on the
    /// engine recorder (per-worker CPU time, like the sweep chunks), so
    /// `ml2tuner report` can break profile time into sim vs hazard vs
    /// codegen.
    pub fn profile_one_with(
        &self,
        env: &TuningEnv,
        space_index: usize,
        scratch: &mut SimScratch,
    ) -> TrialRecord {
        let sched = env.space.schedule(space_index);
        let cached =
            self.cache.get_or_compile(&env.compiler, &env.layer, sched);
        let verdict =
            env.simulator.check_with(&cached.compiled.program, scratch);
        self.recorder.record_duration_ns(Stage::Timing, scratch.timing_ns);
        self.recorder.record_duration_ns(Stage::Hazard, scratch.hazard_ns);
        let outcome = outcome_of(&verdict);
        TrialRecord {
            space_index,
            schedule: sched,
            visible: env.space.visible(space_index),
            hidden: cached.hidden.clone(),
            outcome,
            fidelity: Fidelity::Full,
        }
    }

    /// Tier-0 coarse prescreen of a candidate pool: analytic cycle
    /// estimates ([`crate::vta::coarse`]) sharded across the worker pool
    /// like the scoring sweep, merged back in candidate order so the
    /// result is byte-identical for any `--jobs`.
    ///
    /// No program is built and nothing is profiled: candidates never hit
    /// the compile cache, `mark_measured`, or the trial counters, so
    /// fleet/budget accounting keeps counting full-fidelity profiles
    /// only. Estimates land in `estimates` (cleared first; reusable
    /// across rounds).
    pub fn prescreen_into(
        &self,
        env: &TuningEnv,
        candidates: &[usize],
        estimates: &mut Vec<CoarseEstimate>,
    ) {
        let _span = self.recorder.span(Stage::Prescreen);
        self.recorder
            .add(Counter::CandidatesPrescreened, candidates.len() as u64);
        let cfg = &env.simulator.cfg;
        let merged = par_map(self.jobs(), candidates.len(), |k| {
            let sched = env.space.schedule(candidates[k]);
            coarse::estimate(cfg, &env.layer, &sched)
        });
        estimates.clear();
        estimates.extend(merged);
    }

    /// Profile a candidate batch across the worker pool. Results come back
    /// ordered by batch position regardless of worker count.
    ///
    /// Each worker owns one [`SimScratch`] for the whole batch (created
    /// by `par_map_with`, dropped when the worker retires), so a warmed
    /// steady state runs the simulator allocation-free per trial. The
    /// scratch never crosses workers and never affects verdicts —
    /// `tests/sim_scratch.rs` pins jobs-invariance.
    pub fn profile_batch(
        &self,
        env: &TuningEnv,
        batch: &[usize],
    ) -> Vec<TrialRecord> {
        let _span = self.recorder.span(Stage::Profile);
        par_map_with(self.jobs(), batch.len(), SimScratch::new, |s, k| {
            self.profile_one_with(env, batch[k], s)
        })
    }

    /// Profile `batch` and do the record bookkeeping every tuning loop
    /// shares: mark each index measured, append the record to the
    /// database (when one is kept) and to the trace, in batch order.
    /// Each record is moved into one shared [`Arc`] — the database and
    /// the trace hold the same allocation, never a deep clone of the
    /// `visible`/`hidden` feature vectors.
    pub fn profile_into(
        &self,
        env: &TuningEnv,
        batch: &[usize],
        space: &mut SearchSpace,
        mut db: Option<&mut Database>,
        trace: &mut TuningTrace,
    ) {
        for rec in self.profile_batch(env, batch) {
            self.recorder.incr(Counter::TrialsProfiled);
            self.recorder.incr(match rec.outcome {
                Outcome::Valid { .. } => Counter::TrialsValid,
                Outcome::Crash => Counter::TrialsCrash,
                Outcome::WrongOutput => Counter::TrialsWrongOutput,
            });
            space.mark_measured(rec.space_index);
            let rec = Arc::new(rec);
            if let Some(d) = &mut db {
                d.push(Arc::clone(&rec));
            }
            trace.trials.push(rec);
        }
    }

    /// Compile a candidate batch (hidden-feature extraction for the
    /// ML²Tuner A-stage) across the worker pool, in batch order.
    pub fn compile_batch(
        &self,
        env: &TuningEnv,
        batch: &[usize],
    ) -> Vec<Arc<CachedCompile>> {
        let _span = self.recorder.span(Stage::Compile);
        par_map(self.jobs(), batch.len(), |k| {
            self.compile_one(env, batch[k])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn env() -> TuningEnv {
        TuningEnv::new(VtaConfig::zcu102(),
                       resnet18::layer("conv5").unwrap())
    }

    #[test]
    fn profile_batch_matches_sequential_profile() {
        let e = env();
        let batch: Vec<usize> = (0..24).map(|i| i * 31).collect();
        let engine = Engine::with_jobs(4);
        let par = engine.profile_batch(&e, &batch);
        assert_eq!(par.len(), batch.len());
        for (k, rec) in par.iter().enumerate() {
            let seq = e.profile(batch[k]);
            assert_eq!(rec.space_index, seq.space_index);
            assert_eq!(rec.schedule, seq.schedule);
            assert_eq!(rec.outcome, seq.outcome);
            assert_eq!(rec.hidden, seq.hidden);
            assert_eq!(rec.visible, seq.visible);
        }
    }

    #[test]
    fn profiling_a_compiled_batch_never_recompiles() {
        let e = env();
        let batch: Vec<usize> = (0..16).collect();
        // unbounded cache so the miss accounting is exact
        let engine = Engine::new(EngineConfig {
            jobs: 2,
            max_cache_entries: usize::MAX,
            max_cache_cost: usize::MAX,
        });
        engine.compile_batch(&e, &batch);
        let misses_after_compile = engine.cache().stats().misses;
        assert_eq!(misses_after_compile, batch.len() as u64);
        engine.profile_batch(&e, &batch);
        let stats = engine.cache().stats();
        assert_eq!(stats.misses, misses_after_compile,
                   "profiling recompiled a pooled candidate");
        assert!(stats.hits >= batch.len() as u64);
    }

    #[test]
    fn prescreen_is_jobs_invariant_and_profiles_nothing() {
        let e = env();
        let batch: Vec<usize> = (0..64).map(|i| i * 17).collect();
        let e1 = Engine::with_jobs(1);
        let e4 = Engine::with_jobs(4);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        e1.prescreen_into(&e, &batch, &mut a);
        e4.prescreen_into(&e, &batch, &mut b);
        assert_eq!(a, b, "tier-0 merge must be jobs-invariant");
        assert_eq!(a.len(), batch.len());
        // tier 0 never compiles, profiles, or counts trials
        assert_eq!(e4.recorder().get(Counter::TrialsProfiled), 0);
        assert_eq!(e4.recorder().get(Counter::CandidatesPrescreened), 64);
        assert_eq!(e4.cache().stats().misses, 0,
                   "prescreen must not touch the compile cache");
    }

    #[test]
    fn engine_types_are_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Engine>();
        check::<CompileCache>();
        check::<TuningEnv>();
        check::<crate::compiler::Compiler>();
        check::<crate::vta::Simulator>();
    }
}
