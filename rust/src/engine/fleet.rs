//! Fleet tuning scheduler — one network tuned across a *list of hardware
//! targets* under one global profiling budget.
//!
//! The fleet pass is where the target registry, the codegen-signature
//! compile cache, and the capacity-aware transfer store compose:
//!
//! * targets are visited **cheapest/smallest capacity first**
//!   ([`crate::vta::targets::capacity_score`]) — the small target's
//!   validity boundary is the strictest, so its logs are conservative
//!   seeds for every larger target that follows;
//! * each per-target run is a full [`super::NetworkTuner`] pass sharing
//!   one [`super::Engine`], so compilations are reused across targets
//!   whenever their codegen signatures agree (e.g. zcu102 ↔ hiband);
//! * every finished target's per-layer logs are appended to the transfer
//!   store and warm-start the next target's models (hardware distance
//!   down-weights and capacity-audits them — see
//!   [`crate::tuner::database::TransferDb::warm_start_for`]).
//!
//! Determinism: target order is a pure function of the configs, each
//! target derives an independent seed stream, and the per-target runs
//! are the deterministic `NetworkTuner` — a fleet run is reproducible
//! for any worker count.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use super::executor::Engine;
use super::scheduler::{NetworkConfig, NetworkOutcome, NetworkTuner,
                       TunerKind};
use crate::compiler::schedule::SpaceKind;
use crate::tuner::database::TransferDb;
use crate::tuner::meta::MetaArtifact;
use crate::tuner::TunerConfig;
use crate::util::table::Table;
use crate::vta::config::VtaConfig;
use crate::vta::targets;
use crate::workloads::ConvLayer;

/// Fleet-run knobs. The per-target loop hyper-parameters mirror
/// [`NetworkConfig`]; `total_trials` is the *global* budget, split
/// evenly across targets (earlier — smaller — targets absorb the
/// remainder).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Hardware targets to tune (visit order is derived from their
    /// capacities, not from this list's order).
    pub targets: Vec<VtaConfig>,
    /// Tuner to run on every target.
    pub tuner: TunerKind,
    /// Knob space to search on every target.
    pub space: SpaceKind,
    /// Base per-layer tuner knobs (seed, rounds, pool sizes).
    pub base: TunerConfig,
    /// Global profiling budget over the whole fleet.
    pub total_trials: usize,
    /// Trials per scheduler decision inside each per-target run.
    pub round_trials: usize,
    /// UCB exploration constant of the per-target layer allocator.
    pub ucb_c: f64,
    /// External seed logs (e.g. `--transfer-from`); per-target logs are
    /// chained on top as the fleet progresses.
    pub transfer: Option<TransferDb>,
    /// Max transferred records per layer.
    pub transfer_cap: usize,
    /// Corpus-trained meta ensembles (`--meta`) shared by every
    /// per-target run.
    pub meta: Option<Arc<MetaArtifact>>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        let net = NetworkConfig::default();
        FleetConfig {
            targets: vec![VtaConfig::zcu102()],
            tuner: net.tuner,
            space: net.space,
            base: net.base,
            total_trials: net.total_trials,
            round_trials: net.round_trials,
            ucb_c: net.ucb_c,
            transfer: None,
            transfer_cap: net.transfer_cap,
            meta: None,
        }
    }
}

/// One target's slice of a fleet run.
pub struct FleetTargetRun {
    /// Target name this slice tuned on.
    pub target: String,
    /// Target clock, for cycles→ms conversion in the summary.
    pub clock_mhz: f64,
    /// The full per-network tuning outcome on this target.
    pub outcome: NetworkOutcome,
}

/// Everything a fleet run produces, in tuned (cheapest-first) order.
pub struct FleetOutcome {
    /// Per-target runs, in the order they were tuned.
    pub runs: Vec<FleetTargetRun>,
}

impl FleetOutcome {
    /// Persist every target's per-layer logs as
    /// `<dir>/<target>/<layer>.json`; returns the written paths.
    pub fn save_databases(
        &self,
        dir: impl AsRef<Path>,
    ) -> Result<Vec<PathBuf>> {
        let dir = dir.as_ref();
        let mut paths = Vec::new();
        for run in &self.runs {
            paths.extend(
                run.outcome.save_databases(dir.join(&run.target))?,
            );
        }
        Ok(paths)
    }

    /// Fleet summary: one row per target, tuned order.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "target", "layers tuned", "trials", "network cycles",
            "network ms",
        ]);
        for run in &self.runs {
            let r = &run.outcome.report;
            let (cycles, ms) = match r.total_cycles() {
                Some(c) => (
                    c.to_string(),
                    format!("{:.3}", c as f64 / (run.clock_mhz * 1e3)),
                ),
                None => ("incomplete".to_string(), "-".to_string()),
            };
            t.row(&[
                run.target.clone(),
                format!("{}/{}", r.tuned_layers(), r.layers.len()),
                r.total_trials.to_string(),
                cycles,
                ms,
            ]);
        }
        format!(
            "== fleet tuning report (targets tuned smallest-capacity \
             first) ==\n{}",
            t.render()
        )
    }
}

/// Visit order over `targets`: capacity score ascending, name as the
/// deterministic tiebreak. Returns indices into the input slice.
pub fn tune_order(targets: &[VtaConfig]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..targets.len()).collect();
    order.sort_by_key(|&i| {
        (targets::capacity_score(&targets[i]), targets[i].target.clone())
    });
    order
}

/// The fleet scheduler. See the module docs for the policy.
pub struct FleetTuner {
    /// Fleet-run knobs.
    pub cfg: FleetConfig,
}

impl FleetTuner {
    /// Scheduler over the given fleet configuration.
    pub fn new(cfg: FleetConfig) -> Self {
        FleetTuner { cfg }
    }

    /// Tune `layers` on every configured target under the global
    /// budget, fanning all profiling work through `engine` (one shared
    /// compile cache for the whole fleet).
    pub fn tune(
        &self,
        engine: &Engine,
        layers: &[ConvLayer],
    ) -> FleetOutcome {
        let cfg = &self.cfg;
        let order = tune_order(&cfg.targets);
        let n = order.len().max(1);
        let share = cfg.total_trials / n;
        let remainder = cfg.total_trials % n;
        let mut store = cfg.transfer.clone().unwrap_or_default();
        let mut runs = Vec::with_capacity(order.len());
        for (pos, &idx) in order.iter().enumerate() {
            let hw = cfg.targets[idx].clone();
            let budget = share + usize::from(pos < remainder);
            let net_cfg = NetworkConfig {
                vta: hw.clone(),
                tuner: cfg.tuner,
                space: cfg.space,
                base: TunerConfig {
                    // independent per-target stream off the global seed
                    // (the per-layer derivation inside NetworkTuner
                    // xors bits 32+; targets use bits 48+)
                    seed: cfg.base.seed ^ ((pos as u64 + 1) << 48),
                    ..cfg.base.clone()
                },
                total_trials: budget,
                round_trials: cfg.round_trials,
                ucb_c: cfg.ucb_c,
                transfer: if store.is_empty() {
                    None
                } else {
                    Some(store.clone())
                },
                transfer_cap: cfg.transfer_cap,
                meta: cfg.meta.clone(),
            };
            let outcome = NetworkTuner::new(net_cfg).tune(engine, layers);
            // chain this target's logs as transfer sources for the next
            // (they carry the target stamp, so the next target's warm
            // start hardware-audits them)
            for db in &outcome.databases {
                store.add(db.clone());
            }
            runs.push(FleetTargetRun {
                target: hw.target.clone(),
                clock_mhz: hw.clock_mhz,
                outcome,
            });
        }
        FleetOutcome { runs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    fn fleet_cfg(
        targets: Vec<VtaConfig>,
        tuner: TunerKind,
        trials: usize,
    ) -> FleetConfig {
        FleetConfig {
            targets,
            tuner,
            total_trials: trials,
            round_trials: 10,
            base: TunerConfig { seed: 11, ..TunerConfig::default() },
            ..FleetConfig::default()
        }
    }

    #[test]
    fn order_is_capacity_ascending() {
        let targets = crate::vta::targets::all();
        let order = tune_order(&targets);
        let names: Vec<&str> = order
            .iter()
            .map(|&i| targets[i].target.as_str())
            .collect();
        assert_eq!(names, ["edge-small", "zcu104", "zcu102", "hiband"]);
    }

    #[test]
    fn budget_splits_and_order_holds() {
        let layers = vec![resnet18::layer("conv5").unwrap()];
        let engine = Engine::with_jobs(2);
        let cfg = fleet_cfg(
            vec![VtaConfig::zcu102(), VtaConfig::zcu104()],
            TunerKind::Random,
            21,
        );
        let out = FleetTuner::new(cfg).tune(&engine, &layers);
        assert_eq!(out.runs.len(), 2);
        // zcu104 is smaller: tuned first, absorbs the remainder trial
        assert_eq!(out.runs[0].target, "zcu104");
        assert_eq!(out.runs[1].target, "zcu102");
        assert_eq!(out.runs[0].outcome.report.total_trials, 11);
        assert_eq!(out.runs[1].outcome.report.total_trials, 10);
        // per-layer logs carry each run's own target stamp
        for run in &out.runs {
            for db in &run.outcome.databases {
                assert_eq!(
                    db.target.as_ref().map(|t| t.name.as_str()),
                    Some(run.target.as_str())
                );
            }
        }
        assert!(out.render().contains("zcu104"));
    }

    #[test]
    fn fleet_runs_are_deterministic() {
        let layers = vec![resnet18::layer("conv5").unwrap()];
        let indices = |jobs: usize| -> Vec<Vec<usize>> {
            let engine = Engine::with_jobs(jobs);
            let cfg = fleet_cfg(
                vec![VtaConfig::zcu104(), VtaConfig::zcu102()],
                TunerKind::Random,
                20,
            );
            FleetTuner::new(cfg)
                .tune(&engine, &layers)
                .runs
                .iter()
                .map(|r| {
                    r.outcome.traces[0]
                        .trials
                        .iter()
                        .map(|t| t.space_index)
                        .collect()
                })
                .collect()
        };
        assert_eq!(indices(1), indices(4),
                   "fleet traces must be worker-count invariant");
    }

    #[test]
    fn later_targets_warm_start_from_earlier_logs() {
        // ml2 policy, enough budget to cross min_train on the first
        // target: the second target's layer session must be warm
        // (trace relabelled "ml2tuner-warm"), the first stays cold
        let layers = vec![resnet18::layer("conv5").unwrap()];
        let engine = Engine::single_threaded();
        let cfg = fleet_cfg(
            vec![VtaConfig::zcu102(), VtaConfig::zcu104()],
            TunerKind::Ml2,
            60,
        );
        let out = FleetTuner::new(cfg).tune(&engine, &layers);
        assert_eq!(out.runs[0].outcome.traces[0].tuner, "ml2tuner",
                   "first (smallest) target runs cold");
        assert_eq!(out.runs[1].outcome.traces[0].tuner, "ml2tuner-warm",
                   "second target must chain the first target's logs");
    }

    #[test]
    fn save_databases_groups_by_target() {
        let layers = vec![resnet18::layer("conv5").unwrap()];
        let engine = Engine::single_threaded();
        let cfg = fleet_cfg(
            vec![VtaConfig::zcu102(), VtaConfig::zcu104()],
            TunerKind::Random,
            10,
        );
        let out = FleetTuner::new(cfg).tune(&engine, &layers);
        let dir = std::env::temp_dir().join("ml2tuner_fleet_test");
        std::fs::remove_dir_all(&dir).ok();
        let paths = out.save_databases(&dir).unwrap();
        assert_eq!(paths.len(), 2);
        assert!(dir.join("zcu104").join("conv5.json").is_file());
        assert!(dir.join("zcu102").join("conv5.json").is_file());
        std::fs::remove_dir_all(&dir).ok();
    }
}
