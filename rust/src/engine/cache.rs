//! Compile cache — memoizes `(target codegen signature, space kind,
//! layer, schedule) → compiled kernel + hidden features`.
//!
//! The ML²Tuner loop compiles every pool candidate for hidden-feature
//! extraction and then compiled the `N` winners *again* when profiling
//! them (paper §2: the `(α+1)·N` pool feeds model A, the re-ranked top-N
//! go to the board). Compilation is deterministic, so the second compile
//! is pure waste; the cache eliminates it and keeps paying off across
//! rounds (the explorer re-proposes near-frontier schedules) and across a
//! whole-network tuning run.
//!
//! Thread-safe: lookups take a [`Mutex`]-guarded map, compilation happens
//! *outside* the lock so [`super::executor::Engine`] workers never
//! serialize on each other's compiles. Two workers racing on the same key
//! may both compile; the map keeps one canonical entry (compilation is
//! deterministic, so both are identical) and results never depend on the
//! race.
//!
//! Memory: a cached entry holds the full instruction stream, and
//! degenerate schedules (1×1 tiles) lower to very large programs — the
//! cache is therefore bounded both by entry count and by total cached
//! instructions. When a bound is hit the *oldest* entries are evicted
//! (FIFO), so the current round's pool — the reuse that kills the
//! A-stage double compilation — always stays hot, even in long
//! shared-engine runs. Results are identical cached or not; only reuse
//! is affected.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::compiler::{Compiled, Compiler};
use crate::obs::{Counter, Recorder};
use crate::vta::config::CodegenSig;
use crate::workloads::ConvLayer;

/// One cached compilation: the lowered kernel and its hidden features
/// (model A's extra inputs), extracted once.
#[derive(Clone, Debug)]
pub struct CachedCompile {
    /// The lowered kernel.
    pub compiled: Compiled,
    /// Hidden features extracted from the lowered kernel.
    pub hidden: Vec<f64>,
}

impl CachedCompile {
    /// Memory-footprint proxy: instructions + micro-ops held.
    fn cost(&self) -> usize {
        self.compiled.program.instrs.len()
            + self.compiled.program.uops.len()
    }
}

/// Cache hit/miss counters (a *miss* is an actual compilation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that compiled.
    pub misses: u64,
}

impl CacheStats {
    /// Total lookups (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction of all lookups (0.0 when none yet).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

// The compiler's space kind is part of the key: entries carry the
// kind-specific hidden-feature vector, so a paper-kind and an
// extended-kind lookup of the same (layer, schedule) must not alias.
// The target's *codegen signature* is part of the key too — but only the
// compile-shaping fields ([`CodegenSig`]), not the target name: a fleet
// run over targets that differ purely in uop capacity or DMA/clock
// coefficients (e.g. zcu102 vs hiband) shares every entry, while targets
// whose buffer slicing differs (zcu102 vs zcu104) never alias.
type Key = (CodegenSig, SpaceKind, &'static str, Schedule);

struct Inner {
    map: HashMap<Key, Arc<CachedCompile>>,
    /// Insertion order, oldest first (FIFO eviction).
    order: VecDeque<Key>,
    total_cost: usize,
}

/// Thread-safe, bounded compile cache keyed by `(codegen signature,
/// space kind, layer name, schedule)`.
///
/// Layer names are the `&'static str` identifiers of
/// [`crate::workloads::resnet18::LAYERS`]; keying by name (not shape)
/// keeps entries unambiguous if two layers ever shared a shape but
/// diverged in future compile options. The codegen signature keys the
/// hardware axis (see the `Key` comment above).
pub struct CompileCache {
    inner: Mutex<Inner>,
    /// Hit/miss counters live on the shared telemetry recorder
    /// ([`Counter::CompileCacheHit`]/[`Counter::CompileCacheMiss`]) so
    /// one recorder owns every number a run report needs. A standalone
    /// cache gets a private recorder; an [`super::Engine`] shares its
    /// own (see [`CompileCache::with_recorder`]).
    recorder: Arc<Recorder>,
    /// Entry-count bound.
    max_entries: usize,
    /// Total cached instructions+uops bound (memory proxy).
    max_total_cost: usize,
}

/// Default entry bound: a full tuning run touches a few thousand
/// schedules at most.
pub const DEFAULT_MAX_ENTRIES: usize = 4096;

/// Default instruction budget (≈ a couple hundred MB worst case).
pub const DEFAULT_MAX_TOTAL_COST: usize = 1 << 21;

impl Default for CompileCache {
    fn default() -> Self {
        Self::new()
    }
}

impl CompileCache {
    /// Cache with the default entry and instruction bounds.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_MAX_ENTRIES, DEFAULT_MAX_TOTAL_COST)
    }

    /// Cache bounded to `max_entries` compilations and `max_total_cost`
    /// cached instructions+uops (oldest entries evicted at the bounds).
    /// `max_total_cost = 0` disables caching entirely (every lookup
    /// compiles, nothing is retained) — useful for one-shot sweeps that
    /// never re-profile a schedule.
    pub fn with_capacity(max_entries: usize, max_total_cost: usize) -> Self {
        Self::with_recorder(max_entries, max_total_cost,
                            Arc::new(Recorder::new()))
    }

    /// Like [`with_capacity`](Self::with_capacity) but counting
    /// hits/misses on a caller-supplied recorder — how the engine shares
    /// one recorder between its cache and its own spans.
    pub fn with_recorder(
        max_entries: usize,
        max_total_cost: usize,
        recorder: Arc<Recorder>,
    ) -> Self {
        CompileCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                total_cost: 0,
            }),
            recorder,
            max_entries: max_entries.max(1),
            max_total_cost,
        }
    }

    /// Cached compilations currently held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lifetime hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.recorder.get(Counter::CompileCacheHit),
            misses: self.recorder.get(Counter::CompileCacheMiss),
        }
    }

    /// Drop all entries (counters are kept; they describe the lifetime of
    /// the cache, not its current contents).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.order.clear();
        inner.total_cost = 0;
    }

    /// Look up `(layer, sched)`; compile on a miss and memoize, evicting
    /// the oldest entries if a bound is hit.
    pub fn get_or_compile(
        &self,
        compiler: &Compiler,
        layer: &ConvLayer,
        sched: Schedule,
    ) -> Arc<CachedCompile> {
        let key = (compiler.cfg.codegen_sig(), compiler.kind, layer.name,
                   sched);
        if let Some(hit) = self.inner.lock().unwrap().map.get(&key) {
            self.recorder.incr(Counter::CompileCacheHit);
            return Arc::clone(hit);
        }
        self.recorder.incr(Counter::CompileCacheMiss);
        // Compile outside the lock: other workers keep hitting the cache
        // while this (comparatively expensive) lowering runs.
        let compiled = compiler.compile(layer, &sched);
        let hidden = compiler.hidden_features(&compiled);
        let entry = Arc::new(CachedCompile { compiled, hidden });
        let cost = entry.cost();
        if cost > self.max_total_cost {
            return entry; // would never fit: don't thrash the cache
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(existing) = inner.map.get(&key) {
            // lost a same-key race: keep the canonical entry
            return Arc::clone(existing);
        }
        // evict oldest-first until the new entry fits
        while inner.map.len() >= self.max_entries
            || inner.total_cost + cost > self.max_total_cost
        {
            let Some(old) = inner.order.pop_front() else { break };
            if let Some(e) = inner.map.remove(&old) {
                inner.total_cost -= e.cost();
            }
        }
        inner.total_cost += cost;
        inner.order.push_back(key);
        inner.map.insert(key, Arc::clone(&entry));
        entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn setup() -> (Compiler, ConvLayer, Schedule) {
        let layer = resnet18::layer("conv5").unwrap();
        let sched = Schedule { tile_h: 4, tile_w: 4, tile_oc: 32,
                               tile_ic: 32, n_vthreads: 2,
                               ..Default::default() };
        (Compiler::new(VtaConfig::zcu102()), layer, sched)
    }

    #[test]
    fn second_lookup_hits() {
        let (compiler, layer, sched) = setup();
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&compiler, &layer, sched);
        let b = cache.get_or_compile(&compiler, &layer, sched);
        assert_eq!(a.compiled.program, b.compiled.program);
        assert_eq!(a.hidden, b.hidden);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_schedules_are_distinct_entries() {
        let (compiler, layer, sched) = setup();
        let other = Schedule { tile_h: 7, ..sched };
        let cache = CompileCache::new();
        cache.get_or_compile(&compiler, &layer, sched);
        cache.get_or_compile(&compiler, &layer, other);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn same_schedule_different_layer_is_a_miss() {
        let (compiler, layer, sched) = setup();
        let conv4 = resnet18::layer("conv4").unwrap();
        let cache = CompileCache::new();
        cache.get_or_compile(&compiler, &layer, sched);
        cache.get_or_compile(&compiler, &conv4, sched);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn same_schedule_different_space_kind_is_a_miss() {
        // hidden-feature layouts differ per kind — aliasing entries
        // across kinds would hand an extended run 21-long hidden vectors
        let (compiler, layer, sched) = setup();
        let ext = Compiler::with_kind(VtaConfig::zcu102(),
                                      SpaceKind::Extended);
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&compiler, &layer, sched);
        let b = cache.get_or_compile(&ext, &layer, sched);
        assert_eq!(cache.stats().misses, 2);
        assert!(b.hidden.len() > a.hidden.len());
        assert_eq!(a.compiled.program, b.compiled.program);
    }

    #[test]
    fn codegen_equivalent_targets_share_entries() {
        // hiband differs from zcu102 only off the codegen path (uop
        // capacity, DMA coefficients): a fleet run over both must reuse
        // every compilation
        let (compiler, layer, sched) = setup();
        let hiband = Compiler::new(VtaConfig::hiband());
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&compiler, &layer, sched);
        let b = cache.get_or_compile(&hiband, &layer, sched);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(a.compiled.program, b.compiled.program);
    }

    #[test]
    fn different_buffer_slicing_never_aliases() {
        // zcu104's smaller buffers change the per-thread scratchpad
        // slices codegen addresses by — handing its run a zcu102 kernel
        // would silently profile the wrong program
        let (compiler, layer, _) = setup();
        // nvt > 1 so the slice bases actually differ between targets
        let sched = Schedule { tile_h: 4, tile_w: 4, tile_oc: 32,
                               tile_ic: 32, n_vthreads: 2,
                               ..Default::default() };
        let zcu104 = Compiler::new(VtaConfig::zcu104());
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&compiler, &layer, sched);
        let b = cache.get_or_compile(&zcu104, &layer, sched);
        assert_eq!(cache.stats().misses, 2);
        assert_ne!(a.compiled.program, b.compiled.program,
                   "slice bases must differ under nvt=2");
    }

    #[test]
    fn entry_bound_evicts_oldest() {
        let (compiler, layer, sched) = setup();
        let cache = CompileCache::with_capacity(1, usize::MAX);
        cache.get_or_compile(&compiler, &layer, sched);
        let other = Schedule { tile_h: 7, ..sched };
        cache.get_or_compile(&compiler, &layer, other); // evicts `sched`
        assert_eq!(cache.len(), 1, "bound respected");
        // the newest entry stays hot ...
        cache.get_or_compile(&compiler, &layer, other);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 2 });
        // ... while the evicted one misses again
        cache.get_or_compile(&compiler, &layer, sched);
        assert_eq!(cache.stats().misses, 3);
    }

    #[test]
    fn zero_cost_budget_disables_caching() {
        let (compiler, layer, sched) = setup();
        let cache = CompileCache::with_capacity(8, 0);
        cache.get_or_compile(&compiler, &layer, sched);
        cache.get_or_compile(&compiler, &layer, sched);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 2 });
    }

    #[test]
    fn clear_resets_cost_accounting() {
        let (compiler, layer, sched) = setup();
        let cache = CompileCache::with_capacity(8, usize::MAX);
        let a = cache.get_or_compile(&compiler, &layer, sched);
        cache.clear();
        assert!(cache.is_empty());
        // re-inserting after clear works (cost budget was released)
        let b = cache.get_or_compile(&compiler, &layer, sched);
        assert_eq!(cache.len(), 1);
        assert_eq!(a.compiled.program, b.compiled.program);
    }

    #[test]
    fn matches_direct_compilation() {
        let (compiler, layer, sched) = setup();
        let cache = CompileCache::new();
        let cached = cache.get_or_compile(&compiler, &layer, sched);
        let direct = compiler.compile(&layer, &sched);
        assert_eq!(cached.compiled.program, direct.program);
        assert_eq!(cached.hidden, compiler.hidden_features(&direct));
    }
}
