//! Network-level tuning scheduler — tunes a whole model (the paper tunes
//! layers one at a time) under one global trial budget. Layers come from
//! the [`crate::workloads`] registry (any [`crate::workloads::Network`]),
//! and each layer's models can be warm-started from prior tuning logs
//! via [`NetworkConfig::transfer`].
//!
//! A [`LayerSession`] holds the incremental tuning state of one layer
//! (search space mask, profiling database, trace, RNG stream) and can be
//! advanced one round at a time. The [`NetworkTuner`] owns one session per
//! layer and allocates the global budget with a round-robin warmup
//! followed by a UCB1-style bandit: each layer's observed reward is its
//! relative best-cycles improvement per granted round, so the budget
//! flows to the layers still making progress (cf. the whole-network
//! tuning workflows of the TPU learned-cost-model and MetaTune lines in
//! PAPERS.md).
//!
//! Everything here is deterministic for a fixed seed and independent of
//! the engine's worker count: allocation decisions use only profiled
//! outcomes, which the executor returns in batch order.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::executor::Engine;
use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::obs::{console, Stage};
use crate::tuner::database::{Database, TransferDb};
use crate::tuner::meta::MetaArtifact;
use crate::tuner::report::TuningTrace;
use crate::tuner::space::SearchSpace;
use crate::tuner::{ml2tuner, salt, tvm_baseline, TunerConfig, TuningEnv};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;

/// Which tuning policy a session runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunerKind {
    /// The paper's multi-level tuner (models P/V/A).
    Ml2,
    /// TVM-style single-level cost-model baseline.
    Tvm,
    /// Uniform random search baseline.
    Random,
}

impl TunerKind {
    /// Parse a CLI tuner name (`ml2tuner`/`ml2`, `tvm`, `random`).
    pub fn parse(name: &str) -> Option<TunerKind> {
        match name {
            "ml2tuner" | "ml2" => Some(TunerKind::Ml2),
            "tvm" => Some(TunerKind::Tvm),
            "random" => Some(TunerKind::Random),
            _ => None,
        }
    }

    /// Canonical tuner name, as stamped into traces and logs.
    pub fn name(&self) -> &'static str {
        match self {
            TunerKind::Ml2 => "ml2tuner",
            TunerKind::Tvm => "tvm",
            TunerKind::Random => "random",
        }
    }

    /// Per-policy RNG salt — the same constants the standalone tuners
    /// use, so a session replays the stream the corresponding `Tuner`
    /// would.
    fn rng_salt(&self) -> u64 {
        match self {
            TunerKind::Ml2 => salt::ML2,
            TunerKind::Tvm => salt::TVM,
            TunerKind::Random => salt::RANDOM,
        }
    }
}

/// Incremental tuning state for one layer: the scheduler advances it one
/// round at a time instead of running a whole budget in one call.
pub struct LayerSession {
    /// Layer + space + compiler + simulator the session tunes against.
    pub env: TuningEnv,
    /// Per-layer tuner knobs (seed, rounds, pool sizes).
    pub cfg: TunerConfig,
    kind: TunerKind,
    space: SearchSpace,
    db: Database,
    /// Transferred records pre-training the ML² models (training-only —
    /// never profiled, never in the trace or the persisted log).
    warm: Option<Database>,
    /// Corpus-trained base ensembles the ML² models adapt from
    /// (training-only, like `warm`); shared across sessions.
    meta: Option<Arc<MetaArtifact>>,
    /// Carried-over boosters for incremental per-round continuation.
    mstate: ml2tuner::ModelState,
    /// Per-trial tuning trace accumulated so far.
    pub trace: TuningTrace,
    rng: Rng,
    round: u64,
}

impl LayerSession {
    /// Fresh (cold) session for one layer under one policy.
    pub fn new(kind: TunerKind, cfg: TunerConfig, env: TuningEnv) -> Self {
        let rng = Rng::new(cfg.seed ^ kind.rng_salt());
        let space = env.space.clone();
        let db =
            Database::for_layer_on(&env.layer, env.kind(), env.hw());
        let trace = TuningTrace::new(env.layer.name, kind.name());
        LayerSession { env, cfg, kind, space, db, warm: None, meta: None,
                       mstate: ml2tuner::ModelState::default(), trace,
                       rng, round: 0 }
    }

    /// Warm-start the session's models from a transferred database
    /// (effective for the ML² policy; the baselines stay cold). The
    /// trace is relabelled so persisted logs distinguish warm from cold
    /// runs, matching the standalone tuner's naming. An empty database
    /// is a no-op — the session stays cold and keeps its cold label.
    pub fn with_warm_start(mut self, warm: Database) -> Self {
        if warm.is_empty() {
            return self;
        }
        self.warm = Some(warm);
        self.relabel();
        self
    }

    /// Adapt the session's models from a corpus-trained meta artifact
    /// (effective for the ML² policy; the baselines stay cold). Like
    /// warm starts, meta ensembles only ever train models — they never
    /// enter the trace or the persisted log.
    pub fn with_meta(mut self, meta: Arc<MetaArtifact>) -> Self {
        self.meta = Some(meta);
        self.relabel();
        self
    }

    /// Restamp the trace with the standalone tuner's name for the
    /// current (warm, meta) combination.
    fn relabel(&mut self) {
        if self.kind != TunerKind::Ml2 {
            return;
        }
        self.trace.tuner = match (self.warm.is_some(), self.meta.is_some())
        {
            (false, false) => "ml2tuner",
            (true, false) => "ml2tuner-warm",
            (false, true) => "ml2tuner-meta",
            (true, true) => "ml2tuner-warm-meta",
        }
        .to_string();
    }

    /// Name of the layer this session tunes.
    pub fn layer_name(&self) -> &'static str {
        self.env.layer.name
    }

    /// Trials profiled so far.
    pub fn trials(&self) -> usize {
        self.trace.len()
    }

    /// Tuning rounds advanced so far.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    /// Best valid cycle count so far, if any.
    pub fn best_cycles(&self) -> Option<u64> {
        self.trace.best_cycles()
    }

    /// Schedule of the best valid trial so far.
    pub fn best_schedule(&self) -> Option<Schedule> {
        let best = self.trace.best_cycles()?;
        self.trace
            .trials
            .iter()
            .find(|t| t.outcome.cycles() == Some(best))
            .map(|t| t.schedule)
    }

    /// Whole search space measured — nothing left to profile.
    pub fn exhausted(&self) -> bool {
        self.space.n_unmeasured() == 0
    }

    /// The session's profiling database: every profiled trial, plus —
    /// when `prescreen_factor` is on — coarse-fidelity records of the
    /// candidates the tier-0 cut pruned.
    pub fn database(&self) -> &Database {
        &self.db
    }

    /// Profile at most `n` trials through the engine (never beyond the
    /// session's own `cfg.max_trials`); returns the number actually
    /// profiled.
    ///
    /// A grant larger than the policy's `n_per_round` is split into
    /// `n_per_round`-sized tuning rounds (models retrained between
    /// them), so a generous scheduler grant keeps the standalone loop
    /// structure — in particular the ML²Tuner `(α+1)·N` A-stage, which
    /// would be silently skipped if `n` exceeded the pool size.
    pub fn step(&mut self, engine: &Engine, n: usize) -> usize {
        let mut done = 0usize;
        while done < n
            && self.trials() < self.cfg.max_trials
            && !self.exhausted()
        {
            let take = (n - done)
                .min(self.cfg.n_per_round)
                .min(self.cfg.max_trials - self.trials())
                .min(self.space.n_unmeasured());
            self.round += 1;
            let scope = engine.recorder().begin_round();
            let before = self.trace.len();
            let (batch, stats) = match self.kind {
                TunerKind::Random => {
                    let _select = engine.recorder().span(Stage::Select);
                    (self.space.sample_unmeasured(&mut self.rng, take),
                     None)
                }
                TunerKind::Tvm => (
                    tvm_baseline::select_batch(
                        &self.cfg, &self.space, &self.db, &mut self.rng,
                        self.round, take, engine,
                    ),
                    None,
                ),
                TunerKind::Ml2 => {
                    let (batch, stats, coarse) = ml2tuner::select_batch(
                        &self.cfg, true, true, &self.env, engine,
                        &self.space, &self.db, self.warm.as_ref(),
                        self.meta.as_deref(), Some(&mut self.mstate),
                        &mut self.rng, self.round, take,
                    );
                    // tier-0 estimates of pruned candidates train the
                    // models but never enter the trace or the budget
                    for c in coarse {
                        self.db.push(c);
                    }
                    (batch, stats)
                }
            };
            if batch.is_empty() {
                break;
            }
            done += batch.len();
            engine.profile_into(&self.env, &batch, &mut self.space,
                                Some(&mut self.db), &mut self.trace);
            let round = self.round;
            let v_margin = self.cfg.v_margin;
            engine.recorder().end_round(scope, || {
                crate::tuner::round_event(&self.env, &self.trace, before,
                                          round, v_margin, stats)
            });
        }
        done
    }

    /// Tear down into the artifacts the scheduler reports/persists.
    pub fn finish(self) -> (TuningTrace, Database) {
        (self.trace, self.db)
    }
}

/// Network-run knobs.
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    /// Hardware target every layer tunes on.
    pub vta: VtaConfig,
    /// Tuning policy every layer session runs.
    pub tuner: TunerKind,
    /// Knob set every layer session enumerates (`--space`).
    pub space: SpaceKind,
    /// Per-layer loop hyper-parameters; `seed` is the global seed (each
    /// layer derives an independent stream from it).
    pub base: TunerConfig,
    /// Global profiling budget shared by all layers.
    pub total_trials: usize,
    /// Trials granted per scheduler decision (one tuning round).
    pub round_trials: usize,
    /// UCB exploration constant (0 = purely greedy on observed reward).
    pub ucb_c: f64,
    /// Prior tuning logs warm-starting every layer's models (the
    /// `--transfer-from` store); `None` = cold start.
    pub transfer: Option<TransferDb>,
    /// Max transferred records per layer.
    pub transfer_cap: usize,
    /// Corpus-trained meta ensembles adapting every layer's models (the
    /// `--meta` artifact for this run's space); `None` = cold start.
    pub meta: Option<Arc<MetaArtifact>>,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            vta: VtaConfig::zcu102(),
            tuner: TunerKind::Ml2,
            space: SpaceKind::Paper,
            base: TunerConfig::default(),
            total_trials: 1000,
            round_trials: TunerConfig::default().n_per_round,
            ucb_c: 0.5,
            transfer: None,
            transfer_cap: 400,
            meta: None,
        }
    }
}

/// Per-layer summary of a network run.
#[derive(Clone, Debug)]
pub struct LayerResult {
    /// Layer name.
    pub layer: &'static str,
    /// Trials profiled on this layer.
    pub trials: usize,
    /// Tuning rounds this layer was granted.
    pub rounds: u64,
    /// Fraction of profiled trials that were invalid.
    pub invalidity: f64,
    /// Best valid cycle count found, if any.
    pub best_cycles: Option<u64>,
    /// Schedule achieving `best_cycles`, if any.
    pub best_schedule: Option<Schedule>,
}

/// Network-level tuning report: per-layer winners plus whole-network
/// totals.
#[derive(Clone, Debug)]
pub struct NetworkReport {
    /// Tuner name the run used.
    pub tuner: &'static str,
    /// Trials profiled across all layers.
    pub total_trials: usize,
    /// Per-layer winners, network order.
    pub layers: Vec<LayerResult>,
}

impl NetworkReport {
    /// Layers that found at least one valid schedule.
    pub fn tuned_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.best_cycles.is_some()).count()
    }

    /// Whole-network cycles (sum of per-layer bests); `None` until every
    /// layer has a valid schedule.
    pub fn total_cycles(&self) -> Option<u64> {
        self.layers
            .iter()
            .map(|l| l.best_cycles)
            .sum::<Option<u64>>()
    }

    /// Printable report table + totals.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "layer", "trials", "rounds", "invalidity", "best cycles",
            "best schedule",
        ]);
        for l in &self.layers {
            t.row(&[
                l.layer.to_string(),
                l.trials.to_string(),
                l.rounds.to_string(),
                format!("{:.3}", l.invalidity),
                l.best_cycles
                    .map(|c| c.to_string())
                    .unwrap_or_else(|| "-".into()),
                l.best_schedule
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        let total = match self.total_cycles() {
            Some(c) => format!("{c} cycles"),
            None => "incomplete (some layer has no valid schedule)".into(),
        };
        format!(
            "== network tuning report ({}) ==\n{}\nlayers tuned: {}/{}   \
             trials: {}   network total: {}\n",
            self.tuner,
            t.render(),
            self.tuned_layers(),
            self.layers.len(),
            self.total_trials,
            total
        )
    }
}

/// Everything a network run produces: the report plus the per-layer
/// traces and databases (one tuning log per layer, TVM-style).
pub struct NetworkOutcome {
    /// The rendered-ready per-layer summary.
    pub report: NetworkReport,
    /// Per-layer tuning traces, network order.
    pub traces: Vec<TuningTrace>,
    /// Per-layer profiling databases, network order.
    pub databases: Vec<Database>,
}

impl NetworkOutcome {
    /// Persist one database per layer as `<dir>/<layer>.json`; returns
    /// the written paths.
    pub fn save_databases(&self, dir: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {dir:?}"))?;
        let mut paths = Vec::with_capacity(self.databases.len());
        for db in &self.databases {
            let path = dir.join(format!("{}.json", db.layer));
            db.save(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }
}

/// The budget allocator. See the module docs for the policy.
pub struct NetworkTuner {
    /// Network-run knobs.
    pub cfg: NetworkConfig,
}

impl NetworkTuner {
    /// Allocator over the given network configuration.
    pub fn new(cfg: NetworkConfig) -> Self {
        NetworkTuner { cfg }
    }

    /// Tune `layers` under the global budget, fanning all profiling work
    /// through `engine`.
    pub fn tune(&self, engine: &Engine, layers: &[ConvLayer]) -> NetworkOutcome {
        let cfg = &self.cfg;
        let mut sessions: Vec<LayerSession> = layers
            .iter()
            .enumerate()
            .map(|(i, layer)| {
                let per_layer = TunerConfig {
                    // independent per-layer stream off the global seed
                    seed: cfg.base.seed ^ ((i as u64 + 1) << 32),
                    max_trials: cfg.total_trials,
                    ..cfg.base.clone()
                };
                let mut session = LayerSession::new(
                    cfg.tuner,
                    per_layer,
                    TuningEnv::with_space(cfg.vta.clone(), *layer,
                                          cfg.space),
                );
                // only the ML² policy consumes warm/meta data — don't
                // pay for similarity matching on the baseline kinds
                if cfg.tuner == TunerKind::Ml2 {
                    if let Some(store) = &cfg.transfer {
                        if let Some(warm) = store.warm_start_for(
                            layer, cfg.space, &cfg.vta,
                            cfg.transfer_cap,
                        ) {
                            session = session.with_warm_start(warm);
                        }
                    }
                    if let Some(meta) = &cfg.meta {
                        session = session.with_meta(Arc::clone(meta));
                    }
                }
                session
            })
            .collect();
        let n = sessions.len();
        let mut rounds = vec![0u64; n];
        let mut reward_sum = vec![0f64; n];
        let mut prev_best: Vec<Option<u64>> = vec![None; n];
        let mut alive = vec![true; n];
        let mut spent = 0usize;
        let mut total_rounds = 0u64;
        while spent < cfg.total_trials && alive.iter().any(|&a| a) {
            let pick = match self.pick(&alive, &rounds, &reward_sum,
                                       total_rounds)
            {
                Some(i) => i,
                None => break,
            };
            let grant =
                cfg.round_trials.max(1).min(cfg.total_trials - spent);
            let done = sessions[pick].step(engine, grant);
            console::verbose(&format!(
                "[sched] round {:>4}  layer {:<8} granted {:>3} \
                 profiled {:>3}  best {}",
                total_rounds + 1,
                sessions[pick].layer_name(),
                grant,
                done,
                sessions[pick]
                    .best_cycles()
                    .map(|c| c.to_string())
                    .unwrap_or_else(|| "-".into()),
            ));
            total_rounds += 1;
            rounds[pick] += 1;
            if done == 0 {
                alive[pick] = false;
                continue;
            }
            spent += done;
            let now = sessions[pick].best_cycles();
            reward_sum[pick] += match (prev_best[pick], now) {
                // relative improvement of the layer's best this round
                (Some(b0), Some(b1)) if b1 < b0 => {
                    1.0 - b1 as f64 / b0 as f64
                }
                // first valid schedule found: maximal reward
                (None, Some(_)) => 1.0,
                _ => 0.0,
            };
            prev_best[pick] = now;
            if sessions[pick].exhausted() {
                alive[pick] = false;
            }
        }
        self.collect(sessions, spent)
    }

    /// Round-robin until every live layer has one round, then UCB1 on the
    /// mean per-round improvement. Ties go to the lowest layer index, so
    /// allocation is fully deterministic.
    fn pick(
        &self,
        alive: &[bool],
        rounds: &[u64],
        reward_sum: &[f64],
        total_rounds: u64,
    ) -> Option<usize> {
        if let Some(i) =
            (0..alive.len()).find(|&i| alive[i] && rounds[i] == 0)
        {
            return Some(i);
        }
        let t = (total_rounds.max(1)) as f64;
        let mut best: Option<(f64, usize)> = None;
        for i in 0..alive.len() {
            if !alive[i] {
                continue;
            }
            let ri = rounds[i] as f64;
            let score = reward_sum[i] / ri
                + self.cfg.ucb_c * (t.ln().max(0.0) / ri).sqrt();
            let improves = match best {
                None => true,
                Some((s, _)) => score > s + 1e-12,
            };
            if improves {
                best = Some((score, i));
            }
        }
        best.map(|(_, i)| i)
    }

    fn collect(
        &self,
        sessions: Vec<LayerSession>,
        spent: usize,
    ) -> NetworkOutcome {
        let mut layers = Vec::with_capacity(sessions.len());
        let mut traces = Vec::with_capacity(sessions.len());
        let mut databases = Vec::with_capacity(sessions.len());
        for s in sessions.into_iter() {
            layers.push(LayerResult {
                layer: s.layer_name(),
                trials: s.trials(),
                // actual tuning rounds run (a large scheduler grant is
                // split into n_per_round-sized rounds by the session)
                rounds: s.rounds(),
                invalidity: s.trace.invalidity_ratio(),
                best_cycles: s.best_cycles(),
                best_schedule: s.best_schedule(),
            });
            let (trace, db) = s.finish();
            traces.push(trace);
            databases.push(db);
        }
        NetworkOutcome {
            report: NetworkReport {
                tuner: self.cfg.tuner.name(),
                total_trials: spent,
                layers,
            },
            traces,
            databases,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    fn two_layer_cfg(kind: TunerKind, trials: usize) -> NetworkConfig {
        NetworkConfig {
            tuner: kind,
            total_trials: trials,
            round_trials: 10,
            base: TunerConfig { seed: 5, ..TunerConfig::default() },
            ..NetworkConfig::default()
        }
    }

    fn layers() -> Vec<ConvLayer> {
        vec![
            resnet18::layer("conv1").unwrap(),
            resnet18::layer("conv5").unwrap(),
        ]
    }

    #[test]
    fn budget_is_spent_and_split() {
        let engine = Engine::with_jobs(2);
        let out = NetworkTuner::new(two_layer_cfg(TunerKind::Random, 60))
            .tune(&engine, &layers());
        assert_eq!(out.report.total_trials, 60);
        let per_layer: usize =
            out.report.layers.iter().map(|l| l.trials).sum();
        assert_eq!(per_layer, 60);
        // warmup guarantees every layer at least one round
        assert!(out.report.layers.iter().all(|l| l.rounds >= 1));
        assert_eq!(out.traces.len(), 2);
        assert_eq!(out.databases.len(), 2);
        for (t, d) in out.traces.iter().zip(&out.databases) {
            assert_eq!(t.len(), d.len());
        }
    }

    /// A session stepped with per-round grants replays the standalone
    /// tuner exactly (same rng salt + call sequence).
    fn assert_session_matches_standalone(
        kind: TunerKind,
        standalone: &crate::tuner::report::TuningTrace,
        trials: usize,
        cfg: TunerConfig,
    ) {
        let layer = resnet18::layer("conv5").unwrap();
        let engine = Engine::single_threaded();
        let mut session = LayerSession::new(
            kind,
            cfg,
            TuningEnv::new(VtaConfig::zcu102(), layer),
        );
        while session.trials() < trials {
            assert!(session.step(&engine, 10) > 0);
        }
        let a: Vec<usize> = session
            .trace
            .trials
            .iter()
            .map(|t| t.space_index)
            .collect();
        let b: Vec<usize> =
            standalone.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(a, b, "{} session diverged from standalone tuner",
                   kind.name());
    }

    #[test]
    fn random_session_matches_standalone_tuner_stream() {
        use crate::tuner::random_baseline::RandomTuner;
        use crate::tuner::Tuner;
        let layer = resnet18::layer("conv5").unwrap();
        let cfg = TunerConfig { max_trials: 30, seed: 9,
                                ..TunerConfig::default() };
        let env = TuningEnv::new(VtaConfig::zcu102(), layer);
        let standalone = RandomTuner::new(cfg.clone()).tune(&env);
        assert_session_matches_standalone(TunerKind::Random, &standalone,
                                          30, cfg);
    }

    #[test]
    fn ml2_session_matches_standalone_tuner_stream() {
        // 40 trials crosses min_train, so model-guided rounds (incl. the
        // A-stage) are exercised, not just the random warmup
        use crate::tuner::ml2tuner::Ml2Tuner;
        use crate::tuner::Tuner;
        let layer = resnet18::layer("conv5").unwrap();
        let cfg = TunerConfig { max_trials: 40, seed: 9,
                                ..TunerConfig::default() };
        let env = TuningEnv::new(VtaConfig::zcu102(), layer);
        let standalone = Ml2Tuner::new(cfg.clone()).tune(&env);
        assert_session_matches_standalone(TunerKind::Ml2, &standalone,
                                          40, cfg);
    }

    #[test]
    fn report_totals() {
        let r = NetworkReport {
            tuner: "ml2tuner",
            total_trials: 40,
            layers: vec![
                LayerResult {
                    layer: "conv1",
                    trials: 20,
                    rounds: 2,
                    invalidity: 0.5,
                    best_cycles: Some(100),
                    best_schedule: None,
                },
                LayerResult {
                    layer: "conv2",
                    trials: 20,
                    rounds: 2,
                    invalidity: 0.5,
                    best_cycles: Some(250),
                    best_schedule: None,
                },
            ],
        };
        assert_eq!(r.total_cycles(), Some(350));
        assert_eq!(r.tuned_layers(), 2);
        let mut incomplete = r.clone();
        incomplete.layers[1].best_cycles = None;
        assert_eq!(incomplete.total_cycles(), None);
        assert!(incomplete.render().contains("incomplete"));
    }
}
