//! Tuning-as-a-service: the persistent best-schedule store and the
//! daemon that serves it.
//!
//! ML²Tuner's economics are that tuning samples are expensive — so at
//! production scale the winning move is to never re-tune: every
//! `tune`/`tune-net`/`tune-fleet` run can append its best schedules to
//! a [`ScheduleDb`] (`--schedule-db <dir>`), and the `serve` daemon
//! answers "best schedule for this layer/target/space" queries from it
//! in-memory — compiling and profiling *nothing* on a hit. Genuine
//! misses fall back to a warm-started tuning job on a bounded worker
//! pool ([`Daemon`]), and the result is promoted into the store for
//! every later query.
//!
//! Three layers:
//!
//! * [`schedule_db`] — the versioned, better-only, atomically-written
//!   store, keyed on (layer shape, codegen signature, space kind);
//! * [`protocol`] — the line-oriented JSON request/response schema;
//! * [`daemon`] — session orchestration: instant lookups, admission
//!   control, per-job engines over one shared compile cache.
//!
//! `experiment storm` (see [`crate::experiments`]) stress-drives the
//! lookup path with thousands of mixed hit/miss queries and reports
//! latency percentiles; EXPERIMENTS.md §Serving documents layout,
//! protocol, and methodology.

pub mod daemon;
pub mod protocol;
pub mod schedule_db;

pub use daemon::{Daemon, ServeConfig, ServeExit, SharedSink};
pub use protocol::{Query, Request, RequestError};
pub use schedule_db::{
    fnv64, Promotion, ScheduleDb, ScheduleEntry, ScheduleKey,
};
