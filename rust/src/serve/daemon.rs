//! The serve daemon: instant schedule lookups, miss-triggered tuning
//! jobs on a bounded worker pool, one shared compile cache.
//!
//! ## Who owns what state
//!
//! The [`Daemon`] owns the long-lived, shared resources: the
//! [`ScheduleDb`] (interior-locked), one [`CompileCache`], the
//! daemon-lifetime [`Recorder`] (lookup hit/miss and job counters), and
//! the startup-loaded transfer store. Each miss-triggered tuning job
//! gets *private* session state: its own [`LayerSession`] (search
//! space, database, models, RNG), its own [`Engine`] over the shared
//! cache, and its own [`Recorder`]+sink so the job's
//! `run_start`/`round`/`run_end` events interleave line-atomically with
//! other jobs' events in one JSONL stream.
//!
//! ## Determinism
//!
//! A job's RNG seed is `cfg.seed ^ fnv64(key.canonical())` — a pure
//! function of the query, independent of arrival order, queue position,
//! or worker count. Warm starts come only from the transfer store
//! loaded at startup (never from schedules other jobs produced
//! mid-session), and the shared compile cache stores pure functions of
//! its keys — so the same query set produces byte-identical schedules
//! for any `--workers` value and any job interleaving (pinned by
//! `tests/serve.rs`).
//!
//! ## Admission control
//!
//! Miss queries with `tune_on_miss` go through a bounded
//! [`mpsc::sync_channel`]: `try_send` either enqueues (response
//! `queued`, then `tuned`/`no_valid` later) or fails fast (response
//! `busy`) when the backlog is full — the daemon never buffers
//! unbounded tuning work.
//!
//! The daemon's own status chatter goes to *stderr*: on stdio
//! transport, stdout belongs to the response protocol.

use std::collections::BTreeMap;
use std::io::{BufRead, BufWriter, Write};
use std::net::TcpListener;
use std::path::Path;
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::engine::{CompileCache, Engine, EngineConfig, LayerSession, TunerKind};
use crate::obs::{Counter, EventSink, Recorder};
use crate::serve::protocol::{self, Query, Request};
use crate::serve::schedule_db::{
    fnv64, ScheduleDb, ScheduleEntry, ScheduleKey,
};
use crate::tuner::database::TransferDb;
use crate::tuner::meta::{MetaArtifact, MetaStore};
use crate::tuner::{TunerConfig, TuningEnv};
use crate::util::json::Json;

/// Daemon knobs (CLI flags of the `serve` subcommand).
#[derive(Clone)]
pub struct ServeConfig {
    /// Tuning-job worker threads (`--workers`, ≥ 1).
    pub workers: usize,
    /// Queued-job bound for admission control (`--queue`, ≥ 1).
    pub queue_cap: usize,
    /// Default trial budget for a miss-triggered job (`--miss-trials`;
    /// a query's `trials` field overrides per job).
    pub miss_trials: usize,
    /// Base seed; each job derives its own stream from this and its key.
    pub seed: u64,
    /// Worker threads *inside* each job's engine (`--jobs`).
    pub jobs: usize,
    /// Transfer store loaded at startup (`--transfer-from`) — the only
    /// warm-start source jobs may use (see the determinism note above).
    pub transfer: Option<TransferDb>,
    /// Warm-start record cap per job (`--transfer-cap`).
    pub transfer_cap: usize,
    /// Meta artifacts loaded at startup (`--meta`); each job adapts
    /// from the artifact matching its query's space. Like warm starts,
    /// a startup-only input, so job results stay arrival-order
    /// independent.
    pub meta: Option<MetaStore>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_cap: 16,
            miss_trials: 60,
            seed: 0,
            jobs: 1,
            transfer: None,
            transfer_cap: 400,
            meta: None,
        }
    }
}

/// Cloneable fan-in writer for the per-job event sinks: every clone
/// appends to one underlying stream, and each `write` call transfers
/// its whole buffer under one lock acquisition. Paired with a
/// [`BufWriter`] per job (which accumulates a full JSONL line before
/// flushing), concurrent jobs produce line-atomic interleavings.
#[derive(Clone)]
pub struct SharedSink {
    inner: Arc<Mutex<Box<dyn Write + Send>>>,
}

impl SharedSink {
    /// Wrap an open stream.
    pub fn new(out: Box<dyn Write + Send>) -> SharedSink {
        SharedSink { inner: Arc::new(Mutex::new(out)) }
    }

    /// Create (truncate) a file sink at `path`.
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<SharedSink> {
        let file = std::fs::File::create(path)?;
        Ok(SharedSink::new(Box::new(file)))
    }
}

impl Write for SharedSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let mut out = self.inner.lock().unwrap();
        out.write_all(buf)?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.lock().unwrap().flush()
    }
}

/// Why a serve session ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeExit {
    /// Input stream closed.
    Eof,
    /// An explicit `{"op":"shutdown"}` request.
    Shutdown,
}

/// The serve daemon (see the module docs for the state-ownership and
/// determinism story). One `Daemon` can serve several sessions in
/// sequence ([`Daemon::serve_tcp`]); the schedule db, compile cache,
/// and counters persist across them.
pub struct Daemon {
    cfg: ServeConfig,
    db: Arc<ScheduleDb>,
    /// Daemon-lifetime counters: schedule-db hits/misses, jobs
    /// tuned/rejected, total trials profiled. (Per-job engines carry
    /// their own recorders; the shared compile cache counts on this
    /// one.)
    recorder: Arc<Recorder>,
    cache: Arc<CompileCache>,
    metrics: Option<SharedSink>,
    /// `cfg.meta` re-wrapped per space kind so each job can share the
    /// artifact without cloning the ensembles.
    meta: BTreeMap<&'static str, Arc<MetaArtifact>>,
}

impl Daemon {
    /// Daemon over an opened schedule db.
    pub fn new(cfg: ServeConfig, db: Arc<ScheduleDb>) -> Daemon {
        let recorder = Arc::new(Recorder::new());
        let ecfg = EngineConfig::default();
        let cache = Arc::new(CompileCache::with_recorder(
            ecfg.max_cache_entries,
            ecfg.max_cache_cost,
            Arc::clone(&recorder),
        ));
        let meta = cfg
            .meta
            .as_ref()
            .map(|s| {
                s.iter()
                    .map(|(k, a)| (k, Arc::new(a.clone())))
                    .collect()
            })
            .unwrap_or_default();
        Daemon { cfg, db, recorder, cache, metrics: None, meta }
    }

    /// Attach a JSONL metrics stream; every tuning job emits its
    /// `run_start`/`round`/`run_end` events into it.
    pub fn with_metrics(mut self, sink: SharedSink) -> Daemon {
        self.metrics = Some(sink);
        self
    }

    /// Daemon-lifetime counters.
    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// The schedule store this daemon answers from.
    pub fn db(&self) -> &ScheduleDb {
        &self.db
    }

    /// The hit path: one in-memory map probe, counted. No I/O, no
    /// compilation, no profiling (pinned by `tests/serve.rs`).
    pub fn answer_lookup(&self, key: &ScheduleKey) -> Option<ScheduleEntry> {
        let found = self.db.lookup(key);
        self.recorder.incr(match found {
            Some(_) => Counter::ScheduleDbHit,
            None => Counter::ScheduleDbMiss,
        });
        found
    }

    /// Serve one session: read request lines from `input`, write
    /// response lines to `output`, until EOF or a `shutdown` request.
    /// Hits, misses, `stats`, admission rejections, and parse errors
    /// are answered synchronously in request order; `tuned`/`no_valid`
    /// responses land whenever their worker finishes (correlate by id).
    pub fn run<R, W>(&self, input: R, output: W) -> Result<ServeExit>
    where
        R: BufRead,
        W: Write + Send,
    {
        let out = Mutex::new(output);
        let (tx, rx) = mpsc::sync_channel::<Query>(self.cfg.queue_cap.max(1));
        let rx = Mutex::new(rx);
        std::thread::scope(|s| -> Result<ServeExit> {
            for _ in 0..self.cfg.workers.max(1) {
                s.spawn(|| loop {
                    let next = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match next {
                        Ok(q) => self.run_job(&q, &out),
                        Err(_) => break,
                    }
                });
            }
            let exit = self.read_loop(input, &out, &tx);
            drop(tx);
            exit
        })
    }

    fn read_loop<R: BufRead, W: Write>(
        &self,
        input: R,
        out: &Mutex<W>,
        tx: &SyncSender<Query>,
    ) -> Result<ServeExit> {
        for line in input.lines() {
            let line = line.context("reading request line")?;
            if line.trim().is_empty() {
                continue;
            }
            match Request::parse(&line) {
                Err(e) => self.respond(out, &protocol::response_error(&e)),
                Ok(Request::Shutdown) => return Ok(ServeExit::Shutdown),
                Ok(Request::Stats { id }) => {
                    let j = self.stats_json(id);
                    self.respond(out, &j);
                }
                Ok(Request::Query(q)) => {
                    let id = q.id;
                    let key = ScheduleKey::for_layer_on(
                        &q.layer, q.space, &q.target,
                    );
                    match self.answer_lookup(&key) {
                        Some(entry) => self.respond(
                            out,
                            &protocol::response_hit(id, &entry),
                        ),
                        None if !q.tune_on_miss => self
                            .respond(out, &protocol::response_miss(id)),
                        None => match tx.try_send(q) {
                            Ok(()) => self.respond(
                                out,
                                &protocol::response_queued(id),
                            ),
                            Err(
                                TrySendError::Full(_)
                                | TrySendError::Disconnected(_),
                            ) => {
                                self.recorder
                                    .incr(Counter::ServeJobsRejected);
                                self.respond(
                                    out,
                                    &protocol::response_busy(id),
                                );
                            }
                        },
                    }
                }
            }
        }
        Ok(ServeExit::Eof)
    }

    /// One miss-triggered tuning job: private session + engine over the
    /// shared cache, warm-started from the startup transfer store,
    /// result promoted into the db.
    fn run_job<W: Write>(&self, q: &Query, out: &Mutex<W>) {
        let key = ScheduleKey::for_layer_on(&q.layer, q.space, &q.target);
        let seed = self.cfg.seed ^ fnv64(key.canonical().as_bytes());
        let trials = q.trials.unwrap_or(self.cfg.miss_trials).max(1);

        let job_recorder = Arc::new(Recorder::new());
        if let Some(sink) = &self.metrics {
            job_recorder.attach_sink(EventSink::from_writer(Box::new(
                BufWriter::with_capacity(64 * 1024, sink.clone()),
            )));
        }
        job_recorder.emit_run_start(
            "serve-job",
            vec![
                ("network", Json::from(q.network.as_str())),
                ("layer", Json::from(q.layer_name.as_str())),
                ("target", Json::from(q.target_name.as_str())),
                ("space", Json::from(q.space.name())),
                ("trials", Json::from(trials)),
                ("seed", Json::from(seed)),
            ],
        );

        let engine = Engine::with_shared_cache(
            EngineConfig {
                jobs: self.cfg.jobs.max(1),
                ..EngineConfig::default()
            },
            Arc::clone(&self.cache),
            Arc::clone(&job_recorder),
        );
        let env = TuningEnv::with_space(q.target.clone(), q.layer, q.space);
        let mut session = LayerSession::new(
            TunerKind::Ml2,
            TunerConfig::default().with_seed(seed).with_trials(trials),
            env,
        );
        if let Some(store) = &self.cfg.transfer {
            if let Some(warm) = store.warm_start_for(
                &q.layer,
                q.space,
                &q.target,
                self.cfg.transfer_cap,
            ) {
                session = session.with_warm_start(warm);
            }
        }
        if let Some(art) = self.meta.get(q.space.name()) {
            session = session.with_meta(Arc::clone(art));
        }
        let trials_run = session.step(&engine, trials);
        job_recorder.emit_run_end();
        self.recorder.add(Counter::TrialsProfiled, trials_run as u64);
        self.recorder.incr(Counter::ServeJobsTuned);

        let best = session.best_cycles().zip(session.best_schedule());
        let Some((cycles, schedule)) = best else {
            self.respond(out, &protocol::response_no_valid(q.id, trials_run));
            return;
        };
        let candidate = ScheduleEntry {
            key,
            version: 0, // assigned by promote
            cycles,
            schedule,
            layer: q.layer_name.clone(),
            target: q.target_name.clone(),
            tuner: session.trace.tuner.clone(),
            trials: trials_run as u64,
        };
        match self.db.promote(candidate) {
            Ok(promotion) => {
                // respond with what the store now holds for the key
                // (on `kept`, that is the better pre-existing entry)
                let stored = self.db.lookup(&key).expect(
                    "promote left no entry for the key",
                );
                self.respond(
                    out,
                    &protocol::response_tuned(
                        q.id, &stored, promotion, trials_run,
                    ),
                );
            }
            Err(e) => {
                eprintln!("ml2tuner serve: promote failed: {e:#}");
                self.respond(
                    out,
                    &protocol::response_error(
                        &protocol::RequestError {
                            id: Some(q.id),
                            message: format!("promote failed: {e:#}"),
                        },
                    ),
                );
            }
        }
    }

    fn respond<W: Write>(&self, out: &Mutex<W>, j: &Json) {
        let mut guard = out.lock().unwrap();
        let _ = writeln!(*guard, "{j}");
        let _ = guard.flush();
    }

    fn stats_json(&self, id: u64) -> Json {
        let snap = self.recorder.snapshot();
        let cache = self.cache.stats();
        let mut o = Json::obj();
        o.set("id", id)
            .set("status", "stats")
            .set("entries", self.db.len())
            .set("skipped_files", self.db.skipped())
            .set("schedule_db_hits", snap.counter(Counter::ScheduleDbHit))
            .set(
                "schedule_db_misses",
                snap.counter(Counter::ScheduleDbMiss),
            )
            .set("serve_jobs_tuned", snap.counter(Counter::ServeJobsTuned))
            .set(
                "serve_jobs_rejected",
                snap.counter(Counter::ServeJobsRejected),
            )
            .set("trials_profiled", snap.counter(Counter::TrialsProfiled))
            .set("compile_cache_hits", cache.hits)
            .set("compile_cache_misses", cache.misses)
            .set("workers", self.cfg.workers.max(1))
            .set("queue_cap", self.cfg.queue_cap.max(1));
        o
    }

    /// Serve TCP clients one at a time (queries are cheap and tuning
    /// happens on the worker pool regardless; a connection holds the
    /// line only for its own request stream). A client's `shutdown`
    /// stops the whole daemon; a disconnect just ends that session.
    pub fn serve_tcp(&self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        eprintln!(
            "ml2tuner serve: listening on {}",
            listener.local_addr().context("reading local addr")?
        );
        for stream in listener.incoming() {
            let stream = stream.context("accepting connection")?;
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".to_string());
            eprintln!("ml2tuner serve: client {peer} connected");
            let reader = std::io::BufReader::new(
                stream.try_clone().context("cloning stream")?,
            );
            match self.run(reader, stream) {
                Ok(ServeExit::Shutdown) => {
                    eprintln!("ml2tuner serve: shutdown requested");
                    return Ok(());
                }
                Ok(ServeExit::Eof) => {
                    eprintln!("ml2tuner serve: client {peer} disconnected");
                }
                Err(e) => {
                    eprintln!("ml2tuner serve: session error: {e:#}");
                }
            }
        }
        Ok(())
    }
}
