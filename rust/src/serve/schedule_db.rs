//! Persistent, versioned best-schedule store — the "never re-tune"
//! memory behind `serve`.
//!
//! A [`ScheduleDb`] is a directory of small JSON files, one per
//! [`ScheduleKey`] — (layer shape, target codegen-signature, space
//! kind). The key deliberately mirrors the compile cache's sharing rule:
//! two targets with the same [`CodegenSig`] (e.g. `zcu102` and `hiband`,
//! which differ only in cycle-model coefficients) produce identical
//! code for identical schedules, so a best schedule found on one is
//! *definitionally* the same artifact on the other and is served to
//! both. The provenance fields ([`ScheduleEntry::target`] et al.) record
//! where a result actually came from; the key records where it applies.
//!
//! Promotion is strictly better-only and versioned: the first result
//! for a key is stored as version 1, a later result replaces it only
//! when its cycle count is strictly lower (bumping the version), and
//! anything else is kept out ([`Promotion::Kept`]) — a worse result can
//! never overwrite a better one, so the store is monotone under any
//! interleaving of writers. Every write goes through a temp file and an
//! atomic `rename`, so readers (and crashed writers) never observe a
//! half-written entry.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::obs::SCHEMA_VERSION;
use crate::tuner::database::LayerMeta;
use crate::util::json::Json;
use crate::vta::config::{CodegenSig, VtaConfig};
use crate::workloads::ConvLayer;

/// FNV-1a 64-bit over a byte string. Used for entry filenames and the
/// per-job RNG seed salt in [`crate::serve::Daemon`] — stable across
/// runs and platforms, unlike `std::hash`.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What a stored best schedule is keyed on: everything that determines
/// whether a schedule artifact is interchangeable between two tuning
/// requests, and nothing that is not (names and cycle-model coefficients
/// are provenance, not identity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduleKey {
    /// Layer shape (the schedule space depends only on this).
    pub shape: LayerMeta,
    /// Compile-shaping subset of the target config.
    pub sig: CodegenSig,
    /// Knob set the schedule was searched in.
    pub space: SpaceKind,
}

impl ScheduleKey {
    /// Build the key for tuning `layer` on `hw` in `space`.
    pub fn for_layer_on(
        layer: &ConvLayer,
        space: SpaceKind,
        hw: &VtaConfig,
    ) -> ScheduleKey {
        ScheduleKey {
            shape: LayerMeta::of(layer),
            sig: hw.codegen_sig(),
            space,
        }
    }

    /// Canonical text form — the hashing/seeding substrate. Field order
    /// is fixed; changing it invalidates every stored filename.
    pub fn canonical(&self) -> String {
        let s = &self.shape;
        let g = &self.sig;
        format!(
            "h{} w{} c{} kc{} kh{} kw{} oh{} ow{} pad{} stride{} | \
             iw{} ww{} aw{} b{} blk{} ib{} wb{} ab{} sh{} | {}",
            s.h,
            s.w,
            s.c,
            s.kc,
            s.kh,
            s.kw,
            s.oh,
            s.ow,
            s.pad,
            s.stride,
            g.log_inp_width,
            g.log_wgt_width,
            g.log_acc_width,
            g.log_batch,
            g.log_block,
            g.log_inp_buff_size,
            g.log_wgt_buff_size,
            g.log_acc_buff_size,
            g.shift,
            self.space.name(),
        )
    }

    /// Stable 64-bit identity: FNV-1a of [`ScheduleKey::canonical`].
    pub fn hash64(&self) -> u64 {
        fnv64(self.canonical().as_bytes())
    }
}

fn sig_to_json(sig: &CodegenSig) -> Json {
    let mut o = Json::obj();
    o.set("log_inp_width", sig.log_inp_width as usize)
        .set("log_wgt_width", sig.log_wgt_width as usize)
        .set("log_acc_width", sig.log_acc_width as usize)
        .set("log_batch", sig.log_batch as usize)
        .set("log_block", sig.log_block as usize)
        .set("log_inp_buff_size", sig.log_inp_buff_size as usize)
        .set("log_wgt_buff_size", sig.log_wgt_buff_size as usize)
        .set("log_acc_buff_size", sig.log_acc_buff_size as usize)
        .set("shift", sig.shift as usize);
    o
}

fn sig_from_json(j: &Json) -> Result<CodegenSig> {
    let geti = |k: &str| -> Result<u32> {
        j.get(k)
            .and_then(Json::as_usize)
            .map(|v| v as u32)
            .ok_or_else(|| anyhow!("codegen_sig missing {k}"))
    };
    Ok(CodegenSig {
        log_inp_width: geti("log_inp_width")?,
        log_wgt_width: geti("log_wgt_width")?,
        log_acc_width: geti("log_acc_width")?,
        log_batch: geti("log_batch")?,
        log_block: geti("log_block")?,
        log_inp_buff_size: geti("log_inp_buff_size")?,
        log_wgt_buff_size: geti("log_wgt_buff_size")?,
        log_acc_buff_size: geti("log_acc_buff_size")?,
        shift: geti("shift")?,
    })
}

/// One stored best-schedule record: the key it answers, the monotone
/// version counter, and the winning result with its provenance.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleEntry {
    /// What this entry answers.
    pub key: ScheduleKey,
    /// 1-based, bumped on every better-only replacement. Assigned by
    /// [`ScheduleDb::promote`]; the value on a candidate is ignored.
    pub version: u64,
    /// Simulated cycle count of `schedule` — the promotion criterion.
    pub cycles: u64,
    /// The best known schedule for the key.
    pub schedule: Schedule,
    /// Provenance: workload layer name the result was tuned as.
    pub layer: String,
    /// Provenance: target the tuning run simulated (entries are served
    /// to every target sharing the key's codegen signature).
    pub target: String,
    /// Provenance: tuner name from the trace (e.g. `ml2tuner-warm`).
    pub tuner: String,
    /// Provenance: trials the producing run spent.
    pub trials: u64,
}

impl ScheduleEntry {
    /// Serialize one entry file.
    pub fn to_json(&self) -> Json {
        let mut best = Json::obj();
        let mut knobs = Json::obj();
        for name in self.key.space.knob_names() {
            knobs.set(name, self.schedule.knob(name).unwrap_or(0));
        }
        best.set("cycles", self.cycles)
            .set("knobs", knobs)
            .set("layer", self.layer.as_str())
            .set("target", self.target.as_str())
            .set("tuner", self.tuner.as_str())
            .set("trials", self.trials);
        let mut o = Json::obj();
        o.set("schema", SCHEMA_VERSION)
            .set("space", self.key.space.name())
            .set("version", self.version)
            .set("shape", self.key.shape.to_json())
            .set("codegen_sig", sig_to_json(&self.key.sig))
            .set("best", best);
        o
    }

    /// Parse one entry file (strict: every knob the declared space
    /// enumerates must be present, same rule as tuning-log loading).
    pub fn from_json(j: &Json) -> Result<ScheduleEntry> {
        let schema = j
            .get("schema")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing schema"))?;
        if schema != SCHEMA_VERSION {
            return Err(anyhow!("unsupported schema {schema}"));
        }
        let space = j
            .get("space")
            .and_then(Json::as_str)
            .and_then(SpaceKind::parse)
            .ok_or_else(|| anyhow!("missing/unknown space"))?;
        let shape = LayerMeta::from_json(
            j.get("shape").ok_or_else(|| anyhow!("missing shape"))?,
        )?;
        let sig = sig_from_json(
            j.get("codegen_sig")
                .ok_or_else(|| anyhow!("missing codegen_sig"))?,
        )?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing version"))?;
        let best = j.get("best").ok_or_else(|| anyhow!("missing best"))?;
        let knobs = best
            .get("knobs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing knobs"))?;
        let mut schedule = Schedule::default();
        for (name, val) in knobs {
            if let Some(v) = val.as_usize() {
                schedule.set_knob(name, v);
            }
        }
        for name in space.knob_names() {
            if knobs.get(*name).and_then(Json::as_usize).is_none() {
                return Err(anyhow!("knob {name} missing or non-numeric"));
            }
        }
        let gets = |k: &str| -> Result<String> {
            best.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("best missing {k}"))
        };
        Ok(ScheduleEntry {
            key: ScheduleKey { shape, sig, space },
            version,
            cycles: best
                .get("cycles")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("best missing cycles"))?,
            schedule,
            layer: gets("layer")?,
            target: gets("target")?,
            tuner: gets("tuner")?,
            trials: best.get("trials").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// What [`ScheduleDb::promote`] did with a candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Promotion {
    /// First result for the key — stored as version 1.
    Inserted,
    /// Strictly better than the stored entry — replaced it, version
    /// bumped; carries the cycles it beat.
    Promoted {
        /// Cycle count of the entry that was replaced.
        prev_cycles: u64,
    },
    /// Not better than the stored entry — store unchanged; carries the
    /// cycles that held the slot.
    Kept {
        /// Cycle count of the entry that kept the slot.
        best_cycles: u64,
    },
}

/// The on-disk best-schedule store: an in-memory index over a directory
/// of entry files, safe to share across the serve daemon's worker
/// threads (interior [`Mutex`]; promotion holds the lock across the
/// compare *and* the file write, so concurrent appenders serialize and
/// better-only stays true under any interleaving).
pub struct ScheduleDb {
    dir: PathBuf,
    entries: Mutex<HashMap<u64, ScheduleEntry>>,
    skipped: usize,
}

impl ScheduleDb {
    /// Open (creating if needed) the store at `dir`, loading every
    /// parseable `*.json` entry. Unparseable files are skipped and
    /// counted ([`ScheduleDb::skipped`]), not fatal — a foreign or
    /// future-schema file must not brick the daemon.
    pub fn open(dir: impl AsRef<Path>) -> Result<ScheduleDb> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| {
            format!("creating schedule db dir {}", dir.display())
        })?;
        let mut entries: HashMap<u64, ScheduleEntry> = HashMap::new();
        let mut skipped = 0usize;
        let mut names: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| {
                format!("reading schedule db dir {}", dir.display())
            })?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|e| e == "json"))
            .collect();
        // sorted so duplicate-key resolution below is order-independent
        names.sort();
        for path in names {
            let parsed = std::fs::read_to_string(&path)
                .ok()
                .and_then(|text| Json::parse(&text).ok())
                .and_then(|j| ScheduleEntry::from_json(&j).ok());
            let Some(entry) = parsed else {
                skipped += 1;
                continue;
            };
            let h = entry.key.hash64();
            // two files for one key can only come from hand-copied
            // stores; better-only applies to loading too
            match entries.get(&h) {
                Some(old) if old.cycles <= entry.cycles => {}
                _ => {
                    entries.insert(h, entry);
                }
            }
        }
        Ok(ScheduleDb { dir, entries: Mutex::new(entries), skipped })
    }

    /// Directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether the store holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Files present at open time that did not parse as entries.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Pure in-memory lookup — no I/O, no compilation, no profiling.
    /// The full-key equality check guards the (astronomically unlikely)
    /// 64-bit hash collision.
    pub fn lookup(&self, key: &ScheduleKey) -> Option<ScheduleEntry> {
        let entries = self.entries.lock().unwrap();
        entries.get(&key.hash64()).filter(|e| e.key == *key).cloned()
    }

    /// All entries, sorted by canonical key text (deterministic across
    /// sessions regardless of insertion order).
    pub fn entries(&self) -> Vec<ScheduleEntry> {
        let entries = self.entries.lock().unwrap();
        let mut all: Vec<ScheduleEntry> = entries.values().cloned().collect();
        all.sort_by_key(|e| e.key.canonical());
        all
    }

    /// Offer a candidate result for its key. Better-only and versioned:
    /// first result for a key is stored as version 1; a strictly lower
    /// cycle count replaces the stored entry and bumps its version; ties
    /// and worse results leave the store untouched. The decision and the
    /// entry-file write happen under one lock, and the file itself is
    /// written temp-then-rename, so a reader of the directory never sees
    /// a torn or regressed entry.
    pub fn promote(&self, mut candidate: ScheduleEntry) -> Result<Promotion> {
        let h = candidate.key.hash64();
        let mut entries = self.entries.lock().unwrap();
        let (promotion, version) = match entries.get(&h) {
            None => (Promotion::Inserted, 1),
            Some(old) if candidate.cycles < old.cycles => (
                Promotion::Promoted { prev_cycles: old.cycles },
                old.version + 1,
            ),
            Some(old) => {
                return Ok(Promotion::Kept { best_cycles: old.cycles })
            }
        };
        candidate.version = version;
        self.write_entry(&candidate)?;
        entries.insert(h, candidate);
        Ok(promotion)
    }

    fn write_entry(&self, entry: &ScheduleEntry) -> Result<()> {
        let name = format!(
            "{}-{:016x}.json",
            entry.key.space.name(),
            entry.key.hash64()
        );
        let path = self.dir.join(&name);
        let tmp = self.dir.join(format!("{name}.tmp"));
        std::fs::write(&tmp, format!("{}\n", entry.to_json().to_string_pretty()))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> ScheduleKey {
        let layer = crate::workloads::network("synth-gemm").unwrap().layers[0];
        ScheduleKey::for_layer_on(
            &layer,
            SpaceKind::Paper,
            &VtaConfig::zcu102(),
        )
    }

    fn entry(cycles: u64) -> ScheduleEntry {
        ScheduleEntry {
            key: key(),
            version: 0,
            cycles,
            schedule: Schedule::default(),
            layer: "gemm".into(),
            target: "zcu102".into(),
            tuner: "ml2tuner".into(),
            trials: 60,
        }
    }

    #[test]
    fn canonical_is_stable_and_space_sensitive() {
        let k = key();
        assert_eq!(k.canonical(), k.canonical());
        let ext = ScheduleKey { space: SpaceKind::Extended, ..k };
        assert_ne!(k.hash64(), ext.hash64());
    }

    #[test]
    fn entry_json_round_trips() {
        let e = ScheduleEntry { version: 3, ..entry(1234) };
        let back =
            ScheduleEntry::from_json(&Json::parse(&e.to_json().to_string())
                .unwrap())
            .unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn entry_json_rejects_missing_knob() {
        let e = entry(99);
        let mut j = e.to_json();
        let knobs = j
            .get("best")
            .and_then(|b| b.get("knobs"))
            .and_then(Json::as_obj)
            .unwrap()
            .clone();
        let mut pruned = Json::obj();
        for (name, val) in &knobs {
            if name != "TH" {
                pruned.set(name, val.clone());
            }
        }
        let mut best = j.get("best").unwrap().clone();
        best.set("knobs", pruned);
        j.set("best", best);
        assert!(ScheduleEntry::from_json(&j).is_err());
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
