//! Line-oriented JSON query protocol for the serve daemon.
//!
//! One request per line in, one (or two — see below) response objects
//! per request out, correlated by a caller-chosen `id`; responses may
//! arrive out of order because miss-triggered tuning jobs complete on
//! worker threads while later lookups are answered synchronously.
//! EXPERIMENTS.md §Serving carries the full request/response field
//! tables with examples; the shapes in short:
//!
//! ```text
//! {"op":"query","id":1,"network":"synth-gemm",
//!  "layer":"gemm_256x256x128","target":"zcu102","space":"paper",
//!  "tune_on_miss":true,"trials":60}
//! {"op":"stats","id":2}
//! {"op":"shutdown"}
//! ```
//!
//! A `query` resolves to a [`crate::serve::ScheduleKey`] and answers
//! `hit` instantly from the db; on a miss it answers `miss` (when
//! `tune_on_miss` is false), or `queued` followed eventually by `tuned`
//! / `no_valid` from the worker that ran the tuning job, or `busy` when
//! admission control rejects the job (queue full).

use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::serve::schedule_db::{Promotion, ScheduleEntry};
use crate::util::json::Json;
use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;

/// A resolved `op: "query"` request: names kept for provenance, plus
/// the workload/target objects the lookup and any fallback tuning job
/// need.
#[derive(Clone, Debug)]
pub struct Query {
    /// Caller-chosen correlation id, echoed on every response.
    pub id: u64,
    /// Requested network name (as registered in [`crate::workloads`]).
    pub network: String,
    /// Requested layer name within the network.
    pub layer_name: String,
    /// Requested target name (as registered in [`crate::vta::targets`]).
    pub target_name: String,
    /// Resolved layer shape.
    pub layer: ConvLayer,
    /// Resolved target config.
    pub target: VtaConfig,
    /// Requested knob space (defaults to `paper`).
    pub space: SpaceKind,
    /// Whether a miss should enqueue a tuning job (defaults to false:
    /// lookups are free, tuning is not).
    pub tune_on_miss: bool,
    /// Per-job trial budget override; `None` uses the daemon default.
    pub trials: Option<usize>,
}

/// One parsed request line.
#[derive(Clone, Debug)]
pub enum Request {
    /// Best-schedule lookup (with optional tuning fallback).
    Query(Query),
    /// Daemon-lifetime counters + store/cache sizes.
    Stats {
        /// Correlation id echoed on the response.
        id: u64,
    },
    /// End this serve session (EOF is equivalent).
    Shutdown,
}

/// Why a request line was rejected; `id` is echoed when the line was
/// parseable enough to carry one, so callers can correlate the error.
#[derive(Clone, Debug)]
pub struct RequestError {
    /// Correlation id, when one could be extracted.
    pub id: Option<u64>,
    /// Human-readable rejection reason.
    pub message: String,
}

impl RequestError {
    fn new(id: Option<u64>, message: impl Into<String>) -> RequestError {
        RequestError { id, message: message.into() }
    }
}

impl Request {
    /// Parse and resolve one request line.
    pub fn parse(line: &str) -> Result<Request, RequestError> {
        let j = Json::parse(line).map_err(|e| {
            RequestError::new(None, format!("malformed JSON: {e}"))
        })?;
        let id = j.get("id").and_then(Json::as_u64);
        let op = j.get("op").and_then(Json::as_str).ok_or_else(|| {
            RequestError::new(id, "missing op")
        })?;
        match op {
            "shutdown" => Ok(Request::Shutdown),
            "stats" => Ok(Request::Stats {
                id: id.ok_or_else(|| {
                    RequestError::new(None, "stats requires id")
                })?,
            }),
            "query" => {
                let id = id.ok_or_else(|| {
                    RequestError::new(None, "query requires id")
                })?;
                let gets = |k: &str| -> Result<&str, RequestError> {
                    j.get(k).and_then(Json::as_str).ok_or_else(|| {
                        RequestError::new(Some(id), format!("missing {k}"))
                    })
                };
                let network = gets("network")?.to_string();
                let layer_name = gets("layer")?.to_string();
                let target_name = gets("target")?.to_string();
                let net =
                    crate::workloads::network(&network).ok_or_else(|| {
                        RequestError::new(
                            Some(id),
                            format!("unknown network '{network}'"),
                        )
                    })?;
                let layer = net.layer(&layer_name).ok_or_else(|| {
                    RequestError::new(
                        Some(id),
                        format!("unknown layer '{layer_name}'"),
                    )
                })?;
                let target = crate::vta::targets::target(&target_name)
                    .ok_or_else(|| {
                        RequestError::new(
                            Some(id),
                            format!("unknown target '{target_name}'"),
                        )
                    })?;
                let space = match j.get("space").and_then(Json::as_str) {
                    None => SpaceKind::Paper,
                    Some(name) => SpaceKind::parse(name).ok_or_else(|| {
                        RequestError::new(
                            Some(id),
                            format!("unknown space '{name}'"),
                        )
                    })?,
                };
                Ok(Request::Query(Query {
                    id,
                    network,
                    layer_name,
                    target_name,
                    layer,
                    target,
                    space,
                    tune_on_miss: j
                        .get("tune_on_miss")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                    trials: j.get("trials").and_then(Json::as_usize),
                }))
            }
            other => Err(RequestError::new(
                id,
                format!("unknown op '{other}'"),
            )),
        }
    }
}

/// Schedule knobs keyed by name (the same layout tuning logs and
/// schedule-db entries use).
pub fn knobs_json(space: SpaceKind, schedule: &Schedule) -> Json {
    let mut knobs = Json::obj();
    for name in space.knob_names() {
        knobs.set(name, schedule.knob(name).unwrap_or(0));
    }
    knobs
}

fn base(id: u64, status: &str) -> Json {
    let mut o = Json::obj();
    o.set("id", id).set("status", status);
    o
}

/// `hit`: stored best schedule, its version, and its provenance.
pub fn response_hit(id: u64, entry: &ScheduleEntry) -> Json {
    let mut o = base(id, "hit");
    o.set("version", entry.version)
        .set("cycles", entry.cycles)
        .set("knobs", knobs_json(entry.key.space, &entry.schedule))
        .set("layer", entry.layer.as_str())
        .set("target", entry.target.as_str())
        .set("tuner", entry.tuner.as_str())
        .set("trials", entry.trials);
    o
}

/// `miss` without fallback: nothing stored, nothing enqueued.
pub fn response_miss(id: u64) -> Json {
    base(id, "miss")
}

/// `queued`: the miss enqueued a tuning job; a `tuned` / `no_valid`
/// response with the same id follows when the job completes.
pub fn response_queued(id: u64) -> Json {
    base(id, "queued")
}

/// `busy`: admission control rejected the tuning job (queue full).
pub fn response_busy(id: u64) -> Json {
    base(id, "busy")
}

/// `tuned`: the fallback job finished with a valid best schedule; says
/// what the store did with it ([`Promotion`]) and the resulting entry.
pub fn response_tuned(
    id: u64,
    entry: &ScheduleEntry,
    promotion: Promotion,
    trials_run: usize,
) -> Json {
    let label = match promotion {
        Promotion::Inserted => "inserted",
        Promotion::Promoted { .. } => "promoted",
        Promotion::Kept { .. } => "kept",
    };
    let mut o = base(id, "tuned");
    o.set("promotion", label)
        .set("version", entry.version)
        .set("cycles", entry.cycles)
        .set("knobs", knobs_json(entry.key.space, &entry.schedule))
        .set("trials_run", trials_run);
    o
}

/// `no_valid`: the fallback job found no valid configuration within its
/// budget; nothing was stored.
pub fn response_no_valid(id: u64, trials_run: usize) -> Json {
    let mut o = base(id, "no_valid");
    o.set("trials_run", trials_run);
    o
}

/// `error`: the request line was rejected.
pub fn response_error(err: &RequestError) -> Json {
    let mut o = Json::obj();
    if let Some(id) = err.id {
        o.set("id", id);
    }
    o.set("status", "error").set("message", err.message.as_str());
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_query_with_defaults() {
        let r = Request::parse(
            r#"{"op":"query","id":7,"network":"synth-gemm",
                "layer":"gemm_256x256x128","target":"zcu102"}"#,
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.id, 7);
        assert_eq!(q.space, SpaceKind::Paper);
        assert!(!q.tune_on_miss);
        assert_eq!(q.trials, None);
    }

    #[test]
    fn rejects_unknowns_with_id_echo() {
        let e = Request::parse(
            r#"{"op":"query","id":9,"network":"nope",
                "layer":"gemm","target":"zcu102"}"#,
        )
        .unwrap_err();
        assert_eq!(e.id, Some(9));
        assert!(e.message.contains("unknown network"));
        let e = Request::parse("not json").unwrap_err();
        assert_eq!(e.id, None);
        let j = response_error(&e);
        assert_eq!(j.get("status").and_then(Json::as_str), Some("error"));
    }

    #[test]
    fn shutdown_and_stats_parse() {
        assert!(matches!(
            Request::parse(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
        assert!(matches!(
            Request::parse(r#"{"op":"stats","id":3}"#).unwrap(),
            Request::Stats { id: 3 }
        ));
    }
}
