//! VGG-16 convolution workloads (Simonyan & Zisserman, 2015).
//!
//! The table lists the *unique* conv shapes of blocks 2–5 at the standard
//! 224×224 input resolution; repeated layers (conv3_3, conv4_3, conv5_2,
//! conv5_3) share a shape with an earlier entry and are deduplicated —
//! the tuner's winning schedule for one instance applies to all of them.
//! The block-1 stem (C = 3) is omitted: like TVM's VTA flow, the testbed
//! requires input channels to be GEMM-block multiples (see
//! `compiler::passes`), and the stem is conventionally run on the host.

use super::resnet18::ConvLayer;

/// VGG-16 blocks 2–5, deduplicated conv shapes (all 3×3, stride 1, pad 1).
pub const LAYERS: [ConvLayer; 7] = [
    ConvLayer { name: "conv2_1", h: 112, w: 112, c: 64, kc: 128, kh: 3,
                kw: 3, oh: 112, ow: 112, pad: 1, stride: 1 },
    ConvLayer { name: "conv2_2", h: 112, w: 112, c: 128, kc: 128, kh: 3,
                kw: 3, oh: 112, ow: 112, pad: 1, stride: 1 },
    ConvLayer { name: "conv3_1", h: 56, w: 56, c: 128, kc: 256, kh: 3,
                kw: 3, oh: 56, ow: 56, pad: 1, stride: 1 },
    // also covers conv3_3
    ConvLayer { name: "conv3_2", h: 56, w: 56, c: 256, kc: 256, kh: 3,
                kw: 3, oh: 56, ow: 56, pad: 1, stride: 1 },
    ConvLayer { name: "conv4_1", h: 28, w: 28, c: 256, kc: 512, kh: 3,
                kw: 3, oh: 28, ow: 28, pad: 1, stride: 1 },
    // also covers conv4_3
    ConvLayer { name: "conv4_2", h: 28, w: 28, c: 512, kc: 512, kh: 3,
                kw: 3, oh: 28, ow: 28, pad: 1, stride: 1 },
    // also covers conv5_2 and conv5_3
    ConvLayer { name: "conv5_1", h: 14, w: 14, c: 512, kc: 512, kh: 3,
                kw: 3, oh: 14, ow: 14, pad: 1, stride: 1 },
];

/// Look up a layer by name (`conv2_1` … `conv5_1`).
pub fn layer(name: &str) -> Option<ConvLayer> {
    LAYERS.iter().copied().find(|l| l.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_consistent() {
        for l in LAYERS {
            assert_eq!(l.computed_out(), (l.oh, l.ow), "{}", l.name);
            assert_eq!(l.c % 16, 0, "{}", l.name);
            assert_eq!(l.kc % 16, 0, "{}", l.name);
        }
    }

    #[test]
    fn deepest_layer_is_the_big_gemm() {
        let (m, k, n) = layer("conv5_1").unwrap().gemm_dims();
        assert_eq!((m, k, n), (196, 4608, 512));
    }
}
