//! The network registry — every workload the tuner knows how to tune.
//!
//! PR 1's `tune-net` scheduler was hard-wired to the ResNet18 table; the
//! registry generalizes the workload layer so `tune-net`, the experiment
//! harnesses, and the transfer warm-start store operate over *any*
//! registered network. A [`Network`] is just a name plus its profiled
//! conv-layer table (cf. paper Table 2a), so adding a workload is one
//! const table + one registry entry.

use super::gemm;
use super::mobilenet;
use super::resnet18::{self, ConvLayer};
use super::vgg16;

/// A registered network: a name and the conv layers the tuner profiles.
#[derive(Clone, Copy, Debug)]
pub struct Network {
    /// Registry name (`--network` argument).
    pub name: &'static str,
    /// One-line description shown by `--list-networks`.
    pub description: &'static str,
    /// The profiled conv-layer table.
    pub layers: &'static [ConvLayer],
}

impl Network {
    /// Look up a layer of this network by name.
    pub fn layer(&self, name: &str) -> Option<ConvLayer> {
        self.layers.iter().copied().find(|l| l.name == name)
    }

    /// Layer names in table order.
    pub fn layer_names(&self) -> Vec<&'static str> {
        self.layers.iter().map(|l| l.name).collect()
    }

    /// Exact MAC count summed over the table.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }
}

/// All registered networks. (A `static`, not a `const`: lookups hand out
/// `&'static Network` borrows of this table.)
pub static NETWORKS: [Network; 4] = [
    Network {
        name: "resnet18",
        description: "ResNet18 profiled convs (paper Table 2a)",
        layers: &resnet18::LAYERS,
    },
    Network {
        name: "vgg16",
        description: "VGG-16 blocks 2-5, deduplicated 3x3 convs",
        layers: &vgg16::LAYERS,
    },
    Network {
        name: "mobilenet",
        description: "MobileNet-style pointwise-heavy body (1x1 convs)",
        layers: &mobilenet::LAYERS,
    },
    Network {
        name: "synth-gemm",
        description: "synthetic GEMM/dense suite (1x1-conv matmuls)",
        layers: &gemm::LAYERS,
    },
];

/// Look up a network by name (a few aliases accepted).
pub fn network(name: &str) -> Option<&'static Network> {
    let canon = match name {
        "resnet-18" => "resnet18",
        "vgg-16" => "vgg16",
        "gemm" | "synth_gemm" => "synth-gemm",
        other => other,
    };
    NETWORKS.iter().find(|n| n.name == canon)
}

/// Registered network names, registry order.
pub fn network_names() -> Vec<&'static str> {
    NETWORKS.iter().map(|n| n.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule;

    #[test]
    fn every_registered_layer_is_consistent() {
        for net in &NETWORKS {
            assert!(!net.layers.is_empty(), "{}", net.name);
            for l in net.layers {
                assert_eq!(l.computed_out(), (l.oh, l.ow), "{}/{}",
                           net.name, l.name);
                assert_eq!(l.c % 16, 0, "{}/{}", net.name, l.name);
                assert_eq!(l.kc % 16, 0, "{}/{}", net.name, l.name);
            }
            let mut names = net.layer_names();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), net.layers.len(),
                       "{}: duplicate layer names", net.name);
        }
    }

    #[test]
    fn every_layer_has_a_tractable_nonempty_space() {
        use crate::compiler::schedule::SpaceKind;
        for net in &NETWORKS {
            for l in net.layers {
                let n = schedule::candidates(l).len();
                assert!(n > 0, "{}/{}: empty space", net.name, l.name);
                assert!(n < 300_000, "{}/{}: space too large ({n})",
                        net.name, l.name);
                // the extended space multiplies by the new-knob radix
                // (2 load-slot × 3 unroll values) on every layer
                let e = schedule::space_for(l, SpaceKind::Extended).len();
                assert_eq!(e, n * 6, "{}/{}", net.name, l.name);
            }
        }
    }

    #[test]
    fn lookup_and_aliases() {
        assert_eq!(network("resnet18").unwrap().layers.len(), 10);
        assert_eq!(network("vgg-16").unwrap().name, "vgg16");
        assert_eq!(network("gemm").unwrap().name, "synth-gemm");
        assert_eq!(network("synth_gemm").unwrap().name, "synth-gemm");
        assert!(network("alexnet").is_none());
    }

    #[test]
    fn layer_lookup_is_scoped_to_the_network() {
        let mob = network("mobilenet").unwrap();
        assert!(mob.layer("pw1").is_some());
        assert!(mob.layer("conv1").is_none());
        let res = network("resnet18").unwrap();
        assert!(res.layer("conv1").is_some());
        assert!(res.layer("pw1").is_none());
    }

    #[test]
    fn total_macs_positive() {
        let macs: Vec<u64> =
            NETWORKS.iter().map(Network::total_macs).collect();
        assert!(macs.iter().all(|&m| m > 0));
    }
}
