//! MobileNet-style pointwise-heavy network (Howard et al., 2017).
//!
//! MobileNet's compute is dominated by 1×1 pointwise convolutions — a
//! sharply different schedule-space regime from the 3×3-heavy ResNet/VGG
//! tables: no input halo, `K = C` exactly, and the `TW·TH` knobs trade
//! directly against channel tiling. The depthwise 3×3 stages are not
//! expressible on the GEMM core (each output channel reads a single input
//! channel), so — as in accelerator deployments that keep depthwise on the
//! vector unit — the table stands in for each stride-2 depthwise stage
//! with a dense 3×3 stride-2 reducer (`red1`, `red2`) and keeps every
//! pointwise conv exactly.

use super::resnet18::ConvLayer;

/// Pointwise-dominated MobileNet-style body: 1×1 convs (`pw*`) plus two
/// dense 3×3 stride-2 reducers standing in for the depthwise downsamples.
pub const LAYERS: [ConvLayer; 8] = [
    ConvLayer { name: "pw1", h: 56, w: 56, c: 64, kc: 128, kh: 1, kw: 1,
                oh: 56, ow: 56, pad: 0, stride: 1 },
    ConvLayer { name: "red1", h: 56, w: 56, c: 128, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 2 },
    ConvLayer { name: "pw2", h: 28, w: 28, c: 128, kc: 256, kh: 1, kw: 1,
                oh: 28, ow: 28, pad: 0, stride: 1 },
    ConvLayer { name: "pw3", h: 28, w: 28, c: 256, kc: 256, kh: 1, kw: 1,
                oh: 28, ow: 28, pad: 0, stride: 1 },
    ConvLayer { name: "red2", h: 28, w: 28, c: 256, kc: 256, kh: 3, kw: 3,
                oh: 14, ow: 14, pad: 1, stride: 2 },
    ConvLayer { name: "pw4", h: 14, w: 14, c: 256, kc: 512, kh: 1, kw: 1,
                oh: 14, ow: 14, pad: 0, stride: 1 },
    ConvLayer { name: "pw5", h: 14, w: 14, c: 512, kc: 512, kh: 1, kw: 1,
                oh: 14, ow: 14, pad: 0, stride: 1 },
    ConvLayer { name: "pw6", h: 7, w: 7, c: 512, kc: 1024, kh: 1, kw: 1,
                oh: 7, ow: 7, pad: 0, stride: 1 },
];

/// Look up a layer by name (`pw1` … `pw6`, `red1`, `red2`).
pub fn layer(name: &str) -> Option<ConvLayer> {
    LAYERS.iter().copied().find(|l| l.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_consistent() {
        for l in LAYERS {
            assert_eq!(l.computed_out(), (l.oh, l.ow), "{}", l.name);
            assert_eq!(l.c % 16, 0, "{}", l.name);
            assert_eq!(l.kc % 16, 0, "{}", l.name);
        }
    }

    #[test]
    fn pointwise_layers_have_no_halo() {
        for l in LAYERS {
            if l.name.starts_with("pw") {
                assert_eq!((l.kh, l.kw, l.pad, l.stride), (1, 1, 0, 1),
                           "{}", l.name);
                // 1×1 GEMM: K is exactly the input channel count
                assert_eq!(l.gemm_dims().1, l.c, "{}", l.name);
            }
        }
    }
}
