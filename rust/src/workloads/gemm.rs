//! Synthetic GEMM / dense suite.
//!
//! A GEMM with dimensions `(M, K, N)` is exactly a 1×1 convolution with
//! `OH·OW = M`, `C = K`, `KC = N` (the im2col mapping of
//! [`ConvLayer::gemm_dims`] with a unit kernel), so transformer-style
//! matmul and MLP workloads ride on the conv pipeline unchanged. The
//! suite spans the aspect-ratio extremes — square, wide-K, tall-M, and a
//! batch-1 dense layer (`M = 1`, the degenerate spatial space) — and is
//! deliberately cheap to profile: CI's smoke-tune job runs `tune-net` on
//! this network.

use super::resnet18::ConvLayer;

/// Synthetic GEMM/dense workloads, named `gemm_MxKxN` / `dense_KxN`.
pub const LAYERS: [ConvLayer; 5] = [
    // square-ish mid-size GEMM
    ConvLayer { name: "gemm_256x256x128", h: 16, w: 16, c: 256, kc: 128,
                kh: 1, kw: 1, oh: 16, ow: 16, pad: 0, stride: 1 },
    // many rows, moderate reduction
    ConvLayer { name: "gemm_1024x128x256", h: 32, w: 32, c: 128, kc: 256,
                kh: 1, kw: 1, oh: 32, ow: 32, pad: 0, stride: 1 },
    // few rows, deep reduction (attention-projection shape)
    ConvLayer { name: "gemm_64x512x512", h: 8, w: 8, c: 512, kc: 512,
                kh: 1, kw: 1, oh: 8, ow: 8, pad: 0, stride: 1 },
    // tall-and-skinny
    ConvLayer { name: "gemm_4096x64x64", h: 64, w: 64, c: 64, kc: 64,
                kh: 1, kw: 1, oh: 64, ow: 64, pad: 0, stride: 1 },
    // batch-1 dense layer: the spatial knobs collapse to 1×1
    ConvLayer { name: "dense_512x1024", h: 1, w: 1, c: 512, kc: 1024,
                kh: 1, kw: 1, oh: 1, ow: 1, pad: 0, stride: 1 },
];

/// Look up a layer by name (`gemm_MxKxN` / `dense_KxN`).
pub fn layer(name: &str) -> Option<ConvLayer> {
    LAYERS.iter().copied().find(|l| l.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_encode_gemm_dims() {
        for l in LAYERS {
            let (m, k, n) = l.gemm_dims();
            if l.name.starts_with("gemm_") {
                let expect = format!("gemm_{m}x{k}x{n}");
                assert_eq!(l.name, expect);
            } else {
                assert_eq!(m, 1, "{}", l.name);
                assert_eq!(l.name, format!("dense_{k}x{n}"));
            }
        }
    }

    #[test]
    fn shapes_consistent() {
        for l in LAYERS {
            assert_eq!(l.computed_out(), (l.oh, l.ow), "{}", l.name);
            assert_eq!(l.c % 16, 0, "{}", l.name);
            assert_eq!(l.kc % 16, 0, "{}", l.name);
        }
    }
}
