//! Workload definitions: the network registry (ResNet18 from paper Table
//! 2a, VGG-16, a MobileNet-style pointwise net, a synthetic GEMM suite)
//! and synthetic generators for tests/ablations.

pub mod gemm;
pub mod mobilenet;
pub mod registry;
pub mod resnet18;
pub mod synth;
pub mod vgg16;

pub use registry::{network, network_names, Network, NETWORKS};
pub use resnet18::ConvLayer;
