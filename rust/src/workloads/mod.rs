//! Workload definitions: the ResNet18 conv layers the paper profiles
//! (Table 2a) and synthetic generators for tests/ablations.

pub mod resnet18;
pub mod synth;

pub use resnet18::ConvLayer;
