//! Synthetic workloads + deterministic tensor data.
//!
//! The paper's layers carry no data dependence (timing and validity are
//! shape/schedule functions), so synthetic int8 tensors from a seeded RNG
//! are sufficient — they only matter for the bit-exactness checks against
//! the AOT golden model.

use super::resnet18::ConvLayer;
use crate::util::rng::Rng;

/// Deterministic int8 input image `(h, w, c)` for a layer.
pub fn input_data(layer: &ConvLayer, seed: u64) -> Vec<i8> {
    let mut r = Rng::new(seed ^ 0x1a9c_37e5);
    (0..layer.input_len()).map(|_| r.i8()).collect()
}

/// Deterministic int8 HWIO weights for a layer.
pub fn weight_data(layer: &ConvLayer, seed: u64) -> Vec<i8> {
    let mut r = Rng::new(seed ^ 0x7b3d_59f1);
    (0..layer.weight_len()).map(|_| r.i8()).collect()
}

/// Random synthetic conv layers (channels kept block multiples) for
/// property tests and generalization experiments.
pub fn random_layer(r: &mut Rng) -> ConvLayer {
    let ksz = *r.choose(&[1usize, 3, 5]);
    let stride = *r.choose(&[1usize, 2]);
    let pad = if ksz == 1 { 0 } else { r.below(ksz / 2 + 1) };
    let c = 16 * (1 + r.below(4)); // 16..64
    let kc = 16 * (1 + r.below(4));
    // choose output size first so every (pad, stride) combination is legal
    let oh = 4 + r.below(25); // 4..28
    let ow = 4 + r.below(25);
    let h = (oh - 1) * stride + ksz - 2 * pad;
    let w = (ow - 1) * stride + ksz - 2 * pad;
    ConvLayer {
        name: "synth",
        h,
        w,
        c,
        kc,
        kh: ksz,
        kw: ksz,
        oh,
        ow,
        pad,
        stride,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn data_deterministic() {
        let l = resnet18::layer("conv5").unwrap();
        assert_eq!(input_data(&l, 7), input_data(&l, 7));
        assert_ne!(input_data(&l, 7), input_data(&l, 8));
        assert_eq!(input_data(&l, 7).len(), l.input_len());
        assert_eq!(weight_data(&l, 7).len(), l.weight_len());
    }

    #[test]
    fn random_layers_are_consistent() {
        let mut r = Rng::new(42);
        for _ in 0..200 {
            let l = random_layer(&mut r);
            assert_eq!(l.computed_out(), (l.oh, l.ow), "{l:?}");
            assert_eq!(l.c % 16, 0);
            assert_eq!(l.kc % 16, 0);
            assert!(l.h >= l.kh.saturating_sub(2 * l.pad));
        }
    }
}
