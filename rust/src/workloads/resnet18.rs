//! Paper Table 2(a): the 10 profiled convolution layers of ResNet18.
//!
//! Kept in sync with `python/compile/model.py::RESNET18_LAYERS` (the AOT
//! golden artifacts are lowered from the Python table; an integration test
//! cross-checks against `artifacts/manifest.json`).

/// One convolution workload (single-image inference, NHWC/HWIO).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    /// Layer name, unique within its network.
    pub name: &'static str,
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Output channels (paper's `KC`).
    pub kc: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Output height.
    pub oh: usize,
    /// Output width.
    pub ow: usize,
    /// Spatial padding.
    pub pad: usize,
    /// Spatial stride.
    pub stride: usize,
}

/// The im2col mapping shared by every conv-shaped view of a workload
/// (`ConvLayer` and the tuning-log `LayerMeta`): `(M, K, N)` from output
/// extent, kernel, and channels.
pub fn im2col_dims(
    oh: usize,
    ow: usize,
    kh: usize,
    kw: usize,
    c: usize,
    kc: usize,
) -> (usize, usize, usize) {
    (oh * ow, kh * kw * c, kc)
}

impl ConvLayer {
    /// GEMM dimensions after im2col: `(M, K, N)`.
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        im2col_dims(self.oh, self.ow, self.kh, self.kw, self.c, self.kc)
    }

    /// Exact MAC count of the convolution.
    pub fn macs(&self) -> u64 {
        let (m, k, n) = self.gemm_dims();
        m as u64 * k as u64 * n as u64
    }

    /// Input tensor element count.
    pub fn input_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Weight tensor element count (HWIO).
    pub fn weight_len(&self) -> usize {
        self.kh * self.kw * self.c * self.kc
    }

    /// Output tensor element count.
    pub fn output_len(&self) -> usize {
        self.oh * self.ow * self.kc
    }

    /// Output spatial size from the conv arithmetic (sanity vs table).
    pub fn computed_out(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad - self.kh) / self.stride + 1,
            (self.w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }
}

/// Paper Table 2(a) — the 10 profiled ResNet18 conv layers.
pub const LAYERS: [ConvLayer; 10] = [
    ConvLayer { name: "conv1", h: 56, w: 56, c: 64, kc: 64, kh: 3, kw: 3,
                oh: 56, ow: 56, pad: 1, stride: 1 },
    ConvLayer { name: "conv2", h: 56, w: 56, c: 64, kc: 128, kh: 1, kw: 1,
                oh: 28, ow: 28, pad: 0, stride: 2 },
    ConvLayer { name: "conv3", h: 56, w: 56, c: 64, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 2 },
    ConvLayer { name: "conv4", h: 28, w: 28, c: 128, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 1 },
    ConvLayer { name: "conv5", h: 28, w: 28, c: 128, kc: 256, kh: 1, kw: 1,
                oh: 14, ow: 14, pad: 0, stride: 2 },
    ConvLayer { name: "conv6", h: 56, w: 56, c: 64, kc: 128, kh: 1, kw: 1,
                oh: 28, ow: 28, pad: 0, stride: 2 },
    ConvLayer { name: "conv7", h: 56, w: 56, c: 64, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 2 },
    ConvLayer { name: "conv8", h: 28, w: 28, c: 128, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 1 },
    ConvLayer { name: "conv9", h: 56, w: 56, c: 64, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 2 },
    ConvLayer { name: "conv10", h: 28, w: 28, c: 128, kc: 128, kh: 3, kw: 3,
                oh: 28, ow: 28, pad: 1, stride: 1 },
];

/// Look up a layer by name (`conv1` … `conv10`).
pub fn layer(name: &str) -> Option<ConvLayer> {
    LAYERS.iter().copied().find(|l| l.name == name)
}

/// Paper Table 2(b): invalidity ratio of configurations per layer under
/// random sampling, as measured on the authors' board (reference series for
/// the table2 experiment; our simulator produces its own column).
pub const PAPER_INVALIDITY: [(&str, f64); 10] = [
    ("conv1", 0.8264),
    ("conv2", 0.7966),
    ("conv3", 0.8057),
    ("conv4", 0.6935),
    ("conv5", 0.5249),
    ("conv6", 0.5249),
    ("conv7", 0.5249),
    ("conv8", 0.5047),
    ("conv9", 0.5047),
    ("conv10", 0.5047),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_2a_output_shapes_consistent() {
        for l in LAYERS {
            assert_eq!(l.computed_out(), (l.oh, l.ow), "{}", l.name);
        }
    }

    #[test]
    fn channels_are_block_multiples() {
        for l in LAYERS {
            assert_eq!(l.c % 16, 0, "{}", l.name);
            assert_eq!(l.kc % 16, 0, "{}", l.name);
        }
    }

    #[test]
    fn conv1_gemm_dims() {
        let (m, k, n) = layer("conv1").unwrap().gemm_dims();
        assert_eq!((m, k, n), (3136, 576, 64));
    }

    #[test]
    fn lookup() {
        assert!(layer("conv10").is_some());
        assert!(layer("conv11").is_none());
    }
}
