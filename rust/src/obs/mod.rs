//! Observability: the telemetry subsystem behind `--metrics-out`,
//! `--quiet`/`-v`, and the `ml2tuner report` subcommand.
//!
//! Four pieces:
//!
//! - [`recorder`] — the always-on [`Recorder`]: atomic counters,
//!   monotonic span timers, and fixed log2-bucket duration histograms,
//!   shared across the `--jobs` worker pool (relaxed atomics, no locks
//!   on hot paths).
//! - [`events`] — the versioned JSONL event schema and [`EventSink`]
//!   (`--metrics-out <file>`): one `run_start` header, one `round`
//!   event per tuning round (stage/cache deltas + model-quality
//!   confusion), one `run_end` trailer. Emission happens only on the
//!   coordinator thread, so event order is deterministic.
//! - [`console`] — the leveled human-output sink (`--quiet`/`-v`).
//! - [`report`] — the `ml2tuner report` aggregator: strict schema
//!   validation plus per-stage, cache, and per-target model-quality
//!   tables.
//!
//! The governing invariant: telemetry observes, never participates. No
//! code in this module touches an rng stream, reorders work, or feeds
//! anything back into tuning — traces stay byte-identical with and
//! without a sink (`tests/telemetry.rs` pins this on both spaces).

pub mod console;
pub mod events;
pub mod recorder;
pub mod report;

pub use events::{
    confusion, EventSink, RoundEvent, RoundScope, VQuality, SCHEMA_VERSION,
};
pub use recorder::{
    Counter, Recorder, Snapshot, Span, Stage, StageTotal, HIST_BUCKETS,
};
