//! `ml2tuner report`: aggregate one or more `--metrics-out` JSONL event
//! files into per-stage time-breakdown, compile-cache, and model-quality
//! tables (per-target rollup, so a fleet run's single file reports each
//! target separately).
//!
//! Parsing is strict on purpose — CI runs `report` over every smoke
//! run's event file as a schema check, so a malformed line, an unknown
//! event, a wrong schema version, or a missing required field is a hard
//! error naming the file and line.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::events::SCHEMA_VERSION;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Required numeric fields of a `round` event (beyond the string
/// identity fields and the optional best/V groups).
const ROUND_NUM_FIELDS: [&str; 14] = [
    "round",
    "trials_new",
    "trials_total",
    "valid_new",
    "crash_new",
    "wrong_new",
    "select_ns",
    "train_ns",
    "sweep_ns",
    "sweep_chunks",
    "compile_ns",
    "profile_ns",
    "cache_hits",
    "cache_misses",
];

const ROUND_STR_FIELDS: [&str; 4] = ["target", "layer", "tuner", "space"];

/// V-group fields: all present or all absent.
const ROUND_V_FIELDS: [&str; 6] =
    ["vetoes", "v_tp", "v_fp", "v_tn", "v_fn", "v_margin"];

/// Prescreen-group fields (tier-0 coarse cut): all present or all
/// absent. Absent on every pre-multi-fidelity event file and on rounds
/// that ran with the prescreen off, so old logs keep validating.
const ROUND_PRESCREEN_FIELDS: [&str; 3] =
    ["prescreened", "survivors", "prescreen_ns"];

/// Profile sub-breakdown fields (timing co-sim vs bounds+hazard inside
/// `check_with`): all present or all absent. Absent on every
/// pre-scratch-arena event file and on rounds that profiled nothing at
/// full fidelity, so old logs keep validating.
const ROUND_CHECK_FIELDS: [&str; 2] = ["timing_ns", "hazard_ns"];

fn num(obj: &Json, key: &str) -> Result<u64> {
    match obj.get(key) {
        Some(Json::Num(n)) if *n >= 0.0 => Ok(*n as u64),
        Some(_) => bail!("field {key:?} is not a non-negative number"),
        None => bail!("missing required field {key:?}"),
    }
}

fn fnum(obj: &Json, key: &str) -> Result<f64> {
    obj.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing numeric field {key:?}"))
}

fn string<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
    obj.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field {key:?}"))
}

/// Parse and schema-validate one JSONL line; returns the event object.
pub fn validate_line(line: &str) -> Result<Json> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    if j.as_obj().is_none() {
        bail!("event line is not a JSON object");
    }
    let schema = num(&j, "schema")?;
    if schema != SCHEMA_VERSION {
        bail!("unsupported schema version {schema} (expected {SCHEMA_VERSION})");
    }
    match string(&j, "event")? {
        "round" => {
            for k in ROUND_STR_FIELDS {
                string(&j, k)?;
            }
            for k in ROUND_NUM_FIELDS {
                num(&j, k)?;
            }
            let n_v =
                ROUND_V_FIELDS.iter().filter(|k| j.get(k).is_some()).count();
            if n_v != 0 && n_v != ROUND_V_FIELDS.len() {
                bail!(
                    "partial V-quality group: expected all or none of \
                     {ROUND_V_FIELDS:?}"
                );
            }
            if n_v > 0 {
                for k in &ROUND_V_FIELDS[..5] {
                    num(&j, k)?;
                }
                fnum(&j, "v_margin")?;
            }
            let n_ps = ROUND_PRESCREEN_FIELDS
                .iter()
                .filter(|k| j.get(k).is_some())
                .count();
            if n_ps != 0 && n_ps != ROUND_PRESCREEN_FIELDS.len() {
                bail!(
                    "partial prescreen group: expected all or none of \
                     {ROUND_PRESCREEN_FIELDS:?}"
                );
            }
            if n_ps > 0 {
                for k in ROUND_PRESCREEN_FIELDS {
                    num(&j, k)?;
                }
            }
            let n_ck = ROUND_CHECK_FIELDS
                .iter()
                .filter(|k| j.get(k).is_some())
                .count();
            if n_ck != 0 && n_ck != ROUND_CHECK_FIELDS.len() {
                bail!(
                    "partial profile-breakdown group: expected all or \
                     none of {ROUND_CHECK_FIELDS:?}"
                );
            }
            if n_ck > 0 {
                for k in ROUND_CHECK_FIELDS {
                    num(&j, k)?;
                }
            }
        }
        "run_start" => {
            string(&j, "cmd")?;
        }
        "run_end" => {
            num(&j, "compile_cache_hits")?;
            num(&j, "compile_cache_misses")?;
            num(&j, "trials_profiled")?;
            let stages = j
                .get("stages")
                .and_then(Json::as_obj)
                .context("missing \"stages\" object")?;
            for (name, st) in stages {
                num(st, "count").with_context(|| format!("stage {name:?}"))?;
                num(st, "total_ns")
                    .with_context(|| format!("stage {name:?}"))?;
            }
        }
        other => bail!("unknown event type {other:?}"),
    }
    Ok(j)
}

/// Per-target model-quality rollup.
#[derive(Clone, Debug, Default)]
pub struct TargetAgg {
    /// Round events seen for this target.
    pub rounds: u64,
    /// Trials profiled.
    pub trials: u64,
    /// Trials that profiled valid.
    pub valid: u64,
    /// Trials that crash-faulted.
    pub crash: u64,
    /// Trials that produced wrong output.
    pub wrong: u64,
    /// Candidates ranked by the tier-0 coarse estimator.
    pub prescreened: u64,
    /// Prescreened candidates that went on to full profiling.
    pub survivors: u64,
    /// Candidates model V filtered out before profiling.
    pub vetoes: u64,
    /// V predicted valid, profiled valid.
    pub tp: u64,
    /// V predicted valid, profiled invalid.
    pub fp: u64,
    /// V predicted invalid, profiled invalid.
    pub tn: u64,
    /// V predicted invalid, profiled valid.
    pub fn_: u64,
    /// Rounds that carried a V-quality group.
    pub v_rounds: u64,
    /// Last-seen `(trials_to_best, best_cycles)` per layer — the final
    /// round event per layer holds the run's samples-to-best.
    pub per_layer_best: BTreeMap<String, (Option<u64>, Option<u64>)>,
}

impl TargetAgg {
    /// V precision: of the candidates V passed, how many profiled valid.
    pub fn precision(&self) -> Option<f64> {
        let denom = self.tp + self.fp;
        (denom > 0).then(|| self.tp as f64 / denom as f64)
    }

    /// V recall: of the actually-valid candidates, how many V passed.
    pub fn recall(&self) -> Option<f64> {
        let denom = self.tp + self.fn_;
        (denom > 0).then(|| self.tp as f64 / denom as f64)
    }

    /// Negative predictive value of V's veto over the profiled sample;
    /// defaults to 1.0 when no vetoed-then-profiled trials exist.
    pub fn npv(&self) -> f64 {
        let denom = self.tn + self.fn_;
        if denom > 0 {
            self.tn as f64 / denom as f64
        } else {
            1.0
        }
    }

    /// Estimated invalid profiling attempts avoided: vetoes scaled by
    /// how often a veto is right (NPV) — the paper's "60.8% fewer
    /// invalid profiling attempts" measured continuously.
    pub fn invalid_avoided(&self) -> f64 {
        self.vetoes as f64 * self.npv()
    }

    /// Mean samples-to-best over layers that reached a valid best.
    pub fn mean_trials_to_best(&self) -> Option<f64> {
        let known: Vec<u64> = self
            .per_layer_best
            .values()
            .filter_map(|(ttb, _)| *ttb)
            .collect();
        (!known.is_empty()).then(|| {
            known.iter().sum::<u64>() as f64 / known.len() as f64
        })
    }
}

/// Aggregate over every parsed event file.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Event files parsed.
    pub files: usize,
    /// `run_start` lines seen.
    pub runs: u64,
    /// Round events seen.
    pub rounds: u64,
    /// Wall time in candidate selection (train/sweep/compile inclusive).
    pub select_ns: u64,
    /// Wall time training models.
    pub train_ns: u64,
    /// Wall time sweeping candidates through the models.
    pub sweep_ns: u64,
    /// Wall time compiling (model A features + profiling prep).
    pub compile_ns: u64,
    /// Wall time profiling on the simulator.
    pub profile_ns: u64,
    /// Wall time in the tier-0 coarse prescreen (inside selection).
    pub prescreen_ns: u64,
    /// Worker CPU time in the timing co-simulation inside profiling
    /// (per-trial sub-span; can exceed `profile_ns` wall at `--jobs`>1).
    pub timing_ns: u64,
    /// Worker CPU time in the bounds+hazard passes inside profiling.
    pub hazard_ns: u64,
    /// Candidates ranked at tier 0 across all rounds.
    pub prescreened: u64,
    /// Tier-0 survivors that went on to full profiling.
    pub survivors: u64,
    /// Parallel sweep chunks dispatched.
    pub sweep_chunks: u64,
    /// Trees appended by warm-continuation fits (incremental training
    /// and meta adaptation) — from `run_end` trailers; 0 on old logs.
    pub trees_appended: u64,
    /// Model fits that adapted a corpus-trained meta base (`--meta`).
    pub meta_adapted: u64,
    /// Compile-cache hits.
    pub cache_hits: u64,
    /// Compile-cache misses.
    pub cache_misses: u64,
    /// True once a `run_end` supplied lifetime cache totals (otherwise
    /// the cache numbers are summed round deltas).
    pub cache_from_run_end: bool,
    /// Per-target rollups, keyed by target name.
    pub targets: BTreeMap<String, TargetAgg>,
}

impl Report {
    fn add_round(&mut self, j: &Json) -> Result<()> {
        self.rounds += 1;
        self.select_ns += num(j, "select_ns")?;
        self.train_ns += num(j, "train_ns")?;
        self.sweep_ns += num(j, "sweep_ns")?;
        self.compile_ns += num(j, "compile_ns")?;
        self.profile_ns += num(j, "profile_ns")?;
        self.sweep_chunks += num(j, "sweep_chunks")?;
        let round_prescreened = if j.get("prescreened").is_some() {
            self.prescreen_ns += num(j, "prescreen_ns")?;
            self.prescreened += num(j, "prescreened")?;
            self.survivors += num(j, "survivors")?;
            (num(j, "prescreened")?, num(j, "survivors")?)
        } else {
            (0, 0)
        };
        if j.get("timing_ns").is_some() {
            self.timing_ns += num(j, "timing_ns")?;
            self.hazard_ns += num(j, "hazard_ns")?;
        }
        if !self.cache_from_run_end {
            self.cache_hits += num(j, "cache_hits")?;
            self.cache_misses += num(j, "cache_misses")?;
        }
        let target = string(j, "target")?.to_string();
        let t = self.targets.entry(target).or_default();
        t.rounds += 1;
        t.trials += num(j, "trials_new")?;
        t.valid += num(j, "valid_new")?;
        t.crash += num(j, "crash_new")?;
        t.wrong += num(j, "wrong_new")?;
        t.prescreened += round_prescreened.0;
        t.survivors += round_prescreened.1;
        if j.get("vetoes").is_some() {
            t.v_rounds += 1;
            t.vetoes += num(j, "vetoes")?;
            t.tp += num(j, "v_tp")?;
            t.fp += num(j, "v_fp")?;
            t.tn += num(j, "v_tn")?;
            t.fn_ += num(j, "v_fn")?;
        }
        let layer = string(j, "layer")?.to_string();
        let ttb = j.get("trials_to_best").and_then(Json::as_f64);
        let best = j.get("best_cycles").and_then(Json::as_f64);
        t.per_layer_best
            .insert(layer, (ttb.map(|v| v as u64), best.map(|v| v as u64)));
        Ok(())
    }

    fn add_run_end(&mut self, j: &Json) -> Result<()> {
        // Lifetime totals are authoritative over summed round deltas
        // (they also cover cache traffic outside any round).
        if !self.cache_from_run_end {
            self.cache_from_run_end = true;
            self.cache_hits = 0;
            self.cache_misses = 0;
        }
        self.cache_hits += num(j, "compile_cache_hits")?;
        self.cache_misses += num(j, "compile_cache_misses")?;
        // incremental-training counters: absent on pre-meta logs, which
        // must keep validating, so both are optional reads
        if j.get("trees_appended").is_some() {
            self.trees_appended += num(j, "trees_appended")?;
        }
        if j.get("meta_adapted").is_some() {
            self.meta_adapted += num(j, "meta_adapted")?;
        }
        Ok(())
    }

    /// Wall time outside train/sweep/prescreen/A-compile but inside
    /// selection (feature building, ranking walks, bookkeeping).
    pub fn select_other_ns(&self) -> u64 {
        self.select_ns
            .saturating_sub(self.train_ns)
            .saturating_sub(self.sweep_ns)
            .saturating_sub(self.compile_ns)
            .saturating_sub(self.prescreen_ns)
    }

    /// Total tracked wall time (selection + profiling).
    pub fn total_ns(&self) -> u64 {
        self.select_ns + self.profile_ns
    }

    /// Total compile-cache lookups (hits + misses).
    pub fn cache_lookups(&self) -> u64 {
        self.cache_hits + self.cache_misses
    }

    /// Render the human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "telemetry report: {} file(s), {} run(s), {} round event(s)\n\n",
            self.files, self.runs, self.rounds
        );

        out.push_str("per-stage time breakdown (coordinator wall time):\n");
        let total = self.total_ns().max(1) as f64;
        let mut t = Table::new(&["stage", "time", "share"]);
        let mut rows: Vec<(&str, u64)> = vec![
            ("train (P/V/A)", self.train_ns),
            ("score-sweep", self.sweep_ns),
            ("compile (A-stage pool)", self.compile_ns),
        ];
        if self.prescreened > 0 {
            rows.push(("prescreen (tier 0)", self.prescreen_ns));
        }
        rows.push(("select-other", self.select_other_ns()));
        rows.push(("profile", self.profile_ns));
        for (name, ns) in rows {
            t.row(&[
                name.to_string(),
                fmt_ns(ns),
                format!("{:.1}%", ns as f64 / total * 100.0),
            ]);
        }
        t.row(&["total".to_string(), fmt_ns(self.total_ns()), "100%".into()]);
        out.push_str(&t.render());
        if self.sweep_chunks > 0 {
            out.push_str(&format!(
                "score-sweep chunks: {} (worker CPU time, not wall)\n",
                self.sweep_chunks
            ));
        }
        if self.timing_ns + self.hazard_ns > 0 {
            out.push_str(&format!(
                "profile breakdown: timing sim {} + bounds/hazard {} \
                 (worker CPU time; rest of profile is codegen + \
                 bookkeeping)\n",
                fmt_ns(self.timing_ns),
                fmt_ns(self.hazard_ns),
            ));
        }
        if self.prescreened > 0 {
            out.push_str(&format!(
                "tier-0 prescreen: {} candidates -> {} survivors \
                 ({:.1}% culled); tier-0 time {} vs tier-1 profile {}\n",
                self.prescreened,
                self.survivors,
                self.prescreened.saturating_sub(self.survivors) as f64
                    / self.prescreened as f64
                    * 100.0,
                fmt_ns(self.prescreen_ns),
                fmt_ns(self.profile_ns),
            ));
        }
        if self.trees_appended > 0 || self.meta_adapted > 0 {
            out.push_str(&format!(
                "incremental training: {} trees appended by \
                 continuation; {} meta-adapted fits\n",
                self.trees_appended, self.meta_adapted,
            ));
        }

        out.push('\n');
        let lookups = self.cache_lookups();
        if lookups > 0 {
            out.push_str(&format!(
                "compile cache: {} hits / {} lookups ({:.1}% hit rate{})\n",
                self.cache_hits,
                lookups,
                self.cache_hits as f64 / lookups as f64 * 100.0,
                if self.cache_from_run_end { "" } else {
                    "; summed from round deltas — no run_end event"
                },
            ));
        } else {
            out.push_str("compile cache: no lookups recorded\n");
        }

        out.push_str("\nmodel quality (per target):\n");
        let mut mt = Table::new(&[
            "target",
            "rounds",
            "trials",
            "invalid%",
            "vetoes",
            "V prec",
            "V recall",
            "invalid avoided",
            "trials-to-best",
        ]);
        for (target, agg) in &self.targets {
            let invalid = agg.crash + agg.wrong;
            let inv_pct = if agg.trials > 0 {
                format!("{:.1}%", invalid as f64 / agg.trials as f64 * 100.0)
            } else {
                "-".into()
            };
            let opt = |v: Option<f64>| match v {
                Some(x) => f(x, 3),
                None => "-".into(),
            };
            let avoided = if agg.v_rounds > 0 {
                format!("~{:.0}", agg.invalid_avoided())
            } else {
                "-".into()
            };
            let ttb = match agg.mean_trials_to_best() {
                Some(m) => format!("{m:.1}"),
                None => "-".into(),
            };
            mt.row(&[
                target.clone(),
                agg.rounds.to_string(),
                agg.trials.to_string(),
                inv_pct,
                agg.vetoes.to_string(),
                opt(agg.precision()),
                opt(agg.recall()),
                avoided,
                ttb,
            ]);
        }
        out.push_str(&mt.render());
        if self.prescreened > 0 {
            out.push_str("\nmulti-fidelity (per target):\n");
            let mut pt =
                Table::new(&["target", "prescreened", "survivors",
                             "survival%"]);
            for (target, agg) in &self.targets {
                if agg.prescreened == 0 {
                    continue;
                }
                pt.row(&[
                    target.clone(),
                    agg.prescreened.to_string(),
                    agg.survivors.to_string(),
                    format!("{:.1}%",
                            agg.survivors as f64
                                / agg.prescreened as f64
                                * 100.0),
                ]);
            }
            out.push_str(&pt.render());
        }
        out.push_str(
            "invalid avoided = vetoes x NPV (NPV = tn/(tn+fn) over \
             vetoed-then-profiled fallback trials; 1.0 when none were \
             profiled). trials-to-best = mean over layers of the final \
             samples-to-best-so-far.\n",
        );
        out
    }
}

/// Parse + validate + aggregate a set of event files.
pub fn aggregate<P: AsRef<std::path::Path>>(paths: &[P]) -> Result<Report> {
    let mut report = Report { files: paths.len(), ..Report::default() };
    for path in paths {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut saw_event = false;
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = validate_line(line).with_context(|| {
                format!("{}:{}", path.display(), lineno + 1)
            })?;
            saw_event = true;
            match j.get("event").and_then(Json::as_str) {
                Some("round") => report.add_round(&j).with_context(|| {
                    format!("{}:{}", path.display(), lineno + 1)
                })?,
                Some("run_start") => report.runs += 1,
                Some("run_end") => {
                    report.add_run_end(&j).with_context(|| {
                        format!("{}:{}", path.display(), lineno + 1)
                    })?
                }
                _ => unreachable!("validate_line admits only known events"),
            }
        }
        if !saw_event {
            bail!("{}: no events (empty or blank file)", path.display());
        }
    }
    Ok(report)
}

/// Human-scale duration formatting (ns → us/ms/s).
pub fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.5us");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_200_000_000), "3.20s");
    }

    #[test]
    fn validate_rejects_malformed() {
        for bad in [
            "not json",
            "[1,2]",
            r#"{"event":"round"}"#,                       // no schema
            r#"{"schema":2,"event":"run_start","cmd":"x"}"#, // wrong version
            r#"{"schema":1,"event":"mystery"}"#,          // unknown event
            r#"{"schema":1}"#,                            // no event
        ] {
            assert!(validate_line(bad).is_err(), "{bad}");
        }
        assert!(
            validate_line(r#"{"schema":1,"event":"run_start","cmd":"tune"}"#)
                .is_ok()
        );
    }

    #[test]
    fn partial_v_group_rejected() {
        // a round line with vetoes but no confusion fields
        let mut j = Json::obj();
        j.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            j.set(k, "x");
        }
        for k in ROUND_NUM_FIELDS {
            j.set(k, 1u64);
        }
        assert!(validate_line(&j.to_string()).is_ok());
        j.set("vetoes", 5u64);
        assert!(validate_line(&j.to_string()).is_err());
        j.set("v_tp", 1u64)
            .set("v_fp", 1u64)
            .set("v_tn", 1u64)
            .set("v_fn", 1u64)
            .set("v_margin", 0.25);
        assert!(validate_line(&j.to_string()).is_ok());
    }

    #[test]
    fn partial_prescreen_group_rejected() {
        // PR-6/7 event files carry no prescreen fields — they must keep
        // validating (schema stays 1), while a partial group is a hard
        // error and a complete one passes
        let mut j = Json::obj();
        j.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            j.set(k, "x");
        }
        for k in ROUND_NUM_FIELDS {
            j.set(k, 1u64);
        }
        assert!(validate_line(&j.to_string()).is_ok(),
                "legacy round line must stay valid");
        j.set("prescreened", 80u64);
        assert!(validate_line(&j.to_string()).is_err());
        j.set("survivors", 20u64);
        assert!(validate_line(&j.to_string()).is_err());
        j.set("prescreen_ns", 4200u64);
        assert!(validate_line(&j.to_string()).is_ok());
    }

    #[test]
    fn partial_profile_breakdown_group_rejected() {
        // pre-scratch-arena event files carry neither field — they must
        // keep validating (schema stays 1); a partial group is a hard
        // error and a complete one passes
        let mut j = Json::obj();
        j.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            j.set(k, "x");
        }
        for k in ROUND_NUM_FIELDS {
            j.set(k, 1u64);
        }
        assert!(validate_line(&j.to_string()).is_ok(),
                "legacy round line must stay valid");
        j.set("timing_ns", 900u64);
        assert!(validate_line(&j.to_string()).is_err());
        j.set("hazard_ns", 350u64);
        assert!(validate_line(&j.to_string()).is_ok());
    }

    #[test]
    fn profile_breakdown_aggregates_and_renders() {
        let mut j = Json::obj();
        j.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            j.set(k, "zcu102");
        }
        for k in ROUND_NUM_FIELDS {
            j.set(k, 2u64);
        }
        j.set("timing_ns", 900u64).set("hazard_ns", 350u64);
        let mut r = Report::default();
        r.add_round(&j).unwrap();
        r.add_round(&j).unwrap();
        assert_eq!((r.timing_ns, r.hazard_ns), (1800, 700));
        assert!(r.render().contains("profile breakdown:"));
        // a report without the group renders no breakdown line
        let mut plain = Json::obj();
        plain.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            plain.set(k, "zcu102");
        }
        for k in ROUND_NUM_FIELDS {
            plain.set(k, 2u64);
        }
        let mut cold = Report::default();
        cold.add_round(&plain).unwrap();
        assert!(!cold.render().contains("profile breakdown:"));
    }

    #[test]
    fn prescreen_fields_aggregate_into_the_report() {
        let mut j = Json::obj();
        j.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            j.set(k, "zcu102");
        }
        for k in ROUND_NUM_FIELDS {
            j.set(k, 2u64);
        }
        j.set("prescreened", 80u64)
            .set("survivors", 20u64)
            .set("prescreen_ns", 4200u64);
        let mut r = Report::default();
        r.add_round(&j).unwrap();
        r.add_round(&j).unwrap();
        assert_eq!(r.prescreened, 160);
        assert_eq!(r.survivors, 40);
        assert_eq!(r.prescreen_ns, 8400);
        let t = &r.targets["zcu102"];
        assert_eq!((t.prescreened, t.survivors), (160, 40));
        // prescreen time is carved out of select-other
        assert_eq!(r.select_other_ns(),
                   r.select_ns
                       .saturating_sub(r.train_ns)
                       .saturating_sub(r.sweep_ns)
                       .saturating_sub(r.compile_ns)
                       .saturating_sub(8400));
        let text = r.render();
        assert!(text.contains("prescreen (tier 0)"));
        assert!(text.contains("multi-fidelity (per target):"));
        // a report with no prescreen rounds renders none of it
        let mut plain = Json::obj();
        plain.set("schema", 1u64).set("event", "round");
        for k in ROUND_STR_FIELDS {
            plain.set(k, "zcu102");
        }
        for k in ROUND_NUM_FIELDS {
            plain.set(k, 2u64);
        }
        let mut cold = Report::default();
        cold.add_round(&plain).unwrap();
        let text = cold.render();
        assert!(!text.contains("prescreen"));
    }

    #[test]
    fn target_agg_metrics() {
        let agg = TargetAgg {
            tp: 6,
            fp: 2,
            tn: 3,
            fn_: 1,
            vetoes: 10,
            v_rounds: 1,
            ..TargetAgg::default()
        };
        assert_eq!(agg.precision(), Some(0.75));
        assert_eq!(agg.recall(), Some(6.0 / 7.0));
        assert_eq!(agg.npv(), 0.75);
        assert_eq!(agg.invalid_avoided(), 7.5);
        // no vetoed trials profiled → NPV defaults to 1.0
        let blind = TargetAgg { vetoes: 4, v_rounds: 1, ..TargetAgg::default() };
        assert_eq!(blind.npv(), 1.0);
        assert_eq!(blind.invalid_avoided(), 4.0);
        assert_eq!(blind.precision(), None);
    }
}
