//! Leveled console sink: the single place human-facing progress output
//! goes through, so `--quiet`/`-v` act uniformly across `tune`,
//! `tune-net`, and `tune-fleet`.
//!
//! Three levels: `Quiet` (results only), `Normal` (default: results +
//! progress), `Verbose` (adds per-grant scheduler lines). The level is
//! a process-global atomic — set once at CLI startup, read everywhere —
//! because threading a handle through every tuning loop would couple
//! the tuner API to presentation concerns.

use std::sync::atomic::{AtomicU8, Ordering};

/// Console verbosity, ordered `Quiet < Normal < Verbose`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// `--quiet`: only final results and errors.
    Quiet = 0,
    /// Default: progress notes + results.
    Normal = 1,
    /// `-v`: adds per-grant / per-step detail.
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Normal as u8);

/// Set the process-global console level (CLI startup).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current process-global console level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        2 => Level::Verbose,
        _ => Level::Normal,
    }
}

/// Progress note — suppressed by `--quiet`.
pub fn info(msg: impl AsRef<str>) {
    if level() >= Level::Normal {
        println!("{}", msg.as_ref());
    }
}

/// Detail line — printed only with `-v`.
pub fn verbose(msg: impl AsRef<str>) {
    if level() >= Level::Verbose {
        println!("{}", msg.as_ref());
    }
}

/// Final result — always printed, even under `--quiet`.
pub fn result(msg: impl AsRef<str>) {
    println!("{}", msg.as_ref());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_round_trips() {
        // Tests run in one process; restore the default when done.
        set_level(Level::Quiet);
        assert_eq!(level(), Level::Quiet);
        set_level(Level::Verbose);
        assert_eq!(level(), Level::Verbose);
        set_level(Level::Normal);
        assert_eq!(level(), Level::Normal);
        assert!(Level::Quiet < Level::Normal && Level::Normal < Level::Verbose);
    }
}
