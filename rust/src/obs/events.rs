//! JSONL event stream: schema, sink, and the per-round event builder.
//!
//! Every line is a self-contained JSON object carrying
//! `"schema": SCHEMA_VERSION` and an `"event"` discriminator
//! (`run_start` / `round` / `run_end` — see EXPERIMENTS.md
//! §Observability for the field tables). All emission happens on the
//! coordinator thread between rounds, so line order is deterministic;
//! worker threads only touch the recorder's atomics.

use std::io::Write;
use std::path::Path;

use super::recorder::{Counter, Recorder, Snapshot, Stage};
use crate::util::json::Json;

/// Version stamped on every event line. Bump when a field is renamed,
/// removed, or changes meaning; `report` refuses other versions.
pub const SCHEMA_VERSION: u64 = 1;

/// Buffered, line-flushed JSONL writer. Event rate is one line per
/// round, so a flush per line is cheap and keeps partially-written
/// files valid if the run is killed.
pub struct EventSink {
    out: Box<dyn Write + Send>,
}

impl EventSink {
    /// Create (truncate) a JSONL file sink at `path`.
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<EventSink> {
        let file = std::fs::File::create(path)?;
        Ok(EventSink::from_writer(Box::new(std::io::BufWriter::new(file))))
    }

    /// Sink over any open stream (tests use in-memory buffers).
    pub fn from_writer(out: Box<dyn Write + Send>) -> EventSink {
        EventSink { out }
    }

    /// Best-effort write: I/O errors are dropped so telemetry can never
    /// fail (or perturb) the run it is observing.
    pub(crate) fn write_event(&mut self, event: &Json) {
        let _ = writeln!(self.out, "{event}");
        let _ = self.out.flush();
    }
}

/// Model-V quality numbers for one round: veto count plus the confusion
/// of V's verdict (at the run's `v_margin`) over the trials that were
/// actually profiled this round.
#[derive(Clone, Debug, PartialEq)]
pub struct VQuality {
    /// Candidates V filtered out this round.
    pub vetoes: u64,
    /// Predicted-valid, actually valid.
    pub tp: u64,
    /// Predicted-valid, actually invalid.
    pub fp: u64,
    /// Predicted-invalid, actually invalid.
    pub tn: u64,
    /// Predicted-invalid, actually valid.
    pub fn_: u64,
    /// Margin threshold the verdicts were taken at.
    pub v_margin: f64,
}

/// Confusion of predicted validity (`margin > v_margin`) against actual
/// profiled validity, zipped pairwise: `(tp, fp, tn, fn)`.
pub fn confusion(
    margins: &[f64],
    v_margin: f64,
    actual_valid: &[bool],
) -> (u64, u64, u64, u64) {
    let (mut tp, mut fp, mut tn, mut fn_) = (0, 0, 0, 0);
    for (&m, &valid) in margins.iter().zip(actual_valid) {
        match (m > v_margin, valid) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, false) => tn += 1,
            (false, true) => fn_ += 1,
        }
    }
    (tp, fp, tn, fn_)
}

/// One per-round event, built by the tuning loops (`tuner::round_event`)
/// and serialized together with the round's recorder delta.
#[derive(Clone, Debug)]
pub struct RoundEvent {
    /// Target name the round profiled on.
    pub target: String,
    /// Layer being tuned.
    pub layer: String,
    /// Tuner name (`ml2tuner` / `tvm-approach` / `random`).
    pub tuner: String,
    /// Knob-space name the round searched.
    pub space: String,
    /// 1-based round number within this layer's tuning stream.
    pub round: u64,
    /// Trials profiled this round.
    pub trials_new: u64,
    /// Cumulative trials profiled.
    pub trials_total: u64,
    /// Valid results this round.
    pub valid_new: u64,
    /// Crash-faulted results this round.
    pub crash_new: u64,
    /// Wrong-output results this round.
    pub wrong_new: u64,
    /// Best cycle count so far, if any valid result exists.
    pub best_cycles: Option<u64>,
    /// 1-based trial index that first reached `best_cycles`
    /// ("samples to best-so-far").
    pub trials_to_best: Option<u64>,
    /// Present only on rounds where model V was trained and filtering.
    pub v: Option<VQuality>,
}

impl RoundEvent {
    /// Serialize, folding in the round's stage/cache deltas.
    pub fn to_json(&self, delta: &Snapshot) -> Json {
        let mut o = Json::obj();
        o.set("schema", SCHEMA_VERSION)
            .set("event", "round")
            .set("target", self.target.as_str())
            .set("layer", self.layer.as_str())
            .set("tuner", self.tuner.as_str())
            .set("space", self.space.as_str())
            .set("round", self.round)
            .set("trials_new", self.trials_new)
            .set("trials_total", self.trials_total)
            .set("valid_new", self.valid_new)
            .set("crash_new", self.crash_new)
            .set("wrong_new", self.wrong_new)
            .set("select_ns", delta.stage(Stage::Select).total_ns)
            .set("train_ns", delta.stage(Stage::Train).total_ns)
            .set("sweep_ns", delta.stage(Stage::Sweep).total_ns)
            .set("sweep_chunks", delta.stage(Stage::SweepChunk).count)
            .set("compile_ns", delta.stage(Stage::Compile).total_ns)
            .set("profile_ns", delta.stage(Stage::Profile).total_ns)
            .set("cache_hits", delta.counter(Counter::CompileCacheHit))
            .set("cache_misses", delta.counter(Counter::CompileCacheMiss));
        // prescreen group: present only on rounds that ran the tier-0
        // cut, so prescreen-off runs serialize byte-identically to the
        // pre-multi-fidelity schema (still version 1, additive fields)
        let prescreened = delta.counter(Counter::CandidatesPrescreened);
        if prescreened > 0 {
            o.set("prescreened", prescreened)
                .set("survivors",
                     delta.counter(Counter::PrescreenSurvivors))
                .set("prescreen_ns",
                     delta.stage(Stage::Prescreen).total_ns);
        }
        // profile sub-breakdown group: present only on rounds that ran
        // the full-fidelity checker (same additive-field discipline as
        // the prescreen group — schema stays 1). Worker CPU time, so at
        // jobs>1 the pair can sum past profile_ns wall time.
        let timing = delta.stage(Stage::Timing);
        if timing.count > 0 {
            o.set("timing_ns", timing.total_ns)
                .set("hazard_ns", delta.stage(Stage::Hazard).total_ns);
        }
        if let Some(best) = self.best_cycles {
            o.set("best_cycles", best);
        }
        if let Some(n) = self.trials_to_best {
            o.set("trials_to_best", n);
        }
        if let Some(v) = &self.v {
            o.set("vetoes", v.vetoes)
                .set("v_tp", v.tp)
                .set("v_fp", v.fp)
                .set("v_tn", v.tn)
                .set("v_fn", v.fn_)
                .set("v_margin", v.v_margin);
        }
        o
    }
}

/// Guard marking the start of one round: a snapshot the matching
/// `end_round` diffs against.
pub struct RoundScope {
    start: Snapshot,
}

impl Recorder {
    /// Snapshot counters/stage totals at the top of a round.
    pub fn begin_round(&self) -> RoundScope {
        RoundScope { start: self.snapshot() }
    }

    /// Emit the round event; `build` runs only when a sink is attached,
    /// so sink-less runs skip event construction entirely.
    pub fn end_round<F: FnOnce() -> RoundEvent>(
        &self,
        scope: RoundScope,
        build: F,
    ) {
        if !self.has_sink() {
            return;
        }
        let delta = self.snapshot().delta_since(&scope.start);
        self.emit(&build().to_json(&delta));
    }

    /// Emit the `run_start` header line (command + its salient args).
    pub fn emit_run_start(&self, cmd: &str, fields: Vec<(&str, Json)>) {
        if !self.has_sink() {
            return;
        }
        let mut o = Json::obj();
        o.set("schema", SCHEMA_VERSION)
            .set("event", "run_start")
            .set("cmd", cmd);
        for (k, v) in fields {
            o.set(k, v);
        }
        self.emit(&o);
    }

    /// Emit the `run_end` trailer: lifetime counters plus per-stage
    /// count/total (histogram buckets stay in-process; the report
    /// derives shares from totals).
    pub fn emit_run_end(&self) {
        if !self.has_sink() {
            return;
        }
        let snap = self.snapshot();
        let mut o = Json::obj();
        o.set("schema", SCHEMA_VERSION).set("event", "run_end");
        for c in Counter::ALL {
            o.set(c.name(), snap.counter(c));
        }
        let mut stages = Json::obj();
        for s in Stage::ALL {
            let t = snap.stage(s);
            let mut st = Json::obj();
            st.set("count", t.count).set("total_ns", t.total_ns);
            stages.set(s.name(), st);
        }
        o.set("stages", stages);
        self.emit(&o);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_event(v: Option<VQuality>) -> RoundEvent {
        RoundEvent {
            target: "zcu102".into(),
            layer: "conv1".into(),
            tuner: "ml2tuner".into(),
            space: "paper".into(),
            round: 3,
            trials_new: 10,
            trials_total: 30,
            valid_new: 7,
            crash_new: 2,
            wrong_new: 1,
            best_cycles: Some(12345),
            trials_to_best: Some(17),
            v,
        }
    }

    #[test]
    fn confusion_counts_quadrants() {
        let margins = [0.5, 0.5, 0.1, 0.1, 0.3];
        let actual = [true, false, false, true, true];
        // margin > 0.25 ⇒ predicted valid
        assert_eq!(confusion(&margins, 0.25, &actual), (2, 1, 1, 1));
        assert_eq!(confusion(&[], 0.25, &[]), (0, 0, 0, 0));
    }

    #[test]
    fn round_event_serializes_with_delta() {
        let rec = Recorder::new();
        rec.record_duration_ns(Stage::Train, 1000);
        rec.record_duration_ns(Stage::Select, 5000);
        rec.add(Counter::CompileCacheHit, 3);
        let delta = rec.snapshot().delta_since(&Recorder::new().snapshot());
        let ev = sample_event(Some(VQuality {
            vetoes: 8,
            tp: 6,
            fp: 1,
            tn: 2,
            fn_: 1,
            v_margin: 0.25,
        }));
        let j = ev.to_json(&delta);
        assert_eq!(j.get("schema").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("event").unwrap().as_str(), Some("round"));
        assert_eq!(j.get("train_ns").unwrap().as_i64(), Some(1000));
        assert_eq!(j.get("select_ns").unwrap().as_i64(), Some(5000));
        assert_eq!(j.get("cache_hits").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("v_tp").unwrap().as_i64(), Some(6));
        assert_eq!(j.get("vetoes").unwrap().as_i64(), Some(8));
        // line round-trips through the parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn prescreen_fields_gate_on_the_counter() {
        let rec = Recorder::new();
        rec.add(Counter::CandidatesPrescreened, 80);
        rec.add(Counter::PrescreenSurvivors, 20);
        rec.record_duration_ns(Stage::Prescreen, 4200);
        let delta =
            rec.snapshot().delta_since(&Recorder::new().snapshot());
        let j = sample_event(None).to_json(&delta);
        assert_eq!(j.get("prescreened").unwrap().as_i64(), Some(80));
        assert_eq!(j.get("survivors").unwrap().as_i64(), Some(20));
        assert_eq!(j.get("prescreen_ns").unwrap().as_i64(), Some(4200));
        // a round that never prescreened emits none of the group
        let empty = Recorder::new()
            .snapshot()
            .delta_since(&Recorder::new().snapshot());
        let j0 = sample_event(None).to_json(&empty);
        assert!(j0.get("prescreened").is_none());
        assert!(j0.get("survivors").is_none());
        assert!(j0.get("prescreen_ns").is_none());
    }

    #[test]
    fn timing_hazard_fields_gate_on_the_stage_count() {
        let rec = Recorder::new();
        rec.record_duration_ns(Stage::Timing, 900);
        rec.record_duration_ns(Stage::Hazard, 350);
        let delta =
            rec.snapshot().delta_since(&Recorder::new().snapshot());
        let j = sample_event(None).to_json(&delta);
        assert_eq!(j.get("timing_ns").unwrap().as_i64(), Some(900));
        assert_eq!(j.get("hazard_ns").unwrap().as_i64(), Some(350));
        // a round with no full-fidelity checks emits neither field
        let empty = Recorder::new()
            .snapshot()
            .delta_since(&Recorder::new().snapshot());
        let j0 = sample_event(None).to_json(&empty);
        assert!(j0.get("timing_ns").is_none());
        assert!(j0.get("hazard_ns").is_none());
    }

    #[test]
    fn v_fields_absent_without_v() {
        let ev = sample_event(None);
        let delta = Recorder::new().snapshot().delta_since(
            &Recorder::new().snapshot(),
        );
        let j = ev.to_json(&delta);
        assert!(j.get("vetoes").is_none());
        assert!(j.get("v_margin").is_none());
    }

    #[test]
    fn sink_gates_emission_and_build() {
        let rec = Recorder::new();
        let scope = rec.begin_round();
        // no sink: the closure must not even run
        rec.end_round(scope, || panic!("built event without a sink"));
        assert_eq!(rec.get(Counter::EventsEmitted), 0);
        assert!(!rec.has_sink());
    }

    #[test]
    fn run_end_lists_all_counters_and_stages() {
        let rec = Recorder::new();
        rec.attach_sink(EventSink::from_writer(Box::new(std::io::sink())));
        rec.emit_run_end();
        assert_eq!(rec.get(Counter::EventsEmitted), 1);
    }
}
