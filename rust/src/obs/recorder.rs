//! The telemetry recorder: atomic counters, monotonic span timers, and
//! fixed-bucket duration histograms.
//!
//! One [`Recorder`] is shared (via `Arc`) by the engine, its compile
//! cache, and the explorer's scoring sweep, so it must be cheap and safe
//! to hit from every `--jobs` worker: all state is plain atomics with
//! relaxed ordering, no locks on the hot paths. The only lock guards the
//! optional [`super::EventSink`], which is touched exclusively by the
//! coordinator thread (event order is therefore deterministic).
//!
//! Nothing here consumes randomness or reorders work — recording a span
//! or bumping a counter can never change a tuning trace. That invariant
//! is pinned by `tests/telemetry.rs` (trace equality with and without a
//! sink) and by the golden-trace suites, which run with the recorder
//! always active.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::events::EventSink;
use crate::util::json::Json;

/// Monotonic event counters. Cache hit/miss live here (not on the
/// cache) so one recorder owns every number a run report needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Compile-cache lookups served from memory.
    CompileCacheHit,
    /// Compile-cache lookups that actually compiled.
    CompileCacheMiss,
    /// Configurations profiled (attempts, valid or not).
    TrialsProfiled,
    /// Profiled configurations that executed cleanly.
    TrialsValid,
    /// Profiled configurations that crash-faulted.
    TrialsCrash,
    /// Profiled configurations with corrupted output.
    TrialsWrongOutput,
    /// Candidates model V vetoed during ranking walks.
    VVetoes,
    /// Candidates decoded+scored by the explorer sweep.
    SweepCandidates,
    /// JSONL events written to the sink.
    EventsEmitted,
    /// Serve queries answered straight from the schedule db (no
    /// compilation, no profiling — the "invalid profiling avoided"
    /// end-state at serving scale).
    ScheduleDbHit,
    /// Serve queries with no stored schedule for the key.
    ScheduleDbMiss,
    /// Miss-triggered tuning jobs the serve daemon completed.
    ServeJobsTuned,
    /// Miss-triggered tuning jobs rejected by admission control (queue
    /// full).
    ServeJobsRejected,
    /// Candidates ranked by the tier-0 coarse estimator during
    /// prescreen (`--prescreen-factor`).
    CandidatesPrescreened,
    /// Prescreened candidates that survived the tier-0 cut and went on
    /// to full profiling.
    PrescreenSurvivors,
    /// Trees appended by warm-continuation fits (incremental per-round
    /// training and meta adaptation) instead of full refits.
    TreesAppended,
    /// Model fits that adapted a corpus-trained meta base (`--meta`).
    MetaAdapted,
}

/// Number of [`Counter`] variants (array sizing).
pub const N_COUNTERS: usize = 17;

impl Counter {
    /// Every counter, in `run_end` emission order.
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::CompileCacheHit,
        Counter::CompileCacheMiss,
        Counter::TrialsProfiled,
        Counter::TrialsValid,
        Counter::TrialsCrash,
        Counter::TrialsWrongOutput,
        Counter::VVetoes,
        Counter::SweepCandidates,
        Counter::EventsEmitted,
        Counter::ScheduleDbHit,
        Counter::ScheduleDbMiss,
        Counter::ServeJobsTuned,
        Counter::ServeJobsRejected,
        Counter::CandidatesPrescreened,
        Counter::PrescreenSurvivors,
        Counter::TreesAppended,
        Counter::MetaAdapted,
    ];

    /// Stable snake_case name (the `run_end` event key).
    pub fn name(self) -> &'static str {
        match self {
            Counter::CompileCacheHit => "compile_cache_hits",
            Counter::CompileCacheMiss => "compile_cache_misses",
            Counter::TrialsProfiled => "trials_profiled",
            Counter::TrialsValid => "trials_valid",
            Counter::TrialsCrash => "trials_crash",
            Counter::TrialsWrongOutput => "trials_wrong_output",
            Counter::VVetoes => "v_vetoes",
            Counter::SweepCandidates => "sweep_candidates",
            Counter::EventsEmitted => "events_emitted",
            Counter::ScheduleDbHit => "schedule_db_hits",
            Counter::ScheduleDbMiss => "schedule_db_misses",
            Counter::ServeJobsTuned => "serve_jobs_tuned",
            Counter::ServeJobsRejected => "serve_jobs_rejected",
            Counter::CandidatesPrescreened => "candidates_prescreened",
            Counter::PrescreenSurvivors => "prescreen_survivors",
            Counter::TreesAppended => "trees_appended",
            Counter::MetaAdapted => "meta_adapted",
        }
    }
}

/// Timed round-lifecycle stages. `Select` is the umbrella over one
/// whole candidate-selection call and *contains* `Train`, `Sweep`, and
/// the A-stage pool `Compile`; `SweepChunk` is nested inside `Sweep`
/// (per-worker chunk timings, so its total is CPU time, not wall time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// One whole candidate-selection call (umbrella).
    Select,
    /// Model P/V/A training inside selection.
    Train,
    /// Explorer sweep over the space inside selection.
    Sweep,
    /// One per-worker sweep chunk (nested inside `Sweep`).
    SweepChunk,
    /// Schedule compilation (A-stage pool and profiling path).
    Compile,
    /// Simulated hardware profiling of a batch.
    Profile,
    /// Tier-0 coarse prescreen of an over-selected candidate pool
    /// (nested inside `Select` like `Train`/`Sweep`/`Compile`).
    Prescreen,
    /// One per-trial timing co-simulation inside `check_with` (nested
    /// inside `Profile`; recorded per worker, so its total is CPU time
    /// like `SweepChunk`).
    Timing,
    /// One per-trial bounds+hazard pass inside `check_with` (nested
    /// inside `Profile`; per-worker CPU time like `SweepChunk`).
    Hazard,
}

/// Number of [`Stage`] variants (array sizing).
pub const N_STAGES: usize = 9;

impl Stage {
    /// Every stage, in `run_end` emission order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Select,
        Stage::Train,
        Stage::Sweep,
        Stage::SweepChunk,
        Stage::Compile,
        Stage::Profile,
        Stage::Prescreen,
        Stage::Timing,
        Stage::Hazard,
    ];

    /// Stable snake_case name (event keys are `<name>_ns`).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Select => "select",
            Stage::Train => "train",
            Stage::Sweep => "sweep",
            Stage::SweepChunk => "sweep_chunk",
            Stage::Compile => "compile",
            Stage::Profile => "profile",
            Stage::Prescreen => "prescreen",
            Stage::Timing => "timing",
            Stage::Hazard => "hazard",
        }
    }
}

/// Histogram buckets per stage: bucket `i` counts durations in
/// `[2^i, 2^(i+1))` ns (bucket 0 additionally holds 0 ns; the last
/// bucket is open-ended, ≈ 9+ minutes).
pub const HIST_BUCKETS: usize = 40;

/// Bucket index for a duration (log2 of the nanosecond count, clamped).
pub fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        return 0;
    }
    ((63 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive lower bound of a bucket, in ns.
pub fn bucket_floor_ns(bucket: usize) -> u64 {
    if bucket == 0 {
        0
    } else {
        1u64 << bucket
    }
}

struct StageStats {
    count: AtomicU64,
    total_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl StageStats {
    fn new() -> StageStats {
        StageStats {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Count + wall total of one stage, as captured in a [`Snapshot`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTotal {
    /// Spans recorded for the stage.
    pub count: u64,
    /// Summed span duration in nanoseconds.
    pub total_ns: u64,
}

/// Point-in-time copy of every counter and stage total. Per-round
/// deltas come from two snapshots taken on the coordinator thread
/// ([`Snapshot::delta_since`]), so no per-round state lives on the
/// recorder itself.
#[derive(Clone, Debug)]
pub struct Snapshot {
    counters: [u64; N_COUNTERS],
    stages: [StageTotal; N_STAGES],
}

impl Snapshot {
    /// Value of one counter at snapshot time.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Count + wall total of one stage at snapshot time.
    pub fn stage(&self, s: Stage) -> StageTotal {
        self.stages[s as usize]
    }

    /// Component-wise `self - earlier` (saturating, so a snapshot pair
    /// taken out of order degrades to zeros instead of garbage).
    pub fn delta_since(&self, earlier: &Snapshot) -> Snapshot {
        let mut counters = [0u64; N_COUNTERS];
        for (i, c) in counters.iter_mut().enumerate() {
            *c = self.counters[i].saturating_sub(earlier.counters[i]);
        }
        let mut stages = [StageTotal::default(); N_STAGES];
        for (i, s) in stages.iter_mut().enumerate() {
            s.count =
                self.stages[i].count.saturating_sub(earlier.stages[i].count);
            s.total_ns = self.stages[i]
                .total_ns
                .saturating_sub(earlier.stages[i].total_ns);
        }
        Snapshot { counters, stages }
    }
}

/// The shared telemetry recorder. Always active (counters and spans are
/// a handful of relaxed atomics — negligible next to a compile or a
/// model sweep); the JSONL sink is only attached when `--metrics-out`
/// is given.
pub struct Recorder {
    counters: [AtomicU64; N_COUNTERS],
    stages: [StageStats; N_STAGES],
    sink: Mutex<Option<EventSink>>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    /// Fresh recorder with all counters zero and no sink.
    pub fn new() -> Recorder {
        Recorder {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            stages: std::array::from_fn(|_| StageStats::new()),
            sink: Mutex::new(None),
        }
    }

    /// Add `n` to a counter.
    pub fn add(&self, c: Counter, n: u64) {
        self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 to a counter.
    pub fn incr(&self, c: Counter) {
        self.add(c, 1);
    }

    /// Current value of a counter.
    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    /// Start a span; it records into `stage` when dropped (or
    /// explicitly via [`Span::stop`]).
    pub fn span(&self, stage: Stage) -> Span<'_> {
        Span { rec: self, stage, start: Instant::now(), armed: true }
    }

    /// Record an already-measured duration (used by worker threads that
    /// time their own chunk).
    pub fn record_duration_ns(&self, stage: Stage, ns: u64) {
        self.stages[stage as usize].record(ns);
    }

    /// Current count + wall total of one stage.
    pub fn stage_total(&self, stage: Stage) -> StageTotal {
        let s = &self.stages[stage as usize];
        StageTotal {
            count: s.count.load(Ordering::Relaxed),
            total_ns: s.total_ns.load(Ordering::Relaxed),
        }
    }

    /// The stage's duration histogram (bucket `i` = durations in
    /// `[2^i, 2^(i+1))` ns).
    pub fn stage_buckets(&self, stage: Stage) -> [u64; HIST_BUCKETS] {
        let s = &self.stages[stage as usize];
        std::array::from_fn(|i| s.buckets[i].load(Ordering::Relaxed))
    }

    /// Point-in-time copy of every counter and stage total.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: std::array::from_fn(|i| {
                self.counters[i].load(Ordering::Relaxed)
            }),
            stages: std::array::from_fn(|i| StageTotal {
                count: self.stages[i].count.load(Ordering::Relaxed),
                total_ns: self.stages[i].total_ns.load(Ordering::Relaxed),
            }),
        }
    }

    /// Attach the JSONL sink (`--metrics-out`); replaces any previous
    /// one.
    pub fn attach_sink(&self, sink: EventSink) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    /// Whether a JSONL sink is attached.
    pub fn has_sink(&self) -> bool {
        self.sink.lock().unwrap().is_some()
    }

    /// Write one event line to the sink, if attached (no-op otherwise).
    /// Sink I/O errors are swallowed: telemetry must never fail a run.
    pub fn emit(&self, event: &Json) {
        let mut guard = self.sink.lock().unwrap();
        if let Some(sink) = guard.as_mut() {
            sink.write_event(event);
            drop(guard);
            self.incr(Counter::EventsEmitted);
        }
    }
}

/// Monotonic span timer guard — records its elapsed time into the
/// stage when dropped.
pub struct Span<'a> {
    rec: &'a Recorder,
    stage: Stage,
    start: Instant,
    armed: bool,
}

impl Span<'_> {
    /// Stop explicitly; returns the recorded duration in ns.
    pub fn stop(mut self) -> u64 {
        let ns = self.start.elapsed().as_nanos() as u64;
        self.rec.record_duration_ns(self.stage, ns);
        self.armed = false;
        ns
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if self.armed {
            let ns = self.start.elapsed().as_nanos() as u64;
            self.rec.record_duration_ns(self.stage, ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Recorder::new();
        assert_eq!(r.get(Counter::VVetoes), 0);
        r.incr(Counter::VVetoes);
        r.add(Counter::VVetoes, 4);
        assert_eq!(r.get(Counter::VVetoes), 5);
        assert_eq!(r.get(Counter::TrialsProfiled), 0);
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(2047), 10);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_floor_ns(0), 0);
        assert_eq!(bucket_floor_ns(10), 1024);
    }

    #[test]
    fn durations_land_in_their_bucket() {
        let r = Recorder::new();
        r.record_duration_ns(Stage::Train, 1500); // [1024, 2048)
        r.record_duration_ns(Stage::Train, 1600);
        r.record_duration_ns(Stage::Train, 5); // [4, 8)
        let t = r.stage_total(Stage::Train);
        assert_eq!(t.count, 3);
        assert_eq!(t.total_ns, 3105);
        let b = r.stage_buckets(Stage::Train);
        assert_eq!(b[10], 2);
        assert_eq!(b[2], 1);
        assert_eq!(b.iter().sum::<u64>(), 3);
        assert_eq!(r.stage_total(Stage::Sweep).count, 0);
    }

    #[test]
    fn span_guard_records_on_drop_and_stop() {
        let r = Recorder::new();
        {
            let _s = r.span(Stage::Profile);
        }
        assert_eq!(r.stage_total(Stage::Profile).count, 1);
        let ns = r.span(Stage::Profile).stop();
        let t = r.stage_total(Stage::Profile);
        assert_eq!(t.count, 2);
        assert!(t.total_ns >= ns);
    }

    #[test]
    fn snapshot_deltas() {
        let r = Recorder::new();
        r.add(Counter::SweepCandidates, 100);
        r.record_duration_ns(Stage::Sweep, 500);
        let a = r.snapshot();
        r.add(Counter::SweepCandidates, 50);
        r.record_duration_ns(Stage::Sweep, 300);
        let d = r.snapshot().delta_since(&a);
        assert_eq!(d.counter(Counter::SweepCandidates), 50);
        assert_eq!(d.stage(Stage::Sweep),
                   StageTotal { count: 1, total_ns: 300 });
        assert_eq!(d.counter(Counter::TrialsValid), 0);
    }

    #[test]
    fn recorder_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Recorder>();
    }
}
