//! Deterministic xoshiro256** PRNG.
//!
//! Every stochastic component (search-space sampling, model subsampling,
//! tensor data, experiment repeats) draws from an explicitly seeded [`Rng`],
//! so every experiment in EXPERIMENTS.md is bit-reproducible.

/// xoshiro256** 1.0 (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-repeat / per-model seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the xoshiro256** stream.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias negligible for our
    /// bounds ≪ 2^64; exactness not required for sampling).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random int8 value (full range), for synthetic tensors.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Standard normal via Box–Muller (used for label noise in tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), order randomised.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k slots
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 800), "{seen:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let k = r.below(20) + 1;
            let s = r.sample_indices(50, k);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), k);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(17);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
