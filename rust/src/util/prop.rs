//! Mini property-testing harness (offline substitute for `proptest`,
//! ARCHITECTURE.md §Substitutions).
//!
//! A property is checked over `cases` seeded random inputs; on failure the
//! harness re-runs a bounded shrink loop (halving numeric generators toward
//! their minimum) and reports the smallest failing seed/input it found.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath)
//! use ml2tuner::util::prop::{self, Gen};
//! prop::check(200, |g| {
//!     let n = g.usize_in(1, 64);
//!     let mut v: Vec<u64> = (0..n).map(|_| g.u64()).collect();
//!     v.sort();
//!     prop::assert_prop(v.windows(2).all(|w| w[0] <= w[1]), "sorted")
//! });
//! ```

use crate::util::rng::Rng;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Shrink level 0 = full ranges; higher levels bias toward minima.
    shrink: u32,
    /// Log of drawn values for failure reporting.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, shrink: u32) -> Self {
        Gen {
            rng: Rng::new(seed),
            shrink,
            log: Vec::new(),
        }
    }

    fn shrunk_span(&self, span: u64) -> u64 {
        // each shrink level halves the span (toward the lower bound)
        span >> self.shrink.min(63)
    }

    /// Uniform `u64` (shrink levels mask high bits toward 0).
    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64() & (u64::MAX >> self.shrink.min(63));
        self.log.push(format!("u64={v}"));
        v
    }

    /// Uniform `usize` in `[lo, hi]` (shrinks toward `lo`).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = self.shrunk_span((hi - lo) as u64);
        let v = lo + (self.rng.next_u64() % (span + 1)) as usize;
        self.log.push(format!("usize={v}"));
        v
    }

    /// Uniform `i64` in `[lo, hi]` (shrinks toward `lo`).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = self.shrunk_span((hi - lo) as u64);
        let v = lo + (self.rng.next_u64() % (span + 1)) as i64;
        self.log.push(format!("i64={v}"));
        v
    }

    /// Uniform `f64` in `[lo, hi)` (shrinks toward `lo`).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let frac = self.rng.f64() / (1u64 << self.shrink.min(52)) as f64;
        let v = lo + frac * (hi - lo);
        self.log.push(format!("f64={v}"));
        v
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        let v = self.rng.bool(0.5);
        self.log.push(format!("bool={v}"));
        v
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len().max(1));
        self.log.push(format!("pick#{i}"));
        &xs[i]
    }

    /// `len` uniform `f64` values in `[lo, hi)` (not shrunk).
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    /// Escape hatch: the underlying RNG, for draws the `Gen` surface
    /// does not cover (these are not shrunk).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Property outcome: Ok(()) or a failure message.
pub type PropResult = Result<(), String>;

/// Assert helper producing a `PropResult`.
pub fn assert_prop(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two f64 are within `tol`.
pub fn assert_close(a: f64, b: f64, tol: f64) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| > {tol}"))
    }
}

/// Run `prop` over `cases` seeds; panic with the smallest failure found.
pub fn check<F>(cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    check_seeded(0, cases, prop)
}

const SEED_BASE: u64 = 0x4d4c_325f_5455_4e45; // "ML2_TUNE"

/// Like [`check`] but with an explicit base seed.
pub fn check_seeded<F>(extra_seed: u64, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let seed = SEED_BASE ^ extra_seed.wrapping_add(case);
        let mut g = Gen::new(seed, 0);
        if let Err(msg) = prop(&mut g) {
            // shrink: retry same seed with progressively narrowed generators
            let mut best: (u32, String, Vec<String>) = (0, msg, g.log);
            for level in 1..16 {
                let mut gs = Gen::new(seed, level);
                if let Err(m) = prop(&mut gs) {
                    best = (level, m, gs.log);
                }
            }
            panic!(
                "property failed (seed={seed:#x}, case={case}, \
                 shrink_level={}): {}\ninputs: [{}]",
                best.0,
                best.1,
                best.2.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check(100, |g| {
            let a = g.i64_in(-100, 100);
            let b = g.i64_in(-100, 100);
            assert_prop(a + b == b + a, "commutative")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_inputs() {
        check(100, |g| {
            let v = g.usize_in(0, 1000);
            assert_prop(v < 500, "v < 500")
        });
    }

    #[test]
    fn shrink_narrows_ranges() {
        let mut g0 = Gen::new(1, 0);
        let mut g8 = Gen::new(1, 8);
        let wide: Vec<usize> = (0..50).map(|_| g0.usize_in(0, 1000)).collect();
        let narrow: Vec<usize> =
            (0..50).map(|_| g8.usize_in(0, 1000)).collect();
        assert!(narrow.iter().max() < wide.iter().max());
    }
}
