//! Statistics helpers used by the cost models and the experiment harnesses.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Geometric mean of strictly positive values (paper Table 5 "GeoAVG").
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64)
        .exp()
}

/// Root-mean-square error between predictions and targets (paper Fig 3/4).
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (se / pred.len() as f64).sqrt()
}

/// Linear-interpolated quantile, q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Minimum of a sample (`+inf` when empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum of a sample (`-inf` when empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Normalized histogram over `bins` equal-width buckets spanning
/// [min, max] of the data (paper Fig 2b right panel).
pub fn normalized_histogram(xs: &[f64], bins: usize) -> Vec<(f64, f64)> {
    assert!(bins > 0);
    if xs.is_empty() {
        return Vec::new();
    }
    let (lo, hi) = (min(xs), max(xs));
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
        .iter()
        .enumerate()
        .map(|(i, &c)| {
            (lo + (i as f64 + 0.5) * width, c as f64 / xs.len() as f64)
        })
        .collect()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Running best-so-far minimum (tuning-curve transform, paper Fig 2a).
pub fn cummin(xs: &[f64]) -> Vec<f64> {
    let mut best = f64::INFINITY;
    xs.iter()
        .map(|&x| {
            best = best.min(x);
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn rmse_zero_when_equal() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(rmse(&xs, &xs), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs()
            < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn histogram_sums_to_one() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let h = normalized_histogram(&xs, 20);
        let total: f64 = h.iter().map(|&(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert_eq!(h.len(), 20);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cummin_monotone() {
        let xs = [5.0, 7.0, 3.0, 4.0, 1.0];
        assert_eq!(cummin(&xs), vec![5.0, 5.0, 3.0, 3.0, 1.0]);
    }
}
