//! Minimal JSON value type + writer + recursive-descent parser.
//!
//! Used for the artifact manifest, the profiling database, and experiment
//! result dumps. The offline vendor set has no `serde`/`serde_json`, so this
//! is a deliberate substrate (ARCHITECTURE.md §Substitutions). It supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (all numbers are `f64`, as in JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// New empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object member lookup; `None` on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value; `None` for non-numbers.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to `i64`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// Numeric value truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Numeric value as `u64` (saturating at 0 for negatives, like the
    /// other integer accessors' `as` casts).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    /// String value; `None` for non-strings.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value; `None` for non-booleans.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements; `None` for non-arrays.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object members; `None` for non-objects.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["layers", "conv1", "h"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(0));
        s
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s, None);
        f.write_str(&s)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&(n as i64).to_string());
    } else if n.is_finite() {
        out.push_str(&n.to_string());
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind + 1));
                    write_json(item, out, Some(ind + 1));
                } else {
                    write_json(item, out, None);
                }
            }
            if let Some(ind) = indent {
                out.push('\n');
                out.push_str(&"  ".repeat(ind));
            }
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind + 1));
                }
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent.map(|i| i + 1));
            }
            if let Some(ind) = indent {
                out.push('\n');
                out.push_str(&"  ".repeat(ind));
            }
            out.push('}');
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub pos: usize,
    /// What the parser expected or found.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc =
                        self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for text in ["null", "true", "false", "0", "-17", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn round_trip_nested() {
        let text = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":"x\ny"}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_python_manifest_style() {
        let text = r#"{
  "layers": {
    "conv1": { "artifact": "conv1.hlo.txt", "h": 56, "pad": 1 }
  },
  "shift": 8
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["shift"]).unwrap().as_i64(), Some(8));
        assert_eq!(
            v.at(&["layers", "conv1", "artifact"]).unwrap().as_str(),
            Some("conv1.hlo.txt")
        );
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn u_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_serialize_without_decimal() {
        let mut o = Json::obj();
        o.set("n", 42i64).set("f", 2.5f64);
        let s = o.to_string();
        assert!(s.contains("\"n\":42"), "{s}");
        assert!(s.contains("\"f\":2.5"), "{s}");
    }

    #[test]
    fn pretty_round_trips() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2, 3]).set("name", "abc");
        let pretty = o.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), o);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }
}
