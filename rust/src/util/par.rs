//! Order-preserving scoped-thread parallel map — the worker-pool
//! primitive shared by the engine's batch executor
//! ([`crate::engine::Engine`]) and the explorer's chunked scoring sweep
//! ([`crate::tuner::explorer::score_candidates`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Order-preserving parallel map over `0..n` on `jobs` scoped threads.
///
/// Work is distributed dynamically (atomic cursor), results land in
/// per-index slots — output order equals input order by construction, so
/// callers see deterministic results for any worker count. Falls back to
/// a plain sequential map when a pool cannot help (`jobs ≤ 1` or `n ≤ 1`).
pub fn par_map<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let workers = jobs.min(n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner().unwrap().expect("worker filled every slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        for jobs in [1, 2, 4, 9] {
            let out = par_map(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(4, 1, |i| i + 7), vec![7]);
    }
}
