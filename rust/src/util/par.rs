//! Order-preserving scoped-thread parallel map — the worker-pool
//! primitive shared by the engine's batch executor
//! ([`crate::engine::Engine`]) and the explorer's chunked scoring sweep
//! ([`crate::tuner::explorer::score_candidates`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Order-preserving parallel map over `0..n` on `jobs` scoped threads.
///
/// Work is distributed dynamically (atomic cursor), results land in
/// per-index slots — output order equals input order by construction, so
/// callers see deterministic results for any worker count. Falls back to
/// a plain sequential map when a pool cannot help (`jobs ≤ 1` or `n ≤ 1`).
pub fn par_map<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_with(jobs, n, || (), |(), i| f(i))
}

/// [`par_map`] with per-worker mutable state: each worker calls `init`
/// once, then reuses that state across every index it pulls — the hook
/// for per-worker scratch arenas (simulator scratch, sweep feature
/// buffers) that are warmed once and never reallocated per item.
///
/// The state never crosses threads and never influences which index a
/// worker pulls, so results stay byte-identical for any `jobs` as long
/// as `f`'s output does not depend on the *history* encoded in the
/// state — scratch reuse must be semantically invisible (the simulator
/// scratch types clear themselves per call; `tests/sim_scratch.rs` pins
/// this). The sequential fallback (`jobs ≤ 1` or `n ≤ 1`) runs one
/// state through all indices, which is exactly a one-worker pool.
pub fn par_map_with<T, S, I, F>(jobs: usize, n: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let workers = jobs.min(n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut state, i);
                    *slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner().unwrap().expect("worker filled every slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        for jobs in [1, 2, 4, 9] {
            let out = par_map(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(4, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_map_with_preserves_order_for_any_jobs() {
        for jobs in [1, 2, 4, 9] {
            let out = par_map_with(
                jobs,
                100,
                Vec::<u8>::new,
                |scratch, i| {
                    scratch.clear();
                    scratch.extend(std::iter::repeat(1).take(i % 7));
                    i * 2 + scratch.len()
                },
            );
            let want: Vec<usize> =
                (0..100).map(|i| i * 2 + i % 7).collect();
            assert_eq!(out, want, "jobs={jobs}");
        }
    }

    #[test]
    fn par_map_with_reuses_one_state_per_worker() {
        use std::sync::atomic::AtomicUsize;
        // number of init() calls must equal the worker count, never n
        let inits = AtomicUsize::new(0);
        let n = 64;
        for jobs in [1usize, 3] {
            inits.store(0, Ordering::Relaxed);
            let out = par_map_with(
                jobs,
                n,
                || {
                    inits.fetch_add(1, Ordering::Relaxed);
                    0usize
                },
                |pulls, i| {
                    *pulls += 1;
                    i
                },
            );
            assert_eq!(out, (0..n).collect::<Vec<_>>());
            let created = inits.load(Ordering::Relaxed);
            assert!(created <= jobs.max(1), "jobs={jobs}: {created} states");
            assert!(created >= 1);
        }
    }
}
