//! Shared infrastructure: deterministic RNG, minimal JSON, statistics,
//! table rendering, and the in-tree property-test / micro-bench harnesses.
//!
//! The build is fully offline against a small vendored crate set (no `rand`,
//! `serde`, `proptest` or `criterion`), so these are deliberate from-scratch
//! substrates — see ARCHITECTURE.md §Substitutions.

pub mod bench;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
