//! Aligned plain-text table rendering for experiment harnesses — every
//! `ml2tuner experiment <id>` prints the paper's rows/series through this.

/// Column-aligned table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; panics if the width differs from the header.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch: {cells:?}"
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Append one row of anything `Display`able.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Render to a column-aligned string (header, rule, rows).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
            }
            // trim trailing spaces
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (helper for experiment rows).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Render an ASCII sparkline-ish curve (used for tuning-curve figures in
/// terminal output): y values mapped onto `height` rows of block chars.
pub fn ascii_curve(ys: &[f64], width: usize, height: usize) -> String {
    if ys.is_empty() || width == 0 || height == 0 {
        return String::new();
    }
    // resample to `width` points
    let pts: Vec<f64> = (0..width)
        .map(|i| {
            let pos = i as f64 / (width.max(2) - 1) as f64
                * (ys.len() - 1) as f64;
            ys[pos.round() as usize]
        })
        .collect();
    let lo = pts.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = pts.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    for (x, &v) in pts.iter().enumerate() {
        let y = ((v - lo) / span * (height - 1) as f64).round() as usize;
        let row = height - 1 - y;
        grid[row][x] = '*';
    }
    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        if r == 0 {
            out.push_str(&format!("{hi:>10.3e} |"));
        } else if r == height - 1 {
            out.push_str(&format!("{lo:>10.3e} |"));
        } else {
            out.push_str("           |");
        }
        out.extend(row.iter());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["conv1".into(), "0.8264".into()]);
        t.row(&["conv10".into(), "0.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("conv1 "));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn curve_has_height_lines() {
        let ys: Vec<f64> = (0..100).map(|i| (100 - i) as f64).collect();
        let s = ascii_curve(&ys, 40, 8);
        assert_eq!(s.lines().count(), 8);
        assert!(s.contains('*'));
    }

    #[test]
    fn f_formats() {
        assert_eq!(f(0.12345, 3), "0.123");
    }
}
