//! Mini micro-benchmark harness (offline substitute for `criterion`,
//! ARCHITECTURE.md §Substitutions).
//!
//! Measures wall time over warmup + timed iterations, reports
//! median / mean / p10 / p90 and a derived throughput. All `cargo bench`
//! targets (`harness = false`) are built on this.

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name as passed to [`Bench::run`].
    pub name: String,
    /// Timed iterations actually executed.
    pub iters: usize,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Median wall time per iteration.
    pub median: Duration,
    /// 10th-percentile wall time per iteration.
    pub p10: Duration,
    /// 90th-percentile wall time per iteration.
    pub p90: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items per second derived from the mean, when
    /// [`BenchResult::items_per_iter`] was given.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|items| items / self.mean.as_secs_f64())
    }

    /// One human-readable summary line.
    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:.2} M items/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:.2} K items/s", t / 1e3),
            Some(t) => format!("  {t:.2} items/s"),
            None => String::new(),
        };
        format!(
            "{:<40} {:>12} median {:>12} mean (p10 {:>12}, p90 {:>12}, n={}){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters,
            tp
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner; collects results and prints a summary.
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Maximum timed iterations.
    pub max_iters: usize,
    /// Results collected so far, in run order.
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Runner with the default 2-second budget per benchmark.
    pub fn new() -> Self {
        Self::default()
    }

    /// Runner with a custom per-benchmark time budget in seconds.
    pub fn with_budget(secs: f64) -> Self {
        Bench {
            budget: Duration::from_secs_f64(secs),
            ..Self::default()
        }
    }

    /// Time `f`, which should return something observable to prevent DCE
    /// (the value is black-boxed).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        self.run_with_items(name, None, &mut f)
    }

    /// Time `f` and report `items` units of work per iteration.
    pub fn run_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) {
        // Warmup: 1 run to estimate cost, then ~10% of budget.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let first = t0.elapsed();
        let warm_deadline = Instant::now() + self.budget / 10;
        while Instant::now() < warm_deadline && first < self.budget / 10 {
            std::hint::black_box(f());
        }
        // Timed runs until budget or max_iters.
        let mut samples: Vec<Duration> = Vec::new();
        let deadline = Instant::now() + self.budget;
        while samples.len() < self.max_iters
            && (samples.len() < 5 || Instant::now() < deadline)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean,
            median: samples[n / 2],
            p10: samples[n / 10],
            p90: samples[(n * 9 / 10).min(n - 1)],
            items_per_iter: items,
        };
        println!("{}", result.report());
        self.results.push(result);
    }

    /// Machine-readable sink for CI bench-regression tracking: when the
    /// `ML2_BENCH_JSON` env var names a file, append one JSON object per
    /// result (`{"suite", "name", "iters", "median_ns", "mean_ns"}`,
    /// newline-delimited). Appending is what lets the sequential `cargo
    /// bench` binaries share one file; `scripts/bench_report.py` folds
    /// the lines into `BENCH_<pr>.json` and diffs the medians against
    /// the committed `BENCH_baseline.json`. A no-op without the env var,
    /// and never fatal — benches must not fail on a read-only FS.
    pub fn maybe_write_json(&self, suite: &str) {
        let Ok(path) = std::env::var("ML2_BENCH_JSON") else {
            return;
        };
        if path.is_empty() {
            return;
        }
        self.write_json_to(suite, path.as_ref());
    }

    /// The env-var-free body of [`Bench::maybe_write_json`] (also what
    /// tests exercise — mutating the process environment under the
    /// multi-threaded test harness is a getenv/setenv race).
    pub fn write_json_to(&self, suite: &str, path: &std::path::Path) {
        let mut lines = String::new();
        for r in &self.results {
            let mut o = crate::util::json::Json::obj();
            o.set("suite", suite)
                .set("name", r.name.as_str())
                .set("iters", r.iters)
                .set("median_ns", r.median.as_nanos() as u64)
                .set("mean_ns", r.mean.as_nanos() as u64);
            lines.push_str(&o.to_string());
            lines.push('\n');
        }
        use std::io::Write;
        match std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
        {
            Ok(mut f) => {
                if let Err(e) = f.write_all(lines.as_bytes()) {
                    eprintln!("ML2_BENCH_JSON: write to {path:?} \
                               failed: {e}");
                }
            }
            Err(e) => {
                eprintln!("ML2_BENCH_JSON: cannot open {path:?}: {e}")
            }
        }
    }

    /// Final summary block (also returned for EXPERIMENTS.md capture).
    pub fn summary(&self) -> String {
        let mut s = String::from("\n== bench summary ==\n");
        for r in &self.results {
            s.push_str(&r.report());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::with_budget(0.05);
        b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters >= 5);
        assert!(b.results[0].mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::with_budget(0.02);
        b.run_items("items", 1000.0, || std::hint::black_box(3 * 7));
        assert!(b.results[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_sink_appends_one_line_per_result() {
        use crate::util::json::Json;
        // write_json_to is the env-free body of maybe_write_json; the
        // test drives it directly rather than racing set_var against
        // the multi-threaded test harness
        let path = std::env::temp_dir().join("ml2tuner_bench_json_test");
        std::fs::remove_file(&path).ok();
        let mut b = Bench::with_budget(0.02);
        let work = || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        };
        b.run("first", work);
        b.run("second", work);
        b.write_json_to("suite_a", &path);
        b.write_json_to("suite_b", &path); // appends, never truncates
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 4);
        for line in &lines {
            let j = crate::util::json::Json::parse(line).unwrap();
            assert!(j.get("median_ns").and_then(Json::as_i64).unwrap()
                    > 0);
            assert!(j.get("suite").and_then(Json::as_str).is_some());
        }
        assert!(lines[0].contains("suite_a"));
        assert!(lines[3].contains("suite_b"));
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
