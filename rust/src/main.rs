//! ml2tuner CLI — the L3 coordinator entrypoint.
//!
//! ```text
//! ml2tuner info                         targets, networks, space sizes
//! ml2tuner tune [--network resnet18] --layer conv1 [--target zcu102]
//!               [--tuner ml2tuner|tvm|random] [--trials N] [--seed S]
//!               [--jobs J] [--space paper|extended] [--v-margin M]
//!               [--db out.json] [--transfer-from dir]
//!               [--metrics-out events.jsonl]
//!
//! All commands accept --quiet (results only) and --verbose / -v
//! (per-grant scheduler progress); the tuning commands accept
//! --metrics-out <file> to stream structured telemetry events (JSONL,
//! consumed by `report`).
//! ml2tuner tune-net [--network resnet18|vgg16|mobilenet|synth-gemm]
//!               [--target zcu102] [--tuner ml2tuner|tvm|random]
//!               [--trials N] [--round N] [--seed S] [--jobs J]
//!               [--layers a,b,..] [--out dir] [--space paper|extended]
//!               [--v-margin M] [--transfer-from dir] [--transfer-cap N]
//!               whole-network tuning, one budget
//! ml2tuner tune-fleet --targets zcu102,zcu104,edge-small [--network N]
//!               [--trials N] [..tune-net flags..] [--out dir]
//!               one network across a hardware fleet, one global budget;
//!               smallest target first, logs chained as warm starts
//! ml2tuner train-meta --corpus dir --out dir [--rounds N]
//!               offline corpus training: fit base P/V/A ensembles over
//!               a directory of accumulated tuning logs and write one
//!               versioned artifact per space kind; the tune commands
//!               and serve load them back with --meta <dir> and adapt
//!               per round instead of fitting cold
//! ml2tuner serve --schedule-db dir [--listen addr:port] [--workers N]
//!               [--queue N] [--miss-trials N] [--seed S] [--jobs J]
//!               [--transfer-from dir] [--metrics-out events.jsonl]
//!               tuning-as-a-service daemon: answers best-schedule
//!               queries (line-oriented JSON on stdin/stdout or TCP)
//!               from the store; misses can enqueue warm-started tuning
//!               jobs whose results are promoted back into the store.
//!               The tune commands take --schedule-db too, appending
//!               their best schedules on completion.
//! ml2tuner report <events.jsonl...>
//!               aggregate --metrics-out telemetry into per-stage time,
//!               cache, and model-quality tables
//! ml2tuner simulate [--network N] --layer conv1 [--target zcu102]
//!               --schedule TH,TW,OC,IC,VT[,SLOTS,UNROLL] [--numeric]
//! ml2tuner validate [--layer conv1] [--samples N] [--seed S] [--space K]
//!               (simulator vs AOT JAX/Pallas golden, bit-exact; the
//!               golden artifacts are zcu102-only)
//! ml2tuner experiment <id>|all [--quick] [--repeats N] [--seed S]
//!               [--target zcu102]
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use ml2tuner::compiler::schedule::{self, Schedule, SpaceKind};
use ml2tuner::compiler::Compiler;
use ml2tuner::engine::{
    default_jobs, Engine, FleetConfig, FleetTuner, NetworkConfig,
    NetworkTuner, TunerKind,
};
use ml2tuner::experiments::{self, ExpConfig};
use ml2tuner::obs::{self, console, EventSink};
use ml2tuner::runtime::{golden, Runtime};
use ml2tuner::serve::{
    Daemon, Promotion, ScheduleDb, ScheduleEntry, ScheduleKey,
    ServeConfig, SharedSink,
};
use ml2tuner::tuner::database::{Database, TransferDb};
use ml2tuner::tuner::meta::{MetaArtifact, MetaStore, META_BOOST_ROUNDS};
use ml2tuner::tuner::ml2tuner::Ml2Tuner;
use ml2tuner::tuner::random_baseline::RandomTuner;
use ml2tuner::tuner::report::{ProfilingCostModel, TuningTrace};
use ml2tuner::tuner::tvm_baseline::TvmTuner;
use ml2tuner::tuner::{Tuner, TunerConfig, TuningEnv};
use ml2tuner::util::json::Json;
use ml2tuner::util::rng::Rng;
use ml2tuner::util::table::Table;
use ml2tuner::vta::{config::VtaConfig, functional, layout, targets,
                    Simulator};
use ml2tuner::workloads::{self, resnet18, synth, ConvLayer, Network};

/// Flags that never take a value — the parser must not swallow the
/// next token as their argument (`tune --quiet --layer conv1` would
/// otherwise read `--layer` fine but `tune --quiet events.jsonl` in
/// `report` would eat the positional).
const BOOL_FLAGS: &[&str] =
    &["quiet", "verbose", "numeric", "quick", "incremental"];

/// Tiny flag parser: `--key value` pairs + positionals. `-v` is
/// shorthand for `--verbose`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "-v" {
                flags.insert("verbose".to_string(), "true".to_string());
            } else if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v)
                        if !v.starts_with("--")
                            && !BOOL_FLAGS.contains(&key) =>
                    {
                        it.next().unwrap().clone()
                    }
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer")),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    if args.has("quiet") && args.has("verbose") {
        bail!("--quiet and --verbose are mutually exclusive");
    }
    if args.has("quiet") {
        console::set_level(console::Level::Quiet);
    } else if args.has("verbose") {
        console::set_level(console::Level::Verbose);
    }
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "tune" => cmd_tune(&args),
        "tune-net" => cmd_tune_net(&args),
        "tune-fleet" => cmd_tune_fleet(&args),
        "train-meta" => cmd_train_meta(&args),
        "serve" => cmd_serve(&args),
        "report" => cmd_report(&args),
        "simulate" => cmd_simulate(&args),
        "validate" => cmd_validate(&args),
        "experiment" => cmd_experiment(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `ml2tuner help`)"),
    }
}

fn print_usage() {
    println!(
        "ml2tuner — multi-level ML autotuning for a simulated extended \
         VTA\n\n\
         commands:\n  \
         info\n  \
         tune [--network N] --layer conv1 [--target T] \
         [--tuner ml2tuner|tvm|random]\n       [--trials N] [--seed S] \
         [--jobs J] [--space paper|extended]\n       [--v-margin M] \
         [--prescreen-factor K] [--db out.json] [--schedule-db dir]\n       \
         [--transfer-from dir] [--meta dir] [--incremental] \
         [--retrain-every R]\n       [--metrics-out events.jsonl]\n  \
         tune-net [--network resnet18|vgg16|mobilenet|synth-gemm] \
         [--target T]\n       [--tuner ..] [--trials N] [--round N] \
         [--seed S] [--jobs J]\n       [--layers a,b,..] [--space \
         paper|extended] [--v-margin M] [--prescreen-factor K] \
         [--out dir]\n       \
         [--schedule-db dir] [--transfer-from dir] [--transfer-cap N]\n       \
         [--meta dir] [--incremental] [--retrain-every R] \
         [--metrics-out f]\n  \
         tune-fleet --targets T1,T2,.. [--network N] [--trials N] \
         [--out dir]\n       [..tune-net flags..]\n  \
         train-meta --corpus dir --out dir [--rounds N]   offline corpus \
         training:\n       fit base P/V/A ensembles over accumulated \
         tuning logs, one versioned\n       artifact per space kind \
         (loaded back via --meta)\n  \
         serve --schedule-db dir [--listen addr:port] [--workers N] \
         [--queue N]\n       [--miss-trials N] [--seed S] [--jobs J] \
         [--transfer-from dir]\n       [--meta dir] [--metrics-out f]   \
         best-schedule query daemon (JSON lines)\n  \
         report <events.jsonl...>   aggregate --metrics-out telemetry\n  \
         simulate [--network N] --layer conv1 [--target T] --schedule \
         \n       TH,TW,OC,IC,VT[,SLOTS,UNROLL] [--numeric]\n  \
         validate [--layer conv1] [--samples N] [--seed S] [--space ..]\n  \
         experiment <fig2a|fig2b|fig3|fig4|fig5|table2|table4|table5|\
         headline|transfer|storm|fidelity|all> [--quick] [--repeats N] \
         [--seed S] [--target T] [--meta]\n\n\
         --network: a registered workload ({}); layer names are resolved\n\
        \x20       within it.\n\
         --target: a registered hardware target ({}); default zcu102 \
         (paper\n        Table 1). tune-fleet takes a comma list via \
         --targets and tunes\n        smallest-capacity first, chaining \
         each target's logs into the next\n        target's transfer \
         warm start.\n\
         --space: knob set. 'paper' is the paper-exact 5-knob space \
         (byte-reproducible\n        traces); 'extended' adds load \
         double-buffering (nLoadSlots 1|2) and\n        kernel unroll \
         (kernelUnroll 1|2|4) — 6x the space per layer.\n\
         --v-margin: model-V veto margin on the hinge score (default \
         0.25).\n\
         --prescreen-factor: tier-0 multi-fidelity prescreen. At K >= 2 \
         the\n        ML2Tuner round over-selects a Kx candidate pool, \
         ranks it with the\n        coarse analytic cycle estimator (no \
         compile, no simulation), and\n        spends full profiling \
         only on the survivors. 0 (default) disables\n        it — \
         traces are byte-identical to the single-fidelity loop.\n\
         --jobs: profiling/compile worker threads (default: all cores); \
         traces are\n        identical for any worker count.\n\
         --metrics-out: stream structured telemetry (JSONL: run_start, \
         per-round\n        events with stage timings + model-V quality, \
         run_end) to a file;\n        traces are byte-identical with or \
         without it. Aggregate with `report`.\n\
         --quiet / --verbose (-v): console verbosity (results only / \
         per-grant\n        scheduler progress).\n\
         --transfer-from: directory of prior tuning logs (tune --db / \
         tune-net --out);\n        shape-similar layers warm-start the \
         models before the first batch\n        (knob values are \
         similarity-matched across space versions).\n\
         --meta: directory of train-meta artifacts. Per-round fits \
         adapt the\n        corpus-trained base ensembles (a few \
         recalibrated trees) instead of\n        fitting cold, so the \
         run is model-guided from its first batch.\n        `experiment \
         transfer --meta` adds a warm+meta arm to that study.\n\
         --incremental: per-round refits continue the previous round's \
         boosters\n        (append a few trees on the grown record set) \
         instead of refitting\n        from scratch; --retrain-every R \
         forces a full refit every R rounds\n        (0 = never). \
         Continuation on an unchanged prefix is bit-identical\n        \
         to the full refit.\n\
         --schedule-db: persistent best-schedule store (one JSON file \
         per\n        layer-shape x codegen-signature x space key, \
         versioned, better-only\n        promotion). The tune commands \
         append on completion; `serve` answers\n        queries from it \
         without compiling or profiling anything on a hit.\n\
         tune-net splits one global --trials budget across the layers \
         with a\n        round-robin + UCB allocator and saves one tuning \
         log per layer to --out;\n        tune-fleet saves them per \
         target to --out/<target>/.",
        workloads::network_names().join("|"),
        targets::TARGET_NAMES.join("|")
    );
}

/// `--space paper|extended` (default: the paper-exact knob set, so cold
/// runs stay byte-reproducible against the paper baseline).
fn space_arg(args: &Args) -> Result<SpaceKind> {
    match args.get("space") {
        None => Ok(SpaceKind::Paper),
        Some(name) => SpaceKind::parse(name).ok_or_else(|| {
            anyhow!("unknown space '{name}' (known: paper, extended)")
        }),
    }
}

/// Registry lookup with the uniform unknown-target error (shared by
/// the singular and plural flags so their messages can never drift).
fn lookup_target(name: &str) -> Result<VtaConfig> {
    targets::target(name).ok_or_else(|| {
        anyhow!(
            "unknown target '{name}' (known: {})",
            targets::TARGET_NAMES.join(", ")
        )
    })
}

/// `--target <name>` through the registry (default: the paper's
/// zcu102, so every pre-registry command line behaves identically).
fn target_arg(args: &Args) -> Result<VtaConfig> {
    lookup_target(args.get("target").unwrap_or("zcu102"))
}

/// `--targets a,b,..` for the fleet (each name registry-routed,
/// duplicates rejected — they would collide in `--out <dir>/<target>`).
fn targets_arg(args: &Args) -> Result<Vec<VtaConfig>> {
    let list = args
        .get("targets")
        .ok_or_else(|| anyhow!("tune-fleet requires --targets a,b,.."))?;
    let mut out: Vec<VtaConfig> = Vec::new();
    for name in list.split(',') {
        let name = name.trim();
        let cfg = lookup_target(name)?;
        if out.iter().any(|c| c.target == cfg.target) {
            bail!("--targets lists '{name}' twice");
        }
        out.push(cfg);
    }
    Ok(out)
}

fn network_arg(args: &Args) -> Result<&'static Network> {
    let name = args.get("network").unwrap_or("resnet18");
    workloads::network(name).ok_or_else(|| {
        anyhow!(
            "unknown network '{name}' (known: {})",
            workloads::network_names().join(", ")
        )
    })
}

/// Registry-routed `--layers a,b,..` (default: every layer of the
/// network). Duplicates are rejected — they would silently overwrite
/// each other's tuning log in `--out`. Shared by `tune-net` and
/// `tune-fleet` so the two commands can never drift in `--layers`
/// syntax.
fn layers_arg(args: &Args, net: &Network) -> Result<Vec<ConvLayer>> {
    let layers: Vec<ConvLayer> = match args.get("layers") {
        None => net.layers.to_vec(),
        Some(list) => list
            .split(',')
            .map(|n| {
                let n = n.trim();
                net.layer(n).ok_or_else(|| {
                    anyhow!(
                        "unknown layer '{n}' of network '{}' (layers: {})",
                        net.name,
                        net.layer_names().join(", ")
                    )
                })
            })
            .collect::<Result<_>>()?,
    };
    for (i, l) in layers.iter().enumerate() {
        if layers[..i].iter().any(|m| m.name == l.name) {
            bail!("--layers lists '{}' twice", l.name);
        }
    }
    Ok(layers)
}

/// Error on any flag the command does not read. The parser itself
/// accepts arbitrary `--key value` pairs, so without this gate a typo
/// (`--trails`, `--sapce`) or a near-miss (`tune-net --targets x`,
/// `tune --layers a,b`) would be silently ignored and the run would
/// proceed with defaults the user never asked for.
fn expect_flags(args: &Args, allowed: &[&str]) -> Result<()> {
    let mut unknown: Vec<&str> = args
        .flags
        .keys()
        .map(String::as_str)
        .filter(|k| !allowed.contains(k))
        .collect();
    if unknown.is_empty() {
        return Ok(());
    }
    unknown.sort_unstable();
    let accepted = if allowed.is_empty() {
        "this command takes no flags".to_string()
    } else {
        format!(
            "flags of this command: {}",
            allowed
                .iter()
                .map(|k| format!("--{k}"))
                .collect::<Vec<_>>()
                .join(" ")
        )
    };
    bail!(
        "unknown flag{} {} ({accepted})",
        if unknown.len() == 1 { "" } else { "s" },
        unknown
            .iter()
            .map(|k| format!("--{k}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
}

/// Wire `--metrics-out <file>` into the engine's recorder: create the
/// JSONL sink, attach it, and emit the `run_start` event. Telemetry is
/// strictly observational — the tuning trace is byte-identical with or
/// without a sink (pinned in `tests/telemetry.rs`).
fn attach_metrics(
    args: &Args,
    cmd: &str,
    engine: &Engine,
    fields: Vec<(&str, Json)>,
) -> Result<()> {
    let Some(path) = args.get("metrics-out") else {
        return Ok(());
    };
    let sink = EventSink::create(path)
        .with_context(|| format!("--metrics-out {path}"))?;
    engine.recorder().attach_sink(sink);
    engine.recorder().emit_run_start(cmd, fields);
    console::verbose(&format!("telemetry: events -> {path}"));
    Ok(())
}

/// Best-schedule candidate from one finished trace (when it found a
/// valid configuration), ready for [`promote_schedules`].
fn schedule_candidate(
    trace: &TuningTrace,
    layer: &ConvLayer,
    space: SpaceKind,
    hw: &VtaConfig,
) -> Option<ScheduleEntry> {
    let cycles = trace.best_cycles()?;
    let best = trace
        .trials
        .iter()
        .find(|t| t.outcome.cycles() == Some(cycles))?;
    Some(ScheduleEntry {
        key: ScheduleKey::for_layer_on(layer, space, hw),
        version: 0, // assigned by the store
        cycles,
        schedule: best.schedule,
        layer: layer.name.to_string(),
        target: hw.target.clone(),
        tuner: trace.tuner.clone(),
        trials: trace.len() as u64,
    })
}

/// Append a run's best schedules to the `--schedule-db` store (open or
/// create, better-only versioned promotion) and report the tally.
fn promote_schedules(
    dir: &str,
    candidates: Vec<ScheduleEntry>,
) -> Result<()> {
    if candidates.is_empty() {
        console::info(&format!(
            "schedule db {dir}: no valid results to promote"
        ));
        return Ok(());
    }
    let db = ScheduleDb::open(dir)?;
    let (mut inserted, mut promoted, mut kept) = (0usize, 0usize, 0usize);
    for c in candidates {
        match db.promote(c)? {
            Promotion::Inserted => inserted += 1,
            Promotion::Promoted { .. } => promoted += 1,
            Promotion::Kept { .. } => kept += 1,
        }
    }
    console::info(&format!(
        "schedule db {dir}: {inserted} inserted, {promoted} promoted, \
         {kept} kept ({} entries total)",
        db.len()
    ));
    Ok(())
}

fn layer_arg(args: &Args, net: &Network) -> Result<ConvLayer> {
    match args.get("layer") {
        None => Ok(net.layers[0]),
        Some(name) => net.layer(name).ok_or_else(|| {
            anyhow!(
                "unknown layer '{name}' of network '{}' (layers: {})",
                net.name,
                net.layer_names().join(", ")
            )
        }),
    }
}

/// Load the `--transfer-from` store, when given — but only for the
/// policy that can use it; the baselines get a note instead of paying
/// for the directory parse.
fn transfer_arg(args: &Args, kind: TunerKind) -> Result<Option<TransferDb>> {
    let Some(dir) = args.get("transfer-from") else {
        return Ok(None);
    };
    if kind != TunerKind::Ml2 {
        console::info(&format!(
            "note: --transfer-from only warm-starts the ml2tuner \
             policy; {} runs cold",
            kind.name()
        ));
        return Ok(None);
    }
    let store = TransferDb::load_dir(dir)?;
    if store.is_empty() {
        bail!("--transfer-from {dir}: no tuning logs found");
    }
    let skipped = if store.skipped > 0 {
        format!(" ({} unparseable files skipped)", store.skipped)
    } else {
        String::new()
    };
    console::info(&format!(
        "transfer store: {} layer logs, {} records{skipped} from {dir}",
        store.n_layers(),
        store.total_records()
    ));
    Ok(Some(store))
}

/// Load the `--meta <dir>` artifact store, when given — like
/// [`transfer_arg`], only for the policy that can adapt from it.
fn meta_arg(args: &Args, kind: TunerKind) -> Result<Option<MetaStore>> {
    let Some(dir) = args.get("meta") else {
        return Ok(None);
    };
    if kind != TunerKind::Ml2 {
        console::info(&format!(
            "note: --meta only seeds the ml2tuner policy; {} runs cold",
            kind.name()
        ));
        return Ok(None);
    }
    let store = MetaStore::load(dir)?;
    console::info(&format!(
        "meta store: {} artifact(s) from {dir}",
        store.len()
    ));
    Ok(Some(store))
}

/// `--meta` narrowed to the one space the run searches: the artifact
/// for that space kind, or a console note when the store has none.
fn meta_for_space(
    store: Option<MetaStore>,
    space: SpaceKind,
) -> Option<MetaArtifact> {
    let mut store = store?;
    match store.take_kind(space) {
        Some(art) => {
            console::info(&format!(
                "meta: adapting from {} corpus records ({} space)",
                art.records,
                space.name()
            ));
            Some(art)
        }
        None => {
            console::info(&format!(
                "meta: no artifact for the {} space — starting cold",
                space.name()
            ));
            None
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    // info reports the whole registry, so it reads no flags — but it
    // still errors on stray ones like every sibling command
    expect_flags(args, &[])?;
    let cfg = VtaConfig::zcu102();
    println!("ml2tuner — extended-VTA ({} + {} more targets) simulated \
              testbed", cfg.target, targets::TARGET_NAMES.len() - 1);
    let mut hw = Table::new(&["target", "INP vecs", "WGT blocks",
                              "ACC vecs", "UOP uops", "DMA B/cyc",
                              "clock MHz"]);
    for t in targets::all() {
        hw.row(&[
            t.target.clone(),
            t.inp_capacity().to_string(),
            t.wgt_capacity().to_string(),
            t.acc_capacity().to_string(),
            t.uop_capacity().to_string(),
            t.dma_bytes_per_cycle.to_string(),
            t.clock_mhz.to_string(),
        ]);
    }
    hw.print();
    println!(
        "  GEMM block {}x{} (all targets)  shift {}  — space sizes \
         below are per layer",
        cfg.block(),
        cfg.block(),
        cfg.shift
    );
    let mut nets = Table::new(&["network", "layers", "total MACs",
                                "description"]);
    for net in &workloads::NETWORKS {
        nets.row(&[
            net.name.to_string(),
            net.layers.len().to_string(),
            net.total_macs().to_string(),
            net.description.to_string(),
        ]);
    }
    nets.print();
    for net in &workloads::NETWORKS {
        println!("\n-- {} --", net.name);
        let mut t = Table::new(&["layer", "H,W,C", "KC,KH,KW", "OH,OW",
                                 "pad,stride", "space paper/extended"]);
        for l in net.layers {
            let paper = schedule::space_for(l, SpaceKind::Paper);
            let ext = schedule::space_for(l, SpaceKind::Extended);
            t.row(&[
                l.name.to_string(),
                format!("{},{},{}", l.h, l.w, l.c),
                format!("{},{},{}", l.kc, l.kh, l.kw),
                format!("{},{}", l.oh, l.ow),
                format!("{},{}", l.pad, l.stride),
                format!("{} / {}", paper.len(), ext.len()),
            ]);
        }
        t.print();
    }
    match Runtime::open_default() {
        Ok(rt) => println!(
            "artifacts: OK ({} layers, platform {})",
            rt.layer_names().len(),
            rt.platform()
        ),
        Err(e) => println!("artifacts: unavailable ({e}) — run `make \
                            artifacts`"),
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    expect_flags(args, &["network", "layer", "target", "tuner",
                         "trials", "seed", "jobs", "space", "v-margin",
                         "prescreen-factor", "db", "schedule-db",
                         "transfer-from", "transfer-cap", "meta",
                         "incremental", "retrain-every", "metrics-out",
                         "quiet", "verbose"])?;
    let net = network_arg(args)?;
    let layer = layer_arg(args, net)?;
    let hw = target_arg(args)?;
    let trials = args.get_usize("trials", 300)?;
    let seed = args.get_u64("seed", 0)?;
    let jobs = args.get_usize("jobs", default_jobs())?;
    let space = space_arg(args)?;
    let v_margin =
        args.get_f64("v-margin", ml2tuner::tuner::DEFAULT_V_MARGIN)?;
    let prescreen_factor = args.get_usize("prescreen-factor", 0)?;
    let cfg = TunerConfig { seed, max_trials: trials, v_margin,
                            prescreen_factor,
                            incremental: args.has("incremental"),
                            retrain_every:
                                args.get_usize("retrain-every", 0)?,
                            ..Default::default() };
    let env = TuningEnv::with_space(hw.clone(), layer, space);
    console::info(&format!(
        "target: {}   space: {} ({} configurations)",
        hw.target,
        space.name(),
        env.space.len()
    ));
    let tuner_name = args.get("tuner").unwrap_or("ml2tuner");
    let kind = TunerKind::parse(tuner_name)
        .ok_or_else(|| anyhow!("unknown tuner '{tuner_name}'"))?;
    let transfer = transfer_arg(args, kind)?;
    let meta = meta_arg(args, kind)?;
    let mut tuner: Box<dyn Tuner> = match kind {
        TunerKind::Ml2 => {
            let mut t = Ml2Tuner::new(cfg);
            if let Some(store) = &transfer {
                let cap = args.get_usize("transfer-cap", 400)?;
                match store.warm_start_for(&layer, space, &hw, cap) {
                    Some(warm) => {
                        console::info(&format!(
                            "warm start: {} transferred records for {}",
                            warm.len(),
                            layer.name
                        ));
                        t = t.with_warm_start(warm);
                    }
                    None => console::info(&format!(
                        "warm start: no shape-similar source for {} — \
                         starting cold",
                        layer.name
                    )),
                }
            }
            if let Some(art) = meta_for_space(meta, space) {
                t = t.with_meta(art);
            }
            Box::new(t)
        }
        TunerKind::Tvm => Box::new(TvmTuner::new(cfg)),
        TunerKind::Random => Box::new(RandomTuner::new(cfg)),
    };
    let engine = Engine::with_jobs(jobs);
    attach_metrics(args, "tune", &engine, vec![
        ("network", Json::Str(net.name.to_string())),
        ("layer", Json::Str(layer.name.to_string())),
        ("target", Json::Str(hw.target.clone())),
        ("tuner", Json::Str(kind.name().to_string())),
        ("space", Json::Str(space.name().to_string())),
        ("trials", Json::Num(trials as f64)),
        ("seed", Json::Num(seed as f64)),
        ("jobs", Json::Num(jobs as f64)),
        ("v_margin", Json::Num(v_margin)),
        ("prescreen_factor", Json::Num(prescreen_factor as f64)),
    ])?;
    let t0 = std::time::Instant::now();
    let trace = tuner.tune_with(&env, &engine);
    engine.recorder().emit_run_end();
    let sim = Simulator::new(hw.clone());
    let cache = engine.cache().stats();
    console::info(&format!(
        "{} on {}: {} trials in {:.1}s ({} jobs, compile cache {} hits / \
         {} lookups)",
        trace.tuner,
        layer.name,
        trace.len(),
        t0.elapsed().as_secs_f64(),
        engine.jobs(),
        cache.hits,
        cache.lookups()
    ));
    match trace.best_cycles() {
        Some(c) => {
            let best = trace
                .trials
                .iter()
                .find(|t| t.outcome.cycles() == Some(c))
                .unwrap();
            console::result(&format!(
                "best: {} = {} cycles ({:.3} ms @ {} MHz)",
                best.schedule,
                c,
                sim.cycles_to_ms(c),
                sim.cfg.clock_mhz
            ));
        }
        None => console::result("no valid configuration found"),
    }
    console::result(&format!(
        "invalidity ratio: {:.3} (crash/wrong: {:?})",
        trace.invalidity_ratio(),
        trace.invalid_counts()
    ));
    console::info(&format!(
        "estimated board wall-clock: {:.0}s",
        trace.estimated_wall_clock(&ProfilingCostModel::default())
    ));
    if let Some(path) = args.get("db") {
        let mut db = Database::for_layer_on(&layer, space, &hw);
        for r in &trace.trials {
            db.push(r.clone());
        }
        db.save(path)?;
        console::info(&format!("tuning log saved to {path}"));
    }
    if let Some(dir) = args.get("schedule-db") {
        let candidates = schedule_candidate(&trace, &layer, space, &hw)
            .into_iter()
            .collect();
        promote_schedules(dir, candidates)?;
    }
    Ok(())
}

fn cmd_tune_net(args: &Args) -> Result<()> {
    expect_flags(args, &["network", "target", "tuner", "trials",
                         "round", "seed", "jobs", "layers", "space",
                         "v-margin", "prescreen-factor", "out",
                         "schedule-db", "transfer-from", "transfer-cap",
                         "meta", "incremental", "retrain-every",
                         "metrics-out", "quiet", "verbose"])?;
    let net = network_arg(args)?;
    let trials = args.get_usize("trials", 1000)?;
    let round = args.get_usize("round", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let jobs = args.get_usize("jobs", default_jobs())?;
    let tuner_name = args.get("tuner").unwrap_or("ml2tuner");
    let tuner = TunerKind::parse(tuner_name)
        .ok_or_else(|| anyhow!("unknown tuner '{tuner_name}'"))?;
    let layers = layers_arg(args, net)?;
    let space = space_arg(args)?;
    let hw = target_arg(args)?;
    let v_margin =
        args.get_f64("v-margin", ml2tuner::tuner::DEFAULT_V_MARGIN)?;
    let prescreen_factor = args.get_usize("prescreen-factor", 0)?;
    let cfg = NetworkConfig {
        vta: hw.clone(),
        tuner,
        space,
        total_trials: trials,
        round_trials: round,
        base: TunerConfig { seed, v_margin, prescreen_factor,
                            incremental: args.has("incremental"),
                            retrain_every:
                                args.get_usize("retrain-every", 0)?,
                            ..Default::default() },
        transfer: transfer_arg(args, tuner)?,
        transfer_cap: args.get_usize("transfer-cap", 400)?,
        meta: meta_for_space(meta_arg(args, tuner)?, space)
            .map(Arc::new),
        ..Default::default()
    };
    let engine = Engine::with_jobs(jobs);
    attach_metrics(args, "tune-net", &engine, vec![
        ("network", Json::Str(net.name.to_string())),
        ("target", Json::Str(hw.target.clone())),
        ("tuner", Json::Str(tuner.name().to_string())),
        ("space", Json::Str(space.name().to_string())),
        ("layers", Json::Num(layers.len() as f64)),
        ("trials", Json::Num(trials as f64)),
        ("seed", Json::Num(seed as f64)),
        ("jobs", Json::Num(jobs as f64)),
        ("v_margin", Json::Num(v_margin)),
        ("prescreen_factor", Json::Num(prescreen_factor as f64)),
    ])?;
    let t0 = std::time::Instant::now();
    console::info(&format!(
        "tuning {} on {} ({} layers, {} trials, {} space)",
        net.name,
        hw.target,
        layers.len(),
        trials,
        space.name()
    ));
    let outcome = NetworkTuner::new(cfg).tune(&engine, &layers);
    engine.recorder().emit_run_end();
    console::result(outcome.report.render().trim_end());
    let cache = engine.cache().stats();
    console::info(&format!(
        "wall-clock {:.1}s ({} jobs, compile cache {} hits / {} lookups, \
         {:.1}% hit rate)",
        t0.elapsed().as_secs_f64(),
        engine.jobs(),
        cache.hits,
        cache.lookups(),
        cache.hit_rate() * 100.0
    ));
    if let Some(dir) = args.get("out") {
        let paths = outcome.save_databases(dir)?;
        console::info(&format!(
            "{} per-layer tuning logs saved to {dir}/",
            paths.len()
        ));
    }
    if let Some(dir) = args.get("schedule-db") {
        let candidates = outcome
            .traces
            .iter()
            .filter_map(|trace| {
                let layer =
                    layers.iter().find(|l| l.name == trace.layer)?;
                schedule_candidate(trace, layer, space, &hw)
            })
            .collect();
        promote_schedules(dir, candidates)?;
    }
    Ok(())
}

fn cmd_tune_fleet(args: &Args) -> Result<()> {
    expect_flags(args, &["network", "targets", "tuner", "trials",
                         "round", "seed", "jobs", "layers", "space",
                         "v-margin", "prescreen-factor", "out",
                         "schedule-db", "transfer-from", "transfer-cap",
                         "meta", "incremental", "retrain-every",
                         "metrics-out", "quiet", "verbose"])?;
    let net = network_arg(args)?;
    let fleet_targets = targets_arg(args)?;
    let trials = args.get_usize("trials", 1000)?;
    let round = args.get_usize("round", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let jobs = args.get_usize("jobs", default_jobs())?;
    let tuner_name = args.get("tuner").unwrap_or("ml2tuner");
    let tuner = TunerKind::parse(tuner_name)
        .ok_or_else(|| anyhow!("unknown tuner '{tuner_name}'"))?;
    let layers = layers_arg(args, net)?;
    let space = space_arg(args)?;
    let v_margin =
        args.get_f64("v-margin", ml2tuner::tuner::DEFAULT_V_MARGIN)?;
    let prescreen_factor = args.get_usize("prescreen-factor", 0)?;
    let cfg = FleetConfig {
        targets: fleet_targets.clone(),
        tuner,
        space,
        base: TunerConfig { seed, v_margin, prescreen_factor,
                            incremental: args.has("incremental"),
                            retrain_every:
                                args.get_usize("retrain-every", 0)?,
                            ..Default::default() },
        total_trials: trials,
        round_trials: round,
        transfer: transfer_arg(args, tuner)?,
        transfer_cap: args.get_usize("transfer-cap", 400)?,
        meta: meta_for_space(meta_arg(args, tuner)?, space)
            .map(Arc::new),
        ..Default::default()
    };
    let engine = Engine::with_jobs(jobs);
    attach_metrics(args, "tune-fleet", &engine, vec![
        ("network", Json::Str(net.name.to_string())),
        ("targets", Json::Arr(
            fleet_targets
                .iter()
                .map(|t| Json::Str(t.target.clone()))
                .collect(),
        )),
        ("tuner", Json::Str(tuner.name().to_string())),
        ("space", Json::Str(space.name().to_string())),
        ("layers", Json::Num(layers.len() as f64)),
        ("trials", Json::Num(trials as f64)),
        ("seed", Json::Num(seed as f64)),
        ("jobs", Json::Num(jobs as f64)),
        ("v_margin", Json::Num(v_margin)),
        ("prescreen_factor", Json::Num(prescreen_factor as f64)),
    ])?;
    let t0 = std::time::Instant::now();
    console::info(&format!(
        "fleet-tuning {} across {} targets ({} layers, {} global \
         trials, {} space)",
        net.name,
        fleet_targets.len(),
        layers.len(),
        trials,
        space.name()
    ));
    let outcome = FleetTuner::new(cfg).tune(&engine, &layers);
    engine.recorder().emit_run_end();
    console::result(outcome.render().trim_end());
    for run in &outcome.runs {
        console::result(&format!("\n-- {} --", run.target));
        console::result(run.outcome.report.render().trim_end());
    }
    let cache = engine.cache().stats();
    console::info(&format!(
        "wall-clock {:.1}s ({} jobs, fleet-shared compile cache {} hits \
         / {} lookups, {:.1}% hit rate)",
        t0.elapsed().as_secs_f64(),
        engine.jobs(),
        cache.hits,
        cache.lookups(),
        cache.hit_rate() * 100.0
    ));
    if let Some(dir) = args.get("out") {
        let paths = outcome.save_databases(dir)?;
        console::info(&format!(
            "{} tuning logs saved under {dir}/<target>/",
            paths.len()
        ));
    }
    if let Some(dir) = args.get("schedule-db") {
        let mut candidates = Vec::new();
        for run in &outcome.runs {
            let Some(hw) =
                fleet_targets.iter().find(|t| t.target == run.target)
            else {
                continue;
            };
            for trace in &run.outcome.traces {
                let Some(layer) =
                    layers.iter().find(|l| l.name == trace.layer)
                else {
                    continue;
                };
                candidates
                    .extend(schedule_candidate(trace, layer, space, hw));
            }
        }
        promote_schedules(dir, candidates)?;
    }
    Ok(())
}

/// `ml2tuner train-meta`: offline corpus training. Ingest a directory
/// of accumulated tuning logs, fit the base P/V/A ensembles per space
/// kind at the full offline budget, and write one versioned artifact
/// file per kind — what the tune commands and `serve` load back with
/// `--meta <dir>`.
fn cmd_train_meta(args: &Args) -> Result<()> {
    expect_flags(args, &["corpus", "out", "rounds", "quiet",
                         "verbose"])?;
    let corpus_dir = args
        .get("corpus")
        .ok_or_else(|| anyhow!("train-meta requires --corpus <dir>"))?;
    let out_dir = args
        .get("out")
        .ok_or_else(|| anyhow!("train-meta requires --out <dir>"))?;
    let rounds = args.get_usize("rounds", META_BOOST_ROUNDS)?;
    let corpus = TransferDb::load_dir(corpus_dir)?;
    if corpus.is_empty() {
        bail!("--corpus {corpus_dir}: no tuning logs found");
    }
    let skipped = if corpus.skipped > 0 {
        format!(" ({} unparseable files skipped)", corpus.skipped)
    } else {
        String::new()
    };
    console::info(&format!(
        "corpus: {} layer logs, {} records{skipped} from {corpus_dir}",
        corpus.n_layers(),
        corpus.total_records()
    ));
    let store = MetaStore::build_with(&corpus, rounds);
    if store.is_empty() {
        bail!(
            "corpus produced no trainable meta ensembles (need at \
             least 2 perf-labelled records of one space kind)"
        );
    }
    let paths = store.save(out_dir)?;
    for (kind, art) in store.iter() {
        console::result(&format!(
            "meta[{kind}]: {} source logs, {} records -> P {}, A {}, \
             {} V bucket(s)",
            art.sources,
            art.records,
            if art.p.is_some() { "yes" } else { "no" },
            if art.a.is_some() { "yes" } else { "no" },
            art.v.len()
        ));
    }
    console::info(&format!(
        "{} artifact file(s) written to {out_dir}/",
        paths.len()
    ));
    Ok(())
}

/// `ml2tuner serve`: long-running tuning-as-a-service daemon over a
/// `--schedule-db` store. Protocol responses go to stdout (or the TCP
/// client); all daemon status chatter goes to stderr so the stdio
/// transport stays machine-readable.
fn cmd_serve(args: &Args) -> Result<()> {
    expect_flags(args, &["schedule-db", "listen", "workers", "queue",
                         "miss-trials", "seed", "jobs", "transfer-from",
                         "transfer-cap", "meta", "metrics-out", "quiet",
                         "verbose"])?;
    let dir = args
        .get("schedule-db")
        .ok_or_else(|| anyhow!("serve requires --schedule-db <dir>"))?;
    let db = Arc::new(ScheduleDb::open(dir)?);
    let skipped = if db.skipped() > 0 {
        format!(" ({} unparseable files skipped)", db.skipped())
    } else {
        String::new()
    };
    eprintln!(
        "ml2tuner serve: schedule db {dir}: {} entries{skipped}",
        db.len()
    );
    // not transfer_arg(): that helper narrates on stdout, which here
    // belongs to the response protocol
    let transfer = match args.get("transfer-from") {
        None => None,
        Some(tdir) => {
            let store = TransferDb::load_dir(tdir)?;
            if store.is_empty() {
                bail!("--transfer-from {tdir}: no tuning logs found");
            }
            eprintln!(
                "ml2tuner serve: transfer store: {} layer logs, {} \
                 records from {tdir}",
                store.n_layers(),
                store.total_records()
            );
            Some(store)
        }
    };
    // --meta likewise narrates on stderr only
    let meta = match args.get("meta") {
        None => None,
        Some(mdir) => {
            let store = MetaStore::load(mdir)?;
            eprintln!(
                "ml2tuner serve: meta store: {} artifact(s) from {mdir}",
                store.len()
            );
            Some(store)
        }
    };
    let cfg = ServeConfig {
        workers: args.get_usize("workers", 2)?.max(1),
        queue_cap: args.get_usize("queue", 16)?.max(1),
        miss_trials: args.get_usize("miss-trials", 60)?.max(1),
        seed: args.get_u64("seed", 0)?,
        jobs: args.get_usize("jobs", 1)?.max(1),
        transfer,
        transfer_cap: args.get_usize("transfer-cap", 400)?,
        meta,
    };
    eprintln!(
        "ml2tuner serve: {} workers, queue {}, {} miss trials",
        cfg.workers, cfg.queue_cap, cfg.miss_trials
    );
    let mut daemon = Daemon::new(cfg, db);
    if let Some(path) = args.get("metrics-out") {
        let sink = SharedSink::create(path)
            .with_context(|| format!("--metrics-out {path}"))?;
        daemon = daemon.with_metrics(sink);
        eprintln!("ml2tuner serve: job telemetry -> {path}");
    }
    match args.get("listen") {
        Some(addr) => daemon.serve_tcp(addr),
        None => {
            eprintln!("ml2tuner serve: reading requests from stdin");
            daemon
                .run(std::io::stdin().lock(), std::io::stdout())
                .map(|_| ())
        }
    }
}

/// `ml2tuner report <events.jsonl...>`: aggregate telemetry event files
/// written by `--metrics-out` into per-stage time, cache, and
/// model-quality tables. Every line is schema-validated; a malformed
/// event is a hard error (CI runs this as the schema check).
fn cmd_report(args: &Args) -> Result<()> {
    expect_flags(args, &["quiet", "verbose"])?;
    if args.positional.is_empty() {
        bail!("report expects one or more event files \
               (ml2tuner report events.jsonl ...)");
    }
    let report = obs::report::aggregate(&args.positional)?;
    console::result(report.render().trim_end());
    Ok(())
}

fn parse_schedule(text: &str) -> Result<Schedule> {
    let parts: Vec<usize> = text
        .split(',')
        .map(|p| p.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .context("--schedule expects TH,TW,OC,IC,VT[,SLOTS,UNROLL] \
                  integers")?;
    if parts.len() != 5 && parts.len() != 7 {
        bail!("--schedule expects 5 (paper knobs) or 7 (paper + \
               nLoadSlots,kernelUnroll) comma-separated values");
    }
    let mut s = Schedule {
        tile_h: parts[0],
        tile_w: parts[1],
        tile_oc: parts[2],
        tile_ic: parts[3],
        n_vthreads: parts[4],
        ..Default::default()
    };
    if parts.len() == 7 {
        s.n_load_slots = parts[5];
        s.k_unroll = parts[6];
    }
    Ok(s)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    expect_flags(args, &["network", "layer", "target", "schedule",
                         "space", "numeric", "seed"])?;
    let net = network_arg(args)?;
    let layer = layer_arg(args, net)?;
    let sched = parse_schedule(
        args.get("schedule").ok_or_else(|| anyhow!("--schedule required"))?,
    )?;
    // a 7-value schedule exercises the extended primitives, so report
    // its hidden features in the extended layout
    let space = match args.get("space") {
        None if sched.n_load_slots != 2 || sched.k_unroll != 1 => {
            SpaceKind::Extended
        }
        _ => space_arg(args)?,
    };
    let cfg = target_arg(args)?;
    let compiler = Compiler::with_kind(cfg.clone(), space);
    let sim = Simulator::new(cfg.clone());
    let compiled = compiler.compile(&layer, &sched);
    println!(
        "{} {} on {}: {} instrs, {} gemm block-ops, {} dma bytes",
        layer.name,
        sched,
        cfg.target,
        compiled.program.len(),
        compiled.stats.gemm_block_ops,
        compiled.stats.dma_bytes
    );
    let verdict = sim.check(&compiled.program);
    println!("verdict: {verdict:?}");
    if verdict.is_valid() {
        println!(
            "execution time: {:.3} ms",
            sim.cycles_to_ms(verdict.cycles())
        );
    }
    let names = ml2tuner::compiler::features::hidden_names(space);
    let hidden = compiler.hidden_features(&compiled);
    let mut t = Table::new(&["hidden feature", "value"]);
    for (n, v) in names.iter().zip(&hidden) {
        t.row(&[n.to_string(), v.to_string()]);
    }
    t.print();
    if args.has("numeric") && verdict.is_valid() {
        let mut rt = Runtime::open_default()?;
        let seed = args.get_u64("seed", 1)?;
        let ok = numeric_vs_golden(&mut rt, &sim, &layer, &compiled, seed)?;
        println!("numeric vs golden: {}", if ok { "BIT-EXACT" } else {
            "MISMATCH"
        });
    }
    Ok(())
}

/// Run the compiled program numerically and compare against the PJRT
/// golden output. Returns bit-exactness.
fn numeric_vs_golden(
    rt: &mut Runtime,
    sim: &Simulator,
    layer: &ConvLayer,
    compiled: &ml2tuner::compiler::Compiled,
    seed: u64,
) -> Result<bool> {
    let x = synth::input_data(layer, seed);
    let w = synth::weight_data(layer, seed);
    let dram = functional::Dram {
        inp: layout::pack_input(&sim.cfg, &x, layer.h, layer.w, layer.c),
        wgt: layout::pack_weights(&sim.cfg, &w, layer.kh, layer.kw,
                                  layer.c, layer.kc),
        out_vecs: compiled.program.dram_out_vecs,
    };
    let out = sim
        .execute(&compiled.program, &dram)
        .map_err(|f| anyhow!("simulator fault: {f:?}"))?;
    let gold = golden::golden_output(rt, layer, seed)?;
    Ok(out == gold)
}

fn cmd_validate(args: &Args) -> Result<()> {
    expect_flags(args, &["network", "layer", "target", "samples",
                         "seed", "space"])?;
    // the AOT JAX/Pallas golden artifacts exist for resnet18 only
    // (network_arg reports unknown names with the registry list)
    let resnet = network_arg(args)?;
    if resnet.name != "resnet18" {
        bail!("validate: golden AOT artifacts exist for resnet18 only \
               (got --network {})", resnet.name);
    }
    // golden artifacts are lowered for the zcu102 (shift, layout);
    // reject other targets instead of "validating" against the wrong
    // reference
    let cfg = target_arg(args)?;
    if cfg.target != "zcu102" {
        bail!("validate: golden AOT artifacts exist for zcu102 only \
               (got --target {})", cfg.target);
    }
    let compiler = Compiler::new(cfg.clone());
    let sim = Simulator::new(cfg.clone());
    let mut rt = Runtime::open_default()?;
    let samples = args.get_usize("samples", 5)?;
    let seed = args.get_u64("seed", 42)?;
    let layers: Vec<ConvLayer> = match args.get("layer") {
        Some(_) => vec![layer_arg(args, resnet)?],
        None => resnet18::LAYERS.to_vec(),
    };
    let space_kind = space_arg(args)?;
    let mut rng = Rng::new(seed);
    let mut checked = 0usize;
    for layer in layers {
        rt.check_layer(&layer)?;
        let space = schedule::space_for(&layer, space_kind);
        let mut found = 0usize;
        let mut attempts = 0usize;
        while found < samples && attempts < samples * 60 {
            attempts += 1;
            let sched = space.schedule(rng.below(space.len()));
            let compiled = compiler.compile(&layer, &sched);
            if !sim.check(&compiled.program).is_valid() {
                continue;
            }
            found += 1;
            let ok = numeric_vs_golden(&mut rt, &sim, &layer, &compiled,
                                       seed ^ found as u64)?;
            checked += 1;
            println!(
                "{} {} -> {}",
                layer.name,
                sched,
                if ok { "BIT-EXACT vs golden" } else { "MISMATCH" }
            );
            if !ok {
                bail!("golden mismatch on a check()-valid config — \
                       simulator/compiler bug");
            }
        }
    }
    println!("validate: {checked} valid configs bit-exact vs the AOT \
              JAX/Pallas golden model");
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    expect_flags(args, &["quick", "repeats", "seed", "target", "meta"])?;
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut cfg = if args.has("quick") {
        ExpConfig::quick()
    } else {
        ExpConfig::full()
    };
    cfg.repeats = args.get_usize("repeats", cfg.repeats)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.hw = target_arg(args)?;
    // --meta is a value flag elsewhere (tune --meta <dir>), so the
    // parser swallows a following bare token; insist it was used as a
    // bare switch here rather than eating the experiment id
    cfg.meta = match args.get("meta") {
        None => false,
        Some("true") => true,
        Some("false") => false,
        Some(v) => bail!(
            "--meta takes no value for `experiment` (got '{v}'); place \
             it after the experiment id"
        ),
    };
    if id == "all" {
        for id in experiments::ALL {
            experiments::run(id, &cfg)?;
        }
        Ok(())
    } else {
        experiments::run(id, &cfg).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_positionals() {
        let argv: Vec<String> = ["fig2a", "--quick", "--seed", "7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["fig2a"]);
        assert!(a.has("quick"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
    }

    #[test]
    fn schedule_parsing() {
        let s = parse_schedule("8,14,32,64,2").unwrap();
        assert_eq!(s.tile_h, 8);
        assert_eq!(s.tile_w, 14);
        assert_eq!(s.n_vthreads, 2);
        assert_eq!((s.n_load_slots, s.k_unroll), (2, 1),
                   "5-value form keeps paper defaults");
        let e = parse_schedule("8,14,32,64,2,1,4").unwrap();
        assert_eq!((e.n_load_slots, e.k_unroll), (1, 4));
        assert!(parse_schedule("1,2,3").is_err());
        assert!(parse_schedule("1,2,3,4,5,6").is_err());
        assert!(parse_schedule("a,b,c,d,e").is_err());
    }
}
