//! Per-layer search space with measurement bookkeeping.

use crate::compiler::schedule::{self, Schedule, ScheduleSpace};
use crate::util::rng::Rng;
use crate::workloads::ConvLayer;

/// The enumerable space for one layer plus a measured-set mask.
#[derive(Clone)]
pub struct SearchSpace {
    space: ScheduleSpace,
    schedules: Vec<Schedule>,
    measured: Vec<bool>,
    n_measured: usize,
}

impl SearchSpace {
    pub fn new(layer: &ConvLayer) -> Self {
        let space = schedule::candidates(layer);
        let schedules = space.all();
        let n = schedules.len();
        SearchSpace { space, schedules, measured: vec![false; n],
                      n_measured: 0 }
    }

    pub fn len(&self) -> usize {
        self.schedules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schedules.is_empty()
    }

    pub fn schedule(&self, i: usize) -> Schedule {
        self.schedules[i]
    }

    pub fn schedules(&self) -> &[Schedule] {
        &self.schedules
    }

    pub fn raw_space(&self) -> &ScheduleSpace {
        &self.space
    }

    pub fn is_measured(&self, i: usize) -> bool {
        self.measured[i]
    }

    pub fn mark_measured(&mut self, i: usize) {
        if !self.measured[i] {
            self.measured[i] = true;
            self.n_measured += 1;
        }
    }

    pub fn n_measured(&self) -> usize {
        self.n_measured
    }

    pub fn n_unmeasured(&self) -> usize {
        self.len() - self.n_measured
    }

    /// Indices not yet measured.
    pub fn unmeasured(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| !self.measured[i]).collect()
    }

    /// Sample up to `k` distinct unmeasured indices.
    pub fn sample_unmeasured(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        let pool = self.unmeasured();
        if pool.len() <= k {
            return pool;
        }
        rng.sample_indices(pool.len(), k)
            .into_iter()
            .map(|j| pool[j])
            .collect()
    }

    /// Reset the measured mask (fresh tuning run on the same space).
    pub fn reset(&mut self) {
        self.measured.fill(false);
        self.n_measured = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn bookkeeping() {
        let l = resnet18::layer("conv5").unwrap();
        let mut s = SearchSpace::new(&l);
        let n = s.len();
        assert!(n > 100);
        assert_eq!(s.n_unmeasured(), n);
        s.mark_measured(5);
        s.mark_measured(5); // idempotent
        assert_eq!(s.n_measured(), 1);
        assert!(!s.unmeasured().contains(&5));
        s.reset();
        assert_eq!(s.n_measured(), 0);
    }

    #[test]
    fn sampling_avoids_measured() {
        let l = resnet18::layer("conv5").unwrap();
        let mut s = SearchSpace::new(&l);
        for i in 0..s.len() / 2 {
            s.mark_measured(i);
        }
        let mut rng = Rng::new(1);
        let picks = s.sample_unmeasured(&mut rng, 50);
        assert_eq!(picks.len(), 50);
        assert!(picks.iter().all(|&i| i >= s.len() / 2));
    }
}
