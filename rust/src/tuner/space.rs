//! Per-layer search space with measurement bookkeeping.
//!
//! Backed by the lazy [`ConfigSpace`]: points are enumerated on demand
//! (`nth` decode per access), nothing is materialized up front, and the
//! measured set is sparse — memory is O(measured + knob candidates),
//! independent of how large the cross product grows (asserted in
//! `tests/extended_space.rs`).

use std::collections::HashSet;

use crate::compiler::schedule::{self, ConfigSpace, Schedule, SpaceKind};
use crate::util::rng::Rng;
use crate::workloads::ConvLayer;

/// The enumerable space for one layer plus a measured-set mask.
#[derive(Clone)]
pub struct SearchSpace {
    space: ConfigSpace,
    measured: HashSet<usize>,
}

impl SearchSpace {
    /// Paper-exact space (pre-refactor behaviour).
    pub fn new(layer: &ConvLayer) -> Self {
        Self::with_kind(layer, SpaceKind::Paper)
    }

    /// Space over a chosen knob set.
    pub fn with_kind(layer: &ConvLayer, kind: SpaceKind) -> Self {
        SearchSpace {
            space: schedule::space_for(layer, kind),
            measured: HashSet::new(),
        }
    }

    /// Which knob set this space enumerates.
    pub fn kind(&self) -> SpaceKind {
        self.space.kind()
    }

    /// Total number of configurations.
    pub fn len(&self) -> usize {
        self.space.len()
    }

    /// True if the space has no configurations.
    pub fn is_empty(&self) -> bool {
        self.space.is_empty()
    }

    /// Lazily decode the `i`-th schedule.
    pub fn schedule(&self, i: usize) -> Schedule {
        self.space.schedule(i)
    }

    /// Visible feature vector of the `i`-th configuration, in this
    /// space's feature layout.
    pub fn visible(&self, i: usize) -> Vec<f64> {
        self.space.visible(i)
    }

    /// Fill `out` (cleared first) with the `i`-th configuration's
    /// visible features — the allocation-free variant of
    /// [`SearchSpace::visible`] the explorer's scoring sweep uses to
    /// reuse one buffer per chunk (bit-identical values).
    pub fn visible_into(&self, i: usize, out: &mut Vec<f64>) {
        self.space.visible_into(i, out);
    }

    /// Visible-feature count (row width of a scoring-sweep matrix).
    pub fn n_visible(&self) -> usize {
        self.space.n_visible()
    }

    /// The underlying lazy configuration space.
    pub fn config_space(&self) -> &ConfigSpace {
        &self.space
    }

    /// True if index `i` has been profiled.
    pub fn is_measured(&self, i: usize) -> bool {
        self.measured.contains(&i)
    }

    /// Record index `i` as profiled.
    pub fn mark_measured(&mut self, i: usize) {
        self.measured.insert(i);
    }

    /// Configurations profiled so far.
    pub fn n_measured(&self) -> usize {
        self.measured.len()
    }

    /// Configurations not yet profiled.
    pub fn n_unmeasured(&self) -> usize {
        self.len() - self.measured.len()
    }

    /// Indices not yet measured, ascending.
    pub fn unmeasured(&self) -> Vec<usize> {
        (0..self.len()).filter(|i| !self.measured.contains(i)).collect()
    }

    /// Sample up to `k` distinct unmeasured indices.
    pub fn sample_unmeasured(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        let pool = self.unmeasured();
        if pool.len() <= k {
            return pool;
        }
        rng.sample_indices(pool.len(), k)
            .into_iter()
            .map(|j| pool[j])
            .collect()
    }

    /// Reset the measured mask (fresh tuning run on the same space).
    pub fn reset(&mut self) {
        self.measured.clear();
    }

    /// Resident bookkeeping size: stored knob candidates + measured
    /// entries. This is what actually scales — NOT `len()`.
    pub fn resident_entries(&self) -> usize {
        self.space.stored_values() + self.measured.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn bookkeeping() {
        let l = resnet18::layer("conv5").unwrap();
        let mut s = SearchSpace::new(&l);
        let n = s.len();
        assert!(n > 100);
        assert_eq!(s.n_unmeasured(), n);
        s.mark_measured(5);
        s.mark_measured(5); // idempotent
        assert_eq!(s.n_measured(), 1);
        assert!(!s.unmeasured().contains(&5));
        s.reset();
        assert_eq!(s.n_measured(), 0);
    }

    #[test]
    fn sampling_avoids_measured() {
        let l = resnet18::layer("conv5").unwrap();
        let mut s = SearchSpace::new(&l);
        for i in 0..s.len() / 2 {
            s.mark_measured(i);
        }
        let mut rng = Rng::new(1);
        let picks = s.sample_unmeasured(&mut rng, 50);
        assert_eq!(picks.len(), 50);
        assert!(picks.iter().all(|&i| i >= s.len() / 2));
    }

    #[test]
    fn extended_space_is_larger_and_lazily_enumerable() {
        let l = resnet18::layer("conv5").unwrap();
        let paper = SearchSpace::new(&l);
        let ext = SearchSpace::with_kind(&l, SpaceKind::Extended);
        assert_eq!(ext.len(), paper.len() * 6);
        // resident bookkeeping barely grows despite the 6× space
        assert!(ext.resident_entries() <= paper.resident_entries() + 5);
        let s = ext.schedule(ext.len() - 1);
        assert_eq!(ext.config_space().index_of_schedule(&s),
                   Some(ext.len() - 1));
        assert_eq!(ext.visible(0).len(), SpaceKind::Extended.n_visible());
    }

    #[test]
    fn visible_into_reuses_the_buffer_and_matches_visible() {
        let l = resnet18::layer("conv5").unwrap();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let s = SearchSpace::with_kind(&l, kind);
            assert_eq!(s.n_visible(), kind.n_visible());
            let mut buf = Vec::new();
            for i in (0..s.len()).step_by(211) {
                s.visible_into(i, &mut buf);
                assert_eq!(buf, s.visible(i), "{kind:?} index {i}");
            }
        }
    }
}
