//! The paper's contribution: multi-level ML tuning.
//!
//! * [`space`] — per-layer search space with measurement bookkeeping.
//! * [`database`] — profiling records (schedule, features, outcome) with
//!   JSON persistence (TVM-style tuning log, shape-stamped), plus the
//!   cross-run [`database::TransferDb`]: a directory of prior logs,
//!   similarity-matched in shape space to warm-start new layers.
//! * [`train`] — the unified [`train::TrainSet`] builder every model
//!   trains through: cold, warm-transferred, and meta-corpus rows are
//!   compositions of `extend_*` calls, not separate training methods.
//! * [`models`] — cost models **P** (performance, visible features),
//!   **V** (validity classifier, visible features) and **A** (performance,
//!   visible ⊕ hidden features) over the [`crate::gbdt`] substrate; one
//!   `fit(&TrainSet, &FitOpts)` per model covers cold fits, warm
//!   continuation, and meta adaptation.
//! * [`meta`] — corpus-trained meta cost models: `train-meta` fits base
//!   P/V/A ensembles over a directory of tuning logs and serializes them
//!   as versioned JSON artifacts; `--meta` loads them so runs are
//!   model-guided from round 1.
//! * [`explorer`] — candidate selection: P-ranking, V-filtering,
//!   ε-greedy exploration, A re-ranking (paper Fig. 1).
//! * [`ml2tuner`] — the full ML²Tuner loop; [`tvm_baseline`] — the
//!   TVM-approach baseline (single model P, invalids penalized);
//!   [`random_baseline`] — random sampling.
//! * [`report`] — tuning traces and the derived curves/ratios the
//!   experiment harnesses print.

pub mod database;
pub mod explorer;
pub mod meta;
pub mod ml2tuner;
pub mod models;
pub mod random_baseline;
pub mod report;
pub mod space;
pub mod train;
pub mod tvm_baseline;

use crate::compiler::schedule::SpaceKind;
use crate::compiler::Compiler;
use crate::engine::Engine;
use crate::vta::{Fault, Simulator, Verdict};
use crate::workloads::ConvLayer;
use database::{Fidelity, Outcome, TrialRecord};
use report::TuningTrace;
use space::SearchSpace;

/// Per-policy RNG stream salts. The standalone tuners and the engine's
/// incremental [`crate::engine::LayerSession`] both derive their stream
/// as `seed ^ salt`, so a session stepped round-by-round replays the
/// standalone tuner exactly (tested in `engine::scheduler`).
pub mod salt {
    /// Stream salt for [`crate::tuner::ml2tuner::Ml2Tuner`].
    pub const ML2: u64 = 0x4d4c_3254;
    /// Stream salt for the TVM-style baseline.
    pub const TVM: u64 = 0x5456_4d21;
    /// Stream salt for the random-search baseline.
    pub const RANDOM: u64 = 0x52_414e_44;
}

/// Build the telemetry round event every tuning loop emits after
/// profiling a batch: outcome counts over the round's new trials
/// (`trace.trials[before..]`), best-so-far, and — when the explorer
/// reported [`explorer::SelectStats`] — the V-quality confusion of
/// predicted validity (`margin > v_margin`) against what profiling
/// actually observed. Fallback-filled vetoed candidates that got
/// profiled anyway land in the TN/FN cells, grounding the veto's
/// negative predictive value.
pub(crate) fn round_event(
    env: &TuningEnv,
    trace: &TuningTrace,
    before: usize,
    round: u64,
    v_margin: f64,
    stats: Option<explorer::SelectStats>,
) -> crate::obs::RoundEvent {
    let new = &trace.trials[before..];
    let valid = new.iter().filter(|t| t.outcome.is_valid()).count();
    let crash =
        new.iter().filter(|t| t.outcome == Outcome::Crash).count();
    let wrong =
        new.iter().filter(|t| t.outcome == Outcome::WrongOutput).count();
    let v = stats.map(|s| {
        let actual: Vec<bool> =
            new.iter().map(|t| t.outcome.is_valid()).collect();
        let (tp, fp, tn, fn_) =
            crate::obs::confusion(&s.margins, v_margin, &actual);
        crate::obs::VQuality { vetoes: s.vetoes, tp, fp, tn, fn_, v_margin }
    });
    crate::obs::RoundEvent {
        target: env.hw().target.to_string(),
        layer: trace.layer.clone(),
        tuner: trace.tuner.clone(),
        space: env.kind().name().to_string(),
        round,
        trials_new: new.len() as u64,
        trials_total: trace.len() as u64,
        valid_new: valid as u64,
        crash_new: crash as u64,
        wrong_new: wrong as u64,
        best_cycles: trace.best_cycles(),
        trials_to_best: trace.trials_to_best().map(|t| t as u64),
        v,
    }
}

/// Classify a simulator verdict into a profiling outcome (paper §A.2:
/// register errors crash the board, hazard corruption "succeeds" with a
/// wrong result; both are invalid).
pub fn outcome_of(verdict: &Verdict) -> Outcome {
    match verdict {
        Verdict::Valid { cycles } => Outcome::Valid { cycles: *cycles },
        Verdict::Invalid { fault: Fault::Corruption(_), .. } => {
            Outcome::WrongOutput
        }
        Verdict::Invalid { .. } => Outcome::Crash,
    }
}

/// Tuning-loop hyper-parameters (paper §3: `N = 10`, `α = 1.0`).
#[derive(Clone, Debug)]
pub struct TunerConfig {
    /// Configurations profiled per iteration (`N`).
    pub n_per_round: usize,
    /// Over-selection factor for the hidden-feature stage (`α`).
    pub alpha: f64,
    /// Total profiling budget (attempts, valid or not).
    pub max_trials: usize,
    /// ε-greedy exploration mixed into model-guided selection (TVM uses
    /// 0.05; same default here).
    pub epsilon: f64,
    /// Model-V veto margin on the hinge score in [-1, 1]: candidates
    /// scoring below it are skipped. Positive values gate stricter than
    /// the raw sign — the P-front hugs the validity boundary, exactly
    /// where marginal false accepts concentrate (calibrated on conv4's
    /// hazard-corruption boundary, see EXPERIMENTS.md §V-margin).
    pub v_margin: f64,
    /// Minimum profiled records before the models are trusted.
    pub min_train: usize,
    /// Boost rounds for in-loop retraining (full Table 3 uses 300; the
    /// loop default trades a little accuracy for retrain latency).
    pub boost_rounds: usize,
    /// RNG seed; the per-tuner stream is `seed ^ salt`.
    pub seed: u64,
    /// Tier-0 prescreen over-selection factor (`--prescreen-factor`).
    /// `0` or `1` disables prescreening entirely — the selection path is
    /// structurally unchanged and cold traces stay byte-identical to the
    /// pre-multi-fidelity behaviour. At `k ≥ 2` the explorer over-selects
    /// a `k×` candidate pool, ranks it with the coarse analytic estimator
    /// ([`crate::vta::coarse`]), and spends full profiling only on the
    /// survivors.
    pub prescreen_factor: usize,
    /// Incremental per-round training (`--incremental`): instead of
    /// refitting each model from scratch every round, continue the
    /// previous round's ensemble and append a few trees
    /// (`boost_rounds / 10`, min 4). Off by default — continuation
    /// deliberately drops the per-round seed churn (`seed ^ round`), so
    /// traces differ from the cold paper behaviour.
    pub incremental: bool,
    /// With `incremental`, fully refit every `R` rounds
    /// (`--retrain-every R`) to bound drift from stale early trees.
    /// `0` never forces a refit.
    pub retrain_every: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            n_per_round: 10,
            alpha: 1.0,
            max_trials: 300,
            epsilon: 0.05,
            v_margin: DEFAULT_V_MARGIN,
            min_train: 20,
            boost_rounds: 120,
            seed: 0,
            prescreen_factor: 0,
            incremental: false,
            retrain_every: 0,
        }
    }
}

/// Default model-V veto margin (traces are byte-identical to the
/// pre-configurable behaviour at this value).
pub const DEFAULT_V_MARGIN: f64 = 0.25;

impl TunerConfig {
    /// Builder: set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder: set the total profiling budget.
    pub fn with_trials(mut self, trials: usize) -> Self {
        self.max_trials = trials;
        self
    }

    /// Candidates accumulated before the hidden-feature stage:
    /// `(α + 1) · N`.
    pub fn pool_size(&self) -> usize {
        ((self.alpha + 1.0) * self.n_per_round as f64).round() as usize
    }
}

/// Everything a tuner needs to profile configurations on the simulated
/// board: layer, search space, compiler, simulator.
pub struct TuningEnv {
    /// The convolution layer being tuned.
    pub layer: ConvLayer,
    /// Enumerable schedule search space for the layer.
    pub space: SearchSpace,
    /// Compiler lowering schedules for this target.
    pub compiler: Compiler,
    /// Cycle-accurate simulator standing in for the board.
    pub simulator: Simulator,
}

impl TuningEnv {
    /// Paper-space environment (pre-refactor behaviour).
    pub fn new(cfg: crate::vta::config::VtaConfig, layer: ConvLayer) -> Self {
        Self::with_space(cfg, layer, SpaceKind::Paper)
    }

    /// Environment over a chosen knob set (`--space paper|extended`).
    pub fn with_space(
        cfg: crate::vta::config::VtaConfig,
        layer: ConvLayer,
        kind: SpaceKind,
    ) -> Self {
        TuningEnv {
            layer,
            space: SearchSpace::with_kind(&layer, kind),
            compiler: Compiler::with_kind(cfg.clone(), kind),
            simulator: Simulator::new(cfg),
        }
    }

    /// Which knob set this environment searches.
    pub fn kind(&self) -> SpaceKind {
        self.space.kind()
    }

    /// The hardware target this environment profiles on (compiler and
    /// simulator always share one config).
    pub fn hw(&self) -> &crate::vta::config::VtaConfig {
        &self.compiler.cfg
    }

    /// "Run on hardware": compile, execute on the simulator, classify the
    /// outcome (paper §2 Profiling & Training).
    ///
    /// Uncached sequential path, kept for tests and one-off probes; the
    /// tuning loops route through [`Engine::profile_batch`], which
    /// produces identical records via the compile cache.
    pub fn profile(&self, space_index: usize) -> TrialRecord {
        let sched = self.space.schedule(space_index);
        let compiled = self.compiler.compile(&self.layer, &sched);
        let hidden = self.compiler.hidden_features(&compiled);
        let outcome = outcome_of(&self.simulator.check(&compiled.program));
        TrialRecord {
            space_index,
            schedule: sched,
            visible: self.space.visible(space_index),
            hidden,
            outcome,
            fidelity: Fidelity::Full,
        }
    }
}

/// Common tuner interface.
///
/// All tuners route profiling (and the ML²Tuner pool compilation)
/// through an [`Engine`]; traces are byte-identical for any worker
/// count, so `tune` defaults to a fresh all-cores engine.
pub trait Tuner {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Run the loop until the budget is spent; returns the trace.
    fn tune(&mut self, env: &TuningEnv) -> TuningTrace {
        self.tune_with(env, &Engine::default())
    }

    /// Run the loop with an explicit engine (worker pool + compile
    /// cache). Reusing one engine across runs shares its compile cache.
    fn tune_with(&mut self, env: &TuningEnv, engine: &Engine)
        -> TuningTrace;
}

/// Result summary used by examples and experiments.
#[derive(Clone, Debug)]
pub struct TuningOutcome {
    /// Full per-trial trace of the run.
    pub trace: TuningTrace,
    /// Best valid latency found, if any.
    pub best_cycles: Option<u64>,
    /// Fraction of profiled trials that were invalid.
    pub invalidity_ratio: f64,
}

impl TuningOutcome {
    /// Summarize a finished trace.
    pub fn from_trace(trace: TuningTrace) -> Self {
        let best_cycles = trace.best_cycles();
        let invalidity_ratio = trace.invalidity_ratio();
        TuningOutcome { trace, best_cycles, invalidity_ratio }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    #[test]
    fn pool_size_formula() {
        let c = TunerConfig::default();
        assert_eq!(c.pool_size(), 20); // (1+1)·10
        let c2 = TunerConfig { alpha: 0.5, n_per_round: 10, ..c };
        assert_eq!(c2.pool_size(), 15);
    }

    #[test]
    fn profile_classifies_outcomes() {
        let env = TuningEnv::new(
            VtaConfig::zcu102(),
            resnet18::layer("conv5").unwrap(),
        );
        // scan until we have seen at least one valid and one invalid
        let mut seen_valid = false;
        let mut seen_invalid = false;
        for i in 0..env.space.len() {
            match env.profile(i).outcome {
                Outcome::Valid { cycles } => {
                    assert!(cycles > 0);
                    seen_valid = true;
                }
                _ => seen_invalid = true,
            }
            if seen_valid && seen_invalid {
                break;
            }
        }
        assert!(seen_valid && seen_invalid);
    }
}
