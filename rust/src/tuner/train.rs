//! Unified training-set assembly — the one path every model trains
//! through.
//!
//! Historically each model grew its own row-assembly entry points — a
//! per-model method on the models plus one per data source on the
//! database — which multiplied whenever a new data source appeared. A
//! [`TrainSet`] replaces the zoo: callers append rows from any number of
//! databases — cold run records, warm-transferred records, meta-corpus
//! records — via the per-model `extend_*` views, and each model's single
//! `fit(&TrainSet, &FitOpts)` consumes the result. Warm-start, tiered
//! COARSE weighting, the TVM penalty labelling, and meta-adaptation are
//! compositions of extends + options, not separate methods.
//!
//! Row order is append order, and the builders walk records in database
//! order — so "warm rows first, then fresh" reproduces the exact row
//! layout (and therefore bit-identical boosters) of the pre-`TrainSet`
//! training paths.

use super::database::{Database, Fidelity, COARSE_LABEL_WEIGHT};
use crate::compiler::features;

/// Where a training row came from. Carried per row so fit options (and
/// diagnostics) can treat run-local measurements differently from
/// imported ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Measured by the current run.
    Cold,
    /// Imported from prior logs via [`super::database::TransferDb`]
    /// warm-start matching.
    Warm,
    /// Drawn from the offline meta-training corpus.
    Meta,
}

/// A model's assembled training set: feature rows, labels, per-row
/// weights, and per-row provenance.
#[derive(Clone, Debug, Default)]
pub struct TrainSet {
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    ws: Vec<f64>,
    prov: Vec<Provenance>,
    any_weighted: bool,
}

impl TrainSet {
    /// Empty set.
    pub fn new() -> Self {
        TrainSet::default()
    }

    /// Append one row. A weight of exactly 1.0 keeps the set on the
    /// unweighted training path (bit-identical to pre-weighting code);
    /// any other weight switches [`TrainSet::weights`] on for the whole
    /// set.
    pub fn push_row(
        &mut self,
        x: Vec<f64>,
        y: f64,
        w: f64,
        prov: Provenance,
    ) {
        if w != 1.0 {
            self.any_weighted = true;
        }
        self.xs.push(x);
        self.ys.push(y);
        self.ws.push(w);
        self.prov.push(prov);
    }

    /// Model-P view of `db`: full-fidelity *valid* records at weight 1.0
    /// (the paper trains P exclusively on valid configurations) plus
    /// coarse tier-0 estimates down-weighted to [`COARSE_LABEL_WEIGHT`]
    /// — they order the landscape but carry level error, so they steer
    /// without outvoting measured labels. Label: `log2(cycles)`.
    pub fn extend_p(&mut self, db: &Database, prov: Provenance) -> &mut Self {
        for r in &db.records {
            if let Some(y) = r.perf_label() {
                let w = match r.fidelity {
                    Fidelity::Full => 1.0,
                    Fidelity::Coarse => COARSE_LABEL_WEIGHT,
                };
                self.push_row(r.visible.clone(), y, w, prov);
            }
        }
        self
    }

    /// Model-V view of `db`: all *full-fidelity* records plus coarse
    /// *invalid* records, label = validity. A tier-0 "valid" is only a
    /// plausibility estimate and must not teach V the config actually
    /// runs; a tier-0 invalid comes from the static capacity check,
    /// which is a sound subset of runtime-invalid, so it is a real
    /// label.
    pub fn extend_v(&mut self, db: &Database, prov: Provenance) -> &mut Self {
        for r in &db.records {
            if r.fidelity == Fidelity::Full || !r.outcome.is_valid() {
                self.push_row(r.visible.clone(), r.valid_label(), 1.0,
                              prov);
            }
        }
        self
    }

    /// Model-A view of `db`: visible ⊕ hidden features of valid records.
    /// Records without hidden features (e.g. transferred from a space
    /// version whose hidden layout cannot be projected onto this one)
    /// are skipped — they still train P and V, which are visible-only.
    /// Coarse records never compile, so they carry no hidden features
    /// and the same skip keeps tier-0 estimates out of A.
    pub fn extend_a(&mut self, db: &Database, prov: Provenance) -> &mut Self {
        for r in &db.records {
            if r.hidden.is_empty() {
                continue;
            }
            if let Some(y) = r.perf_label() {
                self.push_row(
                    features::combined_features(&r.visible, &r.hidden),
                    y,
                    1.0,
                    prov,
                );
            }
        }
        self
    }

    /// TVM-approach view of `db`: all *full-fidelity* records; invalid
    /// ones get a penalty label (worst observed + 1, i.e. "slower than
    /// anything seen" — 30.0 when nothing valid was seen). The TVM
    /// baseline never prescreens, but a log replayed through this view
    /// could carry coarse records — they are estimates, not
    /// measurements, and are excluded.
    pub fn extend_p_penalty(
        &mut self,
        db: &Database,
        prov: Provenance,
    ) -> &mut Self {
        let worst = db
            .records
            .iter()
            .filter(|r| r.fidelity == Fidelity::Full)
            .filter_map(|r| r.perf_label())
            .fold(f64::NEG_INFINITY, f64::max);
        let penalty = if worst.is_finite() { worst + 1.0 } else { 30.0 };
        for r in &db.records {
            if r.fidelity != Fidelity::Full {
                continue;
            }
            self.push_row(
                r.visible.clone(),
                r.perf_label().unwrap_or(penalty),
                1.0,
                prov,
            );
        }
        self
    }

    /// Center the labels of the rows appended since index `from` around
    /// their mean. Meta training calls this once per ingested log: each
    /// log's `log2(cycles)` labels carry a layer- and hardware-specific
    /// level, and centering per log pools them into one corpus that
    /// teaches the *shape* of the performance landscape without the
    /// levels fighting each other (the run-time level comes back via
    /// `FitOpts::recalibrate`).
    pub fn center_from(&mut self, from: usize) -> &mut Self {
        let tail = &mut self.ys[from..];
        if tail.is_empty() {
            return self;
        }
        let mean = tail.iter().sum::<f64>() / tail.len() as f64;
        for y in tail {
            *y -= mean;
        }
        self
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Whether the set holds no rows.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Rows appended with the given provenance.
    pub fn n_from(&self, prov: Provenance) -> usize {
        self.prov.iter().filter(|&&p| p == prov).count()
    }

    /// Feature rows, append order.
    pub fn xs(&self) -> &[Vec<f64>] {
        &self.xs
    }

    /// Labels, append order.
    pub fn ys(&self) -> &[f64] {
        &self.ys
    }

    /// Per-row weights — `None` when every row is weight 1.0, so the
    /// unweighted boosting path (and its bit-exact traces) runs whenever
    /// no down-weighted row is present.
    pub fn weights(&self) -> Option<&[f64]> {
        if self.any_weighted {
            Some(&self.ws)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::{Schedule, SpaceKind};
    use crate::tuner::database::{Outcome, TrialRecord};

    fn rec(i: usize, outcome: Outcome) -> TrialRecord {
        let schedule = Schedule { tile_h: i + 1, tile_w: 2, tile_oc: 16,
                                  tile_ic: 16, n_vthreads: 1,
                                  ..Default::default() };
        TrialRecord {
            space_index: i,
            schedule,
            visible: SpaceKind::Paper.visible_features(&schedule),
            hidden: vec![1.0, 2.0, 3.0],
            outcome,
            fidelity: Fidelity::Full,
        }
    }

    fn coarse_rec(i: usize, outcome: Outcome) -> TrialRecord {
        TrialRecord { hidden: vec![], fidelity: Fidelity::Coarse,
                      ..rec(i, outcome) }
    }

    #[test]
    fn per_model_views() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(rec(1, Outcome::Crash));
        db.push(rec(2, Outcome::Valid { cycles: 2048 }));
        db.push(rec(3, Outcome::WrongOutput));
        let mut p = TrainSet::new();
        p.extend_p(&db, Provenance::Cold);
        assert_eq!(p.len(), 2);
        assert_eq!(p.ys(), &[10.0, 11.0]); // log2
        assert!(p.weights().is_none(), "no coarse row -> unweighted");
        let mut v = TrainSet::new();
        v.extend_v(&db, Provenance::Cold);
        assert_eq!(v.len(), 4);
        assert_eq!(v.ys(), &[1.0, 0.0, 1.0, 0.0]);
        let mut a = TrainSet::new();
        a.extend_a(&db, Provenance::Cold);
        assert_eq!(a.xs()[0].len(),
                   rec(0, Outcome::Crash).visible.len() + 3);
        let mut pen = TrainSet::new();
        pen.extend_p_penalty(&db, Provenance::Cold);
        assert_eq!(pen.len(), 4);
        assert_eq!(pen.ys()[1], 12.0); // worst (11) + 1
    }

    #[test]
    fn views_respect_fidelity_tiers() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(rec(1, Outcome::Crash));
        db.push(coarse_rec(2, Outcome::Valid { cycles: 2048 }));
        db.push(coarse_rec(3, Outcome::Crash));
        // P: both valids, the coarse one down-weighted
        let mut p = TrainSet::new();
        p.extend_p(&db, Provenance::Cold);
        assert_eq!(p.len(), 2);
        assert_eq!(p.ys(), &[10.0, 11.0]);
        assert_eq!(p.weights(), Some(&[1.0, COARSE_LABEL_WEIGHT][..]));
        // V: full records + coarse invalid; coarse "valid" is only a
        // plausibility estimate and is excluded
        let mut v = TrainSet::new();
        v.extend_v(&db, Provenance::Cold);
        assert_eq!(v.len(), 3);
        assert_eq!(v.ys(), &[1.0, 0.0, 0.0]);
        // A: coarse records carry no hidden features and are skipped
        let mut a = TrainSet::new();
        a.extend_a(&db, Provenance::Cold);
        assert_eq!(a.len(), 1);
        // TVM penalty view: full records only
        let mut pen = TrainSet::new();
        pen.extend_p_penalty(&db, Provenance::Cold);
        assert_eq!(pen.len(), 2);
    }

    #[test]
    fn weights_stay_none_without_downweighted_rows() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(rec(1, Outcome::Valid { cycles: 2048 }));
        let mut warm = TrainSet::new();
        warm.extend_p(&db, Provenance::Warm);
        warm.extend_p(&db, Provenance::Cold);
        assert_eq!(warm.len(), 4);
        assert!(warm.weights().is_none());
        assert_eq!(warm.n_from(Provenance::Warm), 2);
        // one coarse row anywhere flips the whole set to weighted
        let mut tiered = Database::new("conv1");
        tiered.push(rec(0, Outcome::Valid { cycles: 1024 }));
        tiered.push(coarse_rec(1, Outcome::Valid { cycles: 2048 }));
        let mut mixed = TrainSet::new();
        mixed.extend_p(&db, Provenance::Warm);
        mixed.extend_p(&tiered, Provenance::Cold);
        assert_eq!(mixed.weights(),
                   Some(&[1.0, 1.0, 1.0, COARSE_LABEL_WEIGHT][..]));
    }

    #[test]
    fn center_from_touches_only_the_tail() {
        let mut set = TrainSet::new();
        set.push_row(vec![0.0], 10.0, 1.0, Provenance::Meta);
        let start = set.len();
        set.push_row(vec![1.0], 4.0, 1.0, Provenance::Meta);
        set.push_row(vec![2.0], 8.0, 1.0, Provenance::Meta);
        set.center_from(start);
        assert_eq!(set.ys(), &[10.0, -2.0, 2.0]);
        // empty tail is a no-op
        let n = set.len();
        set.center_from(n);
        assert_eq!(set.ys(), &[10.0, -2.0, 2.0]);
    }

    #[test]
    fn penalty_defaults_when_nothing_valid() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Crash));
        db.push(rec(1, Outcome::WrongOutput));
        let mut pen = TrainSet::new();
        pen.extend_p_penalty(&db, Provenance::Cold);
        assert_eq!(pen.ys(), &[30.0, 30.0]);
    }
}
