//! Corpus-trained meta cost models (`train-meta` / `--meta`).
//!
//! Every tuning run persists its log; over time a `--db` directory
//! accumulates a *corpus* — many layers, many targets, both knob spaces.
//! `train-meta` ingests the whole corpus ([`TransferDb::load_dir`]) and
//! fits **base** P/V/A ensembles offline, serialized as one versioned
//! JSON artifact per space kind (`meta_paper.json` /
//! `meta_extended.json`). A later `tune --meta <dir>` loads the artifact
//! for its space and hands the ensembles to the selection loop as
//! continuation bases: the run is model-guided from round 1 (no
//! `min_train` random warmup), and each round *adapts* the base with a
//! few appended trees instead of training from scratch.
//!
//! What pools and what does not:
//!
//! * **P and A** pool across layers and targets. Their labels are
//!   `log2(cycles)`, whose *level* is layer- and hardware-specific but
//!   whose *shape* (which schedules beat which) is what transfers — so
//!   each log's labels are centered around the log's own mean before
//!   pooling ([`super::train::TrainSet::center_from`]), and the run-time
//!   level comes back through the mean-residual recalibration in
//!   [`super::models::FitOpts::recalibrate`].
//! * **V does not pool across capacities.** Validity is a hard function
//!   of buffer geometry: a "valid" minted on a bigger-buffered target is
//!   a *wrong* label for a smaller one. V ensembles are therefore
//!   bucketed per capacity signature
//!   ([`crate::vta::targets::TargetMeta::capacity_key`]) and served only
//!   on an exact match — a run on unseen hardware simply gets no meta V
//!   (pre-registry logs without a target stamp land in a `"default"`
//!   bucket that likewise only serves unstamped runs, never a known
//!   target).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::database::{Database, TransferDb};
use super::models::{FitOpts, ModelA, ModelP, ModelV};
use super::train::{Provenance, TrainSet};
use crate::compiler::features;
use crate::compiler::schedule::SpaceKind;
use crate::gbdt::Booster;
use crate::util::json::Json;
use crate::vta::config::VtaConfig;
use crate::vta::targets::TargetMeta;

/// Artifact format version; bumped on any incompatible layout change
/// (load rejects unknown versions instead of guessing).
pub const META_FORMAT_VERSION: i64 = 1;

/// Default boost rounds for offline corpus training — the paper's full
/// Table 3 budget; offline, so retrain latency is not a concern.
pub const META_BOOST_ROUNDS: usize = 300;

/// Fixed corpus-training seed: the same corpus always yields the same
/// artifact, byte for byte.
pub const META_SEED: u64 = 0x4d45_5441; // "META"

/// Capacity bucket for corpus logs written before target stamping. Runs
/// on a *known* target never read it — see the module docs.
pub const UNSTAMPED_KEY: &str = "default";

/// The meta-trained ensembles for one space kind.
#[derive(Clone, Debug)]
pub struct MetaArtifact {
    /// Knob space the corpus logs (and hence the feature layouts) use.
    pub space: SpaceKind,
    /// Source logs ingested.
    pub sources: usize,
    /// Total records across those logs.
    pub records: usize,
    /// Base performance ensemble (visible features, per-log centered
    /// labels); `None` when the corpus held < 2 perf-labelled rows.
    pub p: Option<Booster>,
    /// Base hidden-feature ensemble (visible ⊕ hidden, per-log centered
    /// labels); `None` when too few rows carried hidden features of the
    /// current layout.
    pub a: Option<Booster>,
    /// Base validity ensembles, bucketed per capacity signature.
    pub v: BTreeMap<String, Booster>,
}

/// A log's A-rows are ingestible only when their hidden vectors match
/// the current compiler's layout for the log's space kind — a stale
/// layout would train A on misaligned columns.
fn a_layout_ok(db: &Database) -> bool {
    let want = features::hidden_len(db.kind);
    db.records
        .iter()
        .all(|r| r.hidden.is_empty() || r.hidden.len() == want)
}

impl MetaArtifact {
    /// Fit the ensembles for `kind` over the corpus logs of that kind.
    pub fn build(
        kind: SpaceKind,
        dbs: &[&Database],
        rounds: usize,
    ) -> MetaArtifact {
        let mut pset = TrainSet::new();
        let mut aset = TrainSet::new();
        let mut vsets: BTreeMap<String, TrainSet> = BTreeMap::new();
        let mut records = 0;
        for db in dbs {
            records += db.len();
            let start = pset.len();
            pset.extend_p(db, Provenance::Meta).center_from(start);
            if a_layout_ok(db) {
                let start = aset.len();
                aset.extend_a(db, Provenance::Meta).center_from(start);
            }
            let key = db
                .target
                .as_ref()
                .map_or_else(|| UNSTAMPED_KEY.to_string(),
                             TargetMeta::capacity_key);
            vsets
                .entry(key)
                .or_default()
                .extend_v(db, Provenance::Meta);
        }
        let opts = FitOpts::new(rounds, META_SEED);
        MetaArtifact {
            space: kind,
            sources: dbs.len(),
            records,
            p: ModelP::fit(&pset, &opts).map(|m| m.booster),
            a: ModelA::fit(&aset, &opts).map(|m| m.booster),
            v: vsets
                .into_iter()
                .filter_map(|(k, set)| {
                    ModelV::fit(&set, &opts).map(|m| (k, m.booster))
                })
                .collect(),
        }
    }

    /// The V ensemble for `hw`'s capacity class — exact match only (see
    /// the module docs for why there is deliberately no fallback).
    pub fn v_for(&self, hw: &VtaConfig) -> Option<&Booster> {
        self.v.get(&TargetMeta::of(hw).capacity_key())
    }

    /// Serialize (versioned; see [`META_FORMAT_VERSION`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", META_FORMAT_VERSION)
            .set("space", self.space.name())
            .set("sources", self.sources)
            .set("records", self.records);
        if let Some(p) = &self.p {
            o.set("p", p.to_json());
        }
        if let Some(a) = &self.a {
            o.set("a", a.to_json());
        }
        let mut v = Json::obj();
        for (key, b) in &self.v {
            v.set(key.as_str(), b.to_json());
        }
        o.set("v", v);
        o
    }

    /// Strict parse of [`MetaArtifact::to_json`] output.
    pub fn from_json(j: &Json) -> Result<MetaArtifact> {
        let version = j
            .get("version")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("meta artifact missing version"))?;
        if version != META_FORMAT_VERSION {
            return Err(anyhow!(
                "unsupported meta artifact version {version} \
                 (this build reads {META_FORMAT_VERSION})"
            ));
        }
        let space = j
            .get("space")
            .and_then(Json::as_str)
            .and_then(SpaceKind::parse)
            .ok_or_else(|| anyhow!("meta artifact missing space"))?;
        let booster_at = |key: &str| -> Result<Option<Booster>> {
            j.get(key).map(Booster::from_json).transpose()
        };
        let mut v = BTreeMap::new();
        if let Some(obj) = j.get("v").and_then(Json::as_obj) {
            for (key, b) in obj {
                v.insert(key.clone(), Booster::from_json(b)?);
            }
        }
        Ok(MetaArtifact {
            space,
            sources: j
                .get("sources")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            records: j
                .get("records")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            p: booster_at("p")?,
            a: booster_at("a")?,
            v,
        })
    }
}

/// All meta artifacts of one training run / one `--meta` directory,
/// keyed on space kind.
#[derive(Clone, Debug, Default)]
pub struct MetaStore {
    artifacts: BTreeMap<&'static str, MetaArtifact>,
}

impl MetaStore {
    /// Fit artifacts over a loaded corpus, one per space kind that has
    /// at least one source log, at the default offline budget.
    pub fn build(corpus: &TransferDb) -> MetaStore {
        Self::build_with(corpus, META_BOOST_ROUNDS)
    }

    /// [`MetaStore::build`] with an explicit boost-round budget
    /// (`train-meta --rounds`).
    pub fn build_with(corpus: &TransferDb, rounds: usize) -> MetaStore {
        let mut store = MetaStore::default();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let dbs: Vec<&Database> = corpus
                .sources
                .iter()
                .filter(|d| d.kind == kind)
                .map(|d| d.as_ref())
                .collect();
            if dbs.is_empty() {
                continue;
            }
            store.artifacts.insert(
                kind.name(),
                MetaArtifact::build(kind, &dbs, rounds),
            );
        }
        store
    }

    /// The artifact for a space kind, if the corpus covered it.
    pub fn for_kind(&self, kind: SpaceKind) -> Option<&MetaArtifact> {
        self.artifacts.get(kind.name())
    }

    /// Take ownership of the artifact for a space kind.
    pub fn take_kind(&mut self, kind: SpaceKind) -> Option<MetaArtifact> {
        self.artifacts.remove(kind.name())
    }

    /// Number of artifacts (space kinds covered).
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// Whether no space kind is covered.
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Iterate artifacts, space-name order.
    pub fn iter(
        &self,
    ) -> impl Iterator<Item = (&'static str, &MetaArtifact)> {
        self.artifacts.iter().map(|(k, v)| (*k, v))
    }

    /// Write one `meta_<space>.json` per artifact into `dir` (created if
    /// missing); returns the written paths.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {dir:?}"))?;
        let mut paths = Vec::new();
        for (name, art) in &self.artifacts {
            let path = dir.join(format!("meta_{name}.json"));
            std::fs::write(&path, art.to_json().to_string_pretty())
                .with_context(|| format!("writing {path:?}"))?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// Load every `meta_<space>.json` from `dir`. Unlike corpus loading,
    /// a malformed artifact is a hard error — a `--meta` directory is a
    /// deliberate input, and silently tuning without the requested base
    /// models would be worse than failing.
    pub fn load(dir: impl AsRef<Path>) -> Result<MetaStore> {
        let dir = dir.as_ref();
        let mut store = MetaStore::default();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let path = dir.join(format!("meta_{}.json", kind.name()));
            if !path.exists() {
                continue;
            }
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path:?}"))?;
            let j = Json::parse(&text)
                .map_err(|e| anyhow!("{path:?}: {e}"))?;
            let art = MetaArtifact::from_json(&j)
                .with_context(|| format!("parsing {path:?}"))?;
            if art.space != kind {
                return Err(anyhow!(
                    "{path:?} declares space '{}' but is named for \
                     '{}'",
                    art.space.name(),
                    kind.name()
                ));
            }
            store.artifacts.insert(kind.name(), art);
        }
        if store.is_empty() {
            return Err(anyhow!(
                "no meta_<space>.json artifacts in {dir:?} \
                 (run `train-meta` first)"
            ));
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::compiler::schedule::Schedule;
    use crate::tuner::database::{Fidelity, Outcome, TrialRecord};

    fn vis(kind: SpaceKind, s: &Schedule) -> Vec<f64> {
        kind.visible_features(s)
    }

    fn synth_log(
        layer: &crate::workloads::ConvLayer,
        kind: SpaceKind,
        hw: &VtaConfig,
        n: usize,
        level: f64,
    ) -> Database {
        let mut db = Database::for_layer_on(layer, kind, hw);
        for i in 0..n {
            let th = 1 + (i % 16);
            let vt = 1 + (i % 4);
            let s = Schedule { tile_h: th, tile_w: 4, tile_oc: 32,
                               tile_ic: 32, n_vthreads: vt,
                               ..Default::default() };
            let valid = th * vt <= 24;
            let cycles =
                (level * (200_000.0 / th as f64 + 10_000.0 * vt as f64))
                    as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: vis(kind, &s),
                hidden: vec![1.0; features::hidden_len(kind)],
                outcome: if valid {
                    Outcome::Valid { cycles }
                } else {
                    Outcome::Crash
                },
                fidelity: Fidelity::Full,
            });
        }
        db
    }

    fn corpus() -> TransferDb {
        let conv5 = crate::workloads::resnet18::layer("conv5").unwrap();
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        let mut c = TransferDb::new();
        // two targets, two layers, wildly different levels: pooling
        // must survive via per-log centering
        c.add(synth_log(&conv5, SpaceKind::Paper,
                        &VtaConfig::zcu102(), 96, 1.0));
        c.add(synth_log(&pw4, SpaceKind::Paper,
                        &VtaConfig::edge_small(), 96, 40.0));
        c
    }

    #[test]
    fn build_pools_p_and_buckets_v_per_capacity() {
        let store = MetaStore::build_with(&corpus(), 60);
        let art = store.for_kind(SpaceKind::Paper).unwrap();
        assert_eq!(art.sources, 2);
        assert_eq!(art.records, 192);
        assert!(store.for_kind(SpaceKind::Extended).is_none());
        let p = art.p.as_ref().expect("corpus trains P");
        // centered pooling preserves the landscape's shape
        let f = |th: usize| {
            let s = Schedule { tile_h: th, tile_w: 4, tile_oc: 32,
                               tile_ic: 32, n_vthreads: 1,
                               ..Default::default() };
            p.predict_row(&vis(SpaceKind::Paper, &s))
        };
        assert!(f(2) > f(12), "meta P must order the landscape");
        // V: one bucket per capacity signature, exact-match serving
        assert_eq!(art.v.len(), 2);
        assert!(art.v_for(&VtaConfig::zcu102()).is_some());
        assert!(art.v_for(&VtaConfig::edge_small()).is_some());
        assert!(art.v_for(&VtaConfig::hiband()).is_none(),
                "unseen capacity class gets no meta V");
    }

    #[test]
    fn bigger_target_validity_never_enters_a_smaller_bucket() {
        // conv1 th=28·tw=28·tic=64 fits the zcu102 but not edge-small;
        // a corpus holding both targets' logs must keep the zcu102's
        // "valid" out of edge-small's V bucket
        let conv1 = crate::workloads::resnet18::layer("conv1").unwrap();
        let big_tile = Schedule { tile_h: 28, tile_w: 28, tile_oc: 16,
                                  tile_ic: 64, n_vthreads: 1,
                                  ..Default::default() };
        let mk = |hw: &VtaConfig, valid: bool| {
            let mut db =
                Database::for_layer_on(&conv1, SpaceKind::Paper, hw);
            for i in 0..8usize {
                // pad with small-tile valids so V has both classes
                let s = Schedule { tile_h: 1 + i % 4, tile_w: 4,
                                   tile_oc: 16, tile_ic: 64,
                                   n_vthreads: 1, ..Default::default() };
                db.push(TrialRecord {
                    space_index: i,
                    schedule: s,
                    visible: vis(SpaceKind::Paper, &s),
                    hidden: vec![],
                    outcome: Outcome::Valid { cycles: 1000 },
                    fidelity: Fidelity::Full,
                });
            }
            db.push(TrialRecord {
                space_index: 99,
                schedule: big_tile,
                visible: vis(SpaceKind::Paper, &big_tile),
                hidden: vec![],
                outcome: if valid {
                    Outcome::Valid { cycles: 500 }
                } else {
                    Outcome::Crash
                },
                fidelity: Fidelity::Full,
            });
            db
        };
        let mut c = TransferDb::new();
        c.add(mk(&VtaConfig::zcu102(), true));
        c.add(mk(&VtaConfig::edge_small(), false));
        let store = MetaStore::build_with(&c, 60);
        let art = store.for_kind(SpaceKind::Paper).unwrap();
        let feats = vis(SpaceKind::Paper, &big_tile);
        let edge_v = art.v_for(&VtaConfig::edge_small()).unwrap();
        let big_v = art.v_for(&VtaConfig::zcu102()).unwrap();
        assert!(edge_v.predict_row(&feats) < 0.0,
                "edge bucket learned its own Crash label");
        assert!(big_v.predict_row(&feats) > 0.0,
                "zcu102 bucket keeps its own valid label");
    }

    #[test]
    fn artifact_round_trips_through_store_save_load() {
        let store = MetaStore::build_with(&corpus(), 40);
        let dir = std::env::temp_dir().join("ml2_meta_rt_test");
        std::fs::remove_dir_all(&dir).ok();
        let paths = store.save(&dir).unwrap();
        assert_eq!(paths.len(), 1);
        assert!(paths[0].ends_with("meta_paper.json"));
        let back = MetaStore::load(&dir).unwrap();
        let (a, b) = (
            store.for_kind(SpaceKind::Paper).unwrap(),
            back.for_kind(SpaceKind::Paper).unwrap(),
        );
        assert_eq!(a.sources, b.sources);
        assert_eq!(a.records, b.records);
        let s = Schedule { tile_h: 5, tile_w: 4, tile_oc: 32,
                           tile_ic: 32, n_vthreads: 2,
                           ..Default::default() };
        let feats = vis(SpaceKind::Paper, &s);
        assert_eq!(
            a.p.as_ref().unwrap().predict_row(&feats).to_bits(),
            b.p.as_ref().unwrap().predict_row(&feats).to_bits(),
            "serialized meta P must predict bit-identically"
        );
        for (key, vb) in &a.v {
            assert_eq!(vb.predict_row(&feats).to_bits(),
                       b.v[key].predict_row(&feats).to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_bad_version_and_empty_dir() {
        let dir = std::env::temp_dir().join("ml2_meta_bad_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        assert!(MetaStore::load(&dir).is_err(), "empty dir is an error");
        let store = MetaStore::build_with(&corpus(), 20);
        store.save(&dir).unwrap();
        let path = dir.join("meta_paper.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path,
                       text.replace("\"version\": 1", "\"version\": 99"))
            .unwrap();
        assert!(MetaStore::load(&dir).is_err(),
                "unknown version must be rejected, not guessed at");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_space_corpus_yields_one_artifact_per_kind() {
        let conv5 = crate::workloads::resnet18::layer("conv5").unwrap();
        let mut c = corpus();
        c.add(synth_log(&conv5, SpaceKind::Extended,
                        &VtaConfig::zcu102(), 64, 1.0));
        let store = MetaStore::build_with(&c, 40);
        assert_eq!(store.len(), 2);
        let ext = store.for_kind(SpaceKind::Extended).unwrap();
        assert_eq!(ext.sources, 1);
        assert_eq!(
            ext.p.as_ref().unwrap().n_features,
            SpaceKind::Extended.n_visible(),
            "per-kind artifacts keep their own feature widths"
        );
    }

    #[test]
    fn legacy_unstamped_logs_train_but_serve_no_known_target() {
        // a pre-registry log (no target stamp): P still pools, V lands
        // in the "default" bucket that no registered target reads
        let conv5 = crate::workloads::resnet18::layer("conv5").unwrap();
        let mut log = synth_log(&conv5, SpaceKind::Paper,
                                &VtaConfig::zcu102(), 64, 1.0);
        log.target = None;
        let mut c = TransferDb::new();
        c.add(log);
        let store = MetaStore::build_with(&c, 40);
        let art = store.for_kind(SpaceKind::Paper).unwrap();
        assert!(art.p.is_some());
        assert!(art.v.contains_key(UNSTAMPED_KEY));
        for name in crate::vta::targets::TARGET_NAMES {
            let hw = crate::vta::targets::target(name).unwrap();
            assert!(art.v_for(&hw).is_none(),
                    "unstamped V must not serve target '{name}'");
        }
    }

    #[test]
    fn stale_hidden_layouts_are_kept_out_of_meta_a() {
        let conv5 = crate::workloads::resnet18::layer("conv5").unwrap();
        let mut log = synth_log(&conv5, SpaceKind::Paper,
                                &VtaConfig::zcu102(), 64, 1.0);
        // truncate every hidden vector: a stale layout
        for r in &mut log.records {
            Arc::make_mut(r).hidden.truncate(1);
        }
        let mut c = TransferDb::new();
        c.add(log);
        let store = MetaStore::build_with(&c, 40);
        let art = store.for_kind(SpaceKind::Paper).unwrap();
        assert!(art.p.is_some(), "P is layout-independent");
        assert!(art.a.is_none(),
                "stale hidden layout must not train meta A");
    }
}
