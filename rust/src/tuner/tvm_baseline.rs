//! The "TVM approach" baseline the paper compares against: a single cost
//! model P trained on *all* profiled configurations (invalid ones get a
//! penalty label, the standard AutoTVM treatment of failed measurements),
//! ε-greedy top-N selection, no validity model, no hidden features.

use super::database::Database;
use super::explorer::Explorer;
use super::models::{FitOpts, ModelP};
use super::report::TuningTrace;
use super::space::SearchSpace;
use super::train::{Provenance, TrainSet};
use super::{salt, Tuner, TunerConfig, TuningEnv};
use crate::engine::Engine;
use crate::obs::Stage;
use crate::util::rng::Rng;

/// Single-model AutoTVM-style baseline (see module docs).
pub struct TvmTuner {
    /// Tuning-loop knobs.
    pub cfg: TunerConfig,
}

impl TvmTuner {
    /// Baseline over the given knobs.
    pub fn new(cfg: TunerConfig) -> Self {
        TvmTuner { cfg }
    }
}

impl Tuner for TvmTuner {
    fn name(&self) -> &'static str {
        "tvm"
    }

    fn tune_with(
        &mut self,
        env: &TuningEnv,
        engine: &Engine,
    ) -> TuningTrace {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed ^ salt::TVM);
        let mut space = env.space.clone();
        let mut db = Database::for_layer_in(&env.layer, env.kind());
        let mut trace = TuningTrace::new(env.layer.name, self.name());
        let mut round = 0u64;
        while trace.len() < cfg.max_trials && space.n_unmeasured() > 0 {
            round += 1;
            let scope = engine.recorder().begin_round();
            let before = trace.len();
            let n = cfg.n_per_round.min(cfg.max_trials - trace.len());
            let batch =
                select_batch(cfg, &space, &db, &mut rng, round, n, engine);
            if batch.is_empty() {
                break;
            }
            engine.profile_into(env, &batch, &mut space, Some(&mut db),
                                &mut trace);
            engine.recorder().end_round(scope, || {
                super::round_event(env, &trace, before, round,
                                   cfg.v_margin, None)
            });
        }
        trace
    }
}

/// One round of TVM-approach candidate selection: penalty-P top-N with
/// ε-greedy exploration, no validity model, no hidden features. Shared
/// by [`TvmTuner`] and the network scheduler's incremental sessions.
/// The engine contributes its `jobs` count (sharding the scoring sweep,
/// trace-invariant — see [`crate::tuner::explorer::score_candidates`])
/// and its telemetry recorder.
pub(crate) fn select_batch(
    cfg: &TunerConfig,
    space: &SearchSpace,
    db: &Database,
    rng: &mut Rng,
    round: u64,
    n: usize,
    engine: &Engine,
) -> Vec<usize> {
    let rec = engine.recorder();
    let _select = rec.span(Stage::Select);
    if db.len() < cfg.min_train {
        return space.sample_unmeasured(rng, n);
    }
    let p = {
        let _train = rec.span(Stage::Train);
        let mut set = TrainSet::new();
        set.extend_p_penalty(db, Provenance::Cold);
        ModelP::fit(&set,
                    &FitOpts::new(cfg.boost_rounds, cfg.seed ^ round))
    };
    match p {
        None => space.sample_unmeasured(rng, n),
        Some(p) => Explorer::new(cfg.epsilon)
            .with_jobs(engine.jobs())
            .with_recorder(rec)
            .select(space, &p, None, n, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    #[test]
    fn runs_and_respects_budget() {
        let env = TuningEnv::new(VtaConfig::zcu102(),
                                 resnet18::layer("conv5").unwrap());
        let cfg = TunerConfig { max_trials: 50, ..Default::default() };
        let trace = TvmTuner::new(cfg).tune(&env);
        assert_eq!(trace.len(), 50);
        assert_eq!(trace.tuner, "tvm");
        assert!(trace.best_cycles().is_some());
    }
}
