//! Profiling database — the "Database" box of paper Fig. 1.
//!
//! Stores every profiling attempt with its features and outcome, feeds the
//! three models' training sets, and persists as a JSON tuning log
//! (TVM-style) so runs can be resumed or analyzed offline.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::compiler::schedule::Schedule;
use crate::util::json::Json;

/// Profiling outcome classes (paper §A.2: register-error crash vs
/// wrong-result; both are invalid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Valid { cycles: u64 },
    /// Register error — on the real board this needs a manual reboot.
    Crash,
    /// Runs to completion but the output differs from the golden model.
    WrongOutput,
}

impl Outcome {
    pub fn is_valid(&self) -> bool {
        matches!(self, Outcome::Valid { .. })
    }

    pub fn cycles(&self) -> Option<u64> {
        match self {
            Outcome::Valid { cycles } => Some(*cycles),
            _ => None,
        }
    }
}

/// One profiling attempt.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    pub space_index: usize,
    pub schedule: Schedule,
    pub visible: Vec<f64>,
    pub hidden: Vec<f64>,
    pub outcome: Outcome,
}

impl TrialRecord {
    /// Training label for the performance models: `log2(cycles)`
    /// (scale-free; RMSE ratios in Fig. 3/4 are computed on this).
    pub fn perf_label(&self) -> Option<f64> {
        self.outcome.cycles().map(|c| (c.max(1) as f64).log2())
    }

    /// Training label for model V: 1.0 valid, 0.0 invalid.
    pub fn valid_label(&self) -> f64 {
        self.outcome.is_valid() as u8 as f64
    }
}

/// The profiling database.
#[derive(Clone, Debug, Default)]
pub struct Database {
    pub layer: String,
    pub records: Vec<TrialRecord>,
}

impl Database {
    pub fn new(layer: &str) -> Self {
        Database { layer: layer.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, rec: TrialRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn n_valid(&self) -> usize {
        self.records.iter().filter(|r| r.outcome.is_valid()).count()
    }

    /// Training set for P: visible features of *valid* records only
    /// (the paper trains P exclusively on valid configurations).
    pub fn train_p(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for r in &self.records {
            if let Some(y) = r.perf_label() {
                xs.push(r.visible.clone());
                ys.push(y);
            }
        }
        (xs, ys)
    }

    /// Training set for V: visible features of *all* records,
    /// label = validity.
    pub fn train_v(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let xs = self.records.iter().map(|r| r.visible.clone()).collect();
        let ys = self.records.iter().map(|r| r.valid_label()).collect();
        (xs, ys)
    }

    /// Training set for A: visible ⊕ hidden features of valid records.
    pub fn train_a(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for r in &self.records {
            if let Some(y) = r.perf_label() {
                xs.push(crate::compiler::features::combined_features(
                    &r.visible, &r.hidden,
                ));
                ys.push(y);
            }
        }
        (xs, ys)
    }

    /// TVM-approach training set: ALL records; invalid ones get a penalty
    /// label (worst observed + 1, i.e. "slower than anything seen").
    pub fn train_p_with_penalty(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let worst = self
            .records
            .iter()
            .filter_map(|r| r.perf_label())
            .fold(f64::NEG_INFINITY, f64::max);
        let penalty = if worst.is_finite() { worst + 1.0 } else { 30.0 };
        let xs = self.records.iter().map(|r| r.visible.clone()).collect();
        let ys = self
            .records
            .iter()
            .map(|r| r.perf_label().unwrap_or(penalty))
            .collect();
        (xs, ys)
    }

    /// Best valid cycles so far.
    pub fn best_cycles(&self) -> Option<u64> {
        self.records.iter().filter_map(|r| r.outcome.cycles()).min()
    }

    // ------------------------------------------------------------- JSON --

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("layer", self.layer.as_str());
        let recs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("i", r.space_index)
                    .set("th", r.schedule.tile_h)
                    .set("tw", r.schedule.tile_w)
                    .set("oc", r.schedule.tile_oc)
                    .set("ic", r.schedule.tile_ic)
                    .set("vt", r.schedule.n_vthreads)
                    .set("hidden", r.hidden.clone());
                match r.outcome {
                    Outcome::Valid { cycles } => {
                        o.set("outcome", "valid").set("cycles", cycles);
                    }
                    Outcome::Crash => {
                        o.set("outcome", "crash");
                    }
                    Outcome::WrongOutput => {
                        o.set("outcome", "wrong");
                    }
                }
                o
            })
            .collect();
        root.set("records", recs);
        root
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let layer = j
            .get("layer")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing layer"))?
            .to_string();
        let mut db = Database::new(&layer);
        for r in j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing records"))?
        {
            let geti = |k: &str| {
                r.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("missing {k}"))
            };
            let schedule = Schedule {
                tile_h: geti("th")?,
                tile_w: geti("tw")?,
                tile_oc: geti("oc")?,
                tile_ic: geti("ic")?,
                n_vthreads: geti("vt")?,
            };
            let hidden: Vec<f64> = r
                .get("hidden")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let outcome = match r.get("outcome").and_then(Json::as_str) {
                Some("valid") => Outcome::Valid {
                    cycles: r
                        .get("cycles")
                        .and_then(Json::as_i64)
                        .ok_or_else(|| anyhow!("missing cycles"))?
                        as u64,
                },
                Some("crash") => Outcome::Crash,
                Some("wrong") => Outcome::WrongOutput,
                other => return Err(anyhow!("bad outcome {other:?}")),
            };
            db.push(TrialRecord {
                space_index: geti("i")?,
                schedule,
                visible: schedule.visible_features(),
                hidden,
                outcome,
            });
        }
        Ok(db)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string_pretty())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, outcome: Outcome) -> TrialRecord {
        let schedule = Schedule { tile_h: i + 1, tile_w: 2, tile_oc: 16,
                                  tile_ic: 16, n_vthreads: 1 };
        TrialRecord {
            space_index: i,
            schedule,
            visible: schedule.visible_features(),
            hidden: vec![1.0, 2.0, 3.0],
            outcome,
        }
    }

    #[test]
    fn training_set_views() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(rec(1, Outcome::Crash));
        db.push(rec(2, Outcome::Valid { cycles: 2048 }));
        db.push(rec(3, Outcome::WrongOutput));
        assert_eq!(db.n_valid(), 2);
        let (xs, ys) = db.train_p();
        assert_eq!(xs.len(), 2);
        assert_eq!(ys, vec![10.0, 11.0]); // log2
        let (xv, yv) = db.train_v();
        assert_eq!(xv.len(), 4);
        assert_eq!(yv, vec![1.0, 0.0, 1.0, 0.0]);
        let (xa, _) = db.train_a();
        assert_eq!(xa[0].len(), rec(0, Outcome::Crash).visible.len() + 3);
        let (_, yp) = db.train_p_with_penalty();
        assert_eq!(yp.len(), 4);
        assert_eq!(yp[1], 12.0); // worst (11) + 1
        assert_eq!(db.best_cycles(), Some(1024));
    }

    #[test]
    fn json_round_trip() {
        let mut db = Database::new("conv3");
        db.push(rec(0, Outcome::Valid { cycles: 5000 }));
        db.push(rec(7, Outcome::Crash));
        db.push(rec(9, Outcome::WrongOutput));
        let j = db.to_json();
        let back = Database::from_json(&j).unwrap();
        assert_eq!(back.layer, "conv3");
        assert_eq!(back.len(), 3);
        assert_eq!(back.records[0].outcome,
                   Outcome::Valid { cycles: 5000 });
        assert_eq!(back.records[1].schedule.tile_h, 8);
        assert_eq!(back.records[2].outcome, Outcome::WrongOutput);
        assert_eq!(back.records[0].hidden, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn file_round_trip() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 123 }));
        let path = std::env::temp_dir().join("ml2tuner_db_test.json");
        db.save(&path).unwrap();
        let back = Database::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        std::fs::remove_file(path).ok();
    }
}
