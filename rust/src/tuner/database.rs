//! Profiling database — the "Database" box of paper Fig. 1.
//!
//! Stores every profiling attempt with its features and outcome, feeds the
//! three models' training sets, and persists as a JSON tuning log
//! (TVM-style) so runs can be resumed or analyzed offline. Logs carry the
//! layer's shape ([`LayerMeta`]) and the hardware target's
//! capacity-defining fields ([`TargetMeta`]), which is what lets
//! [`TransferDb`] match a directory of prior logs against a *new* layer
//! on a *new* target and assemble a warm-start training set for it —
//! cross-workload and capacity-aware cross-hardware transfer, cf. the
//! MetaTune / HW-aware-initialization lines in PAPERS.md.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::compiler::features;
use crate::compiler::schedule::{Schedule, SpaceKind};
use crate::util::json::Json;
use crate::vta::config::VtaConfig;
use crate::vta::targets::TargetMeta;
use crate::workloads::ConvLayer;

/// Profiling outcome classes (paper §A.2: register-error crash vs
/// wrong-result; both are invalid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Ran correctly; `cycles` is the measured latency.
    Valid {
        /// Measured execution latency in hardware cycles.
        cycles: u64,
    },
    /// Register error — on the real board this needs a manual reboot.
    Crash,
    /// Runs to completion but the output differs from the golden model.
    WrongOutput,
}

impl Outcome {
    /// Whether the trial profiled valid.
    pub fn is_valid(&self) -> bool {
        matches!(self, Outcome::Valid { .. })
    }

    /// Measured cycles, if the trial was valid.
    pub fn cycles(&self) -> Option<u64> {
        match self {
            Outcome::Valid { cycles } => Some(*cycles),
            _ => None,
        }
    }
}

/// Measurement tier a record's outcome came from.
///
/// `Full` is the cycle-accurate `vta::timing` co-simulation (the only
/// tier that counts against trial budgets); `Coarse` is the tier-0
/// analytic estimate from [`crate::vta::coarse`] — rank-useful, but
/// never to be confused with a measured cycle count. Legacy tuning logs
/// carry no tag and load as `Full`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Fidelity {
    /// Full-fidelity profile: three-timeline co-simulated cycles.
    #[default]
    Full,
    /// Tier-0 prescreen: analytic per-module cycle estimate, no build.
    Coarse,
}

/// Training weight of a coarse (tier-0) label relative to a full
/// profile (1.0). Coarse estimates order the landscape but carry level
/// error, so they steer the models without outvoting measured labels.
pub const COARSE_LABEL_WEIGHT: f64 = 0.25;

/// One profiling attempt.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    /// Index of the schedule in its layer's search space.
    pub space_index: usize,
    /// The profiled schedule.
    pub schedule: Schedule,
    /// Visible feature vector (models P/V input).
    pub visible: Vec<f64>,
    /// Hidden feature vector (model A's extra input).
    pub hidden: Vec<f64>,
    /// What profiling observed.
    pub outcome: Outcome,
    /// Measurement tier the outcome came from.
    pub fidelity: Fidelity,
}

impl TrialRecord {
    /// Training label for the performance models: `log2(cycles)`
    /// (scale-free; RMSE ratios in Fig. 3/4 are computed on this).
    pub fn perf_label(&self) -> Option<f64> {
        self.outcome.cycles().map(|c| (c.max(1) as f64).log2())
    }

    /// Training label for model V: 1.0 valid, 0.0 invalid.
    pub fn valid_label(&self) -> f64 {
        self.outcome.is_valid() as u8 as f64
    }
}

/// Layer shape persisted alongside a tuning log — everything needed to
/// match a stored log against a new layer without the workload tables at
/// hand. Mirrors [`ConvLayer`] minus the name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerMeta {
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Output channels.
    pub kc: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Output height.
    pub oh: usize,
    /// Output width.
    pub ow: usize,
    /// Spatial padding.
    pub pad: usize,
    /// Spatial stride.
    pub stride: usize,
}

impl LayerMeta {
    /// Snapshot the shape of a workload layer.
    pub fn of(l: &ConvLayer) -> LayerMeta {
        LayerMeta {
            h: l.h, w: l.w, c: l.c, kc: l.kc, kh: l.kh, kw: l.kw,
            oh: l.oh, ow: l.ow, pad: l.pad, stride: l.stride,
        }
    }

    /// GEMM dimensions after im2col: `(M, K, N)` (same mapping as
    /// [`ConvLayer::gemm_dims`]).
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        crate::workloads::resnet18::im2col_dims(
            self.oh, self.ow, self.kh, self.kw, self.c, self.kc,
        )
    }

    /// Exact MAC count.
    pub fn macs(&self) -> u64 {
        let (m, k, n) = self.gemm_dims();
        m as u64 * k as u64 * n as u64
    }

    /// log2-space shape signature for similarity matching. The dimensions
    /// are the ones that determine a layer's schedule space (output
    /// extent, channel counts, kernel footprint) plus the stride, so two
    /// layers are "similar" exactly when their spaces — and hence the
    /// validity boundary and the performance landscape — overlap.
    pub fn signature(&self) -> Vec<f64> {
        let lg = |v: usize| (v.max(1) as f64).log2();
        vec![
            lg(self.oh),
            lg(self.ow),
            lg(self.c),
            lg(self.kc),
            lg(self.kh * self.kw),
            self.stride as f64,
        ]
    }

    /// Shape similarity in `(0, 1]`: 1 for identical shapes, decaying
    /// with the Euclidean distance between log-space signatures.
    pub fn similarity(&self, other: &LayerMeta) -> f64 {
        let (a, b) = (self.signature(), other.signature());
        let d2: f64 =
            a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        1.0 / (1.0 + d2.sqrt())
    }

    /// Serialize the shape (flat object of the ten dimension fields).
    /// Public because [`crate::serve::ScheduleDb`] embeds shapes in its
    /// entry files with the same layout tuning logs use.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("h", self.h)
            .set("w", self.w)
            .set("c", self.c)
            .set("kc", self.kc)
            .set("kh", self.kh)
            .set("kw", self.kw)
            .set("oh", self.oh)
            .set("ow", self.ow)
            .set("pad", self.pad)
            .set("stride", self.stride);
        o
    }

    /// Parse a shape serialized by [`LayerMeta::to_json`]; every
    /// dimension field is required.
    pub fn from_json(j: &Json) -> Result<LayerMeta> {
        let geti = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("shape missing {k}"))
        };
        Ok(LayerMeta {
            h: geti("h")?,
            w: geti("w")?,
            c: geti("c")?,
            kc: geti("kc")?,
            kh: geti("kh")?,
            kw: geti("kw")?,
            oh: geti("oh")?,
            ow: geti("ow")?,
            pad: geti("pad")?,
            stride: geti("stride")?,
        })
    }
}

/// The profiling database.
#[derive(Clone, Debug)]
pub struct Database {
    /// Name of the layer the records belong to.
    pub layer: String,
    /// Layer shape, when known. Logs written before shape persistence
    /// (or hand-built test databases) have `None` — they still train
    /// models, but [`TransferDb`] can only match them by exact name.
    pub meta: Option<LayerMeta>,
    /// Knob set of the run that produced this database. Serialized with
    /// the log and used to rebuild visible features on load; logs
    /// without the field (pre-ConfigSpace) are paper-kind.
    pub kind: SpaceKind,
    /// Hardware target the records were profiled on (name + the
    /// capacity-defining fields), when known. Logs written before target
    /// stamping have `None` — [`TransferDb`] treats them as
    /// same-hardware sources (the pre-registry behaviour).
    pub target: Option<TargetMeta>,
    /// Every profiling attempt, in profiling order. Records are
    /// `Arc`-shared so keeping a database alongside a
    /// [`crate::tuner::report::TuningTrace`] copies pointers, not
    /// feature vectors; readers auto-deref.
    pub records: Vec<Arc<TrialRecord>>,
}

impl Default for Database {
    fn default() -> Self {
        Database::new("")
    }
}

impl Database {
    /// Bare database with only a layer name (no shape/target stamp).
    pub fn new(layer: &str) -> Self {
        Database { layer: layer.to_string(), meta: None,
                   kind: SpaceKind::Paper, target: None,
                   records: Vec::new() }
    }

    /// Database for a known layer: carries the shape so the persisted
    /// log is usable for cross-layer transfer.
    pub fn for_layer(layer: &ConvLayer) -> Self {
        Self::for_layer_in(layer, SpaceKind::Paper)
    }

    /// Shape-stamped database for a run over a specific knob set.
    pub fn for_layer_in(layer: &ConvLayer, kind: SpaceKind) -> Self {
        Database {
            layer: layer.name.to_string(),
            meta: Some(LayerMeta::of(layer)),
            kind,
            target: None,
            records: Vec::new(),
        }
    }

    /// Shape- *and* target-stamped database: what every tuning run
    /// persists since the target registry (the stamp is what makes the
    /// log usable for capacity-aware cross-target transfer).
    pub fn for_layer_on(
        layer: &ConvLayer,
        kind: SpaceKind,
        hw: &VtaConfig,
    ) -> Self {
        Database {
            target: Some(TargetMeta::of(hw)),
            ..Self::for_layer_in(layer, kind)
        }
    }

    /// Append one profiling record — owned or already `Arc`-shared
    /// (the engine pushes the same `Arc` it stores in the trace).
    pub fn push(&mut self, rec: impl Into<Arc<TrialRecord>>) {
        self.records.push(rec.into());
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the database holds no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Number of records that profiled valid.
    pub fn n_valid(&self) -> usize {
        self.records.iter().filter(|r| r.outcome.is_valid()).count()
    }

    /// Best valid cycles so far, *measured* records only — a coarse
    /// estimate must never masquerade as a run's best.
    pub fn best_cycles(&self) -> Option<u64> {
        self.records
            .iter()
            .filter(|r| r.fidelity == Fidelity::Full)
            .filter_map(|r| r.outcome.cycles())
            .min()
    }

    // ------------------------------------------------------------- JSON --

    /// Serialize the whole log (shape/target stamps + every record).
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("layer", self.layer.as_str());
        root.set("space", self.kind.name());
        if let Some(m) = &self.meta {
            root.set("shape", m.to_json());
        }
        if let Some(t) = &self.target {
            root.set("target", t.to_json());
        }
        let recs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                // knobs are serialized by NAME so logs remain usable —
                // and transfer-matchable — across space versions: a
                // loader skips names it does not know and defaults the
                // ones a record does not carry
                let mut knobs = Json::obj();
                for name in self.kind.knob_names() {
                    knobs.set(name, r.schedule.knob(name).unwrap_or(0));
                }
                o.set("i", r.space_index)
                    .set("knobs", knobs)
                    .set("hidden", r.hidden.clone());
                // full fidelity is the default — omitting it keeps
                // every pre-tier log byte-identical on re-save
                if r.fidelity == Fidelity::Coarse {
                    o.set("fidelity", "coarse");
                }
                match r.outcome {
                    Outcome::Valid { cycles } => {
                        o.set("outcome", "valid").set("cycles", cycles);
                    }
                    Outcome::Crash => {
                        o.set("outcome", "crash");
                    }
                    Outcome::WrongOutput => {
                        o.set("outcome", "wrong");
                    }
                }
                o
            })
            .collect();
        root.set("records", recs);
        root
    }

    /// Parse a tuning log (current knob-object or legacy flat format).
    pub fn from_json(j: &Json) -> Result<Self> {
        let layer = j
            .get("layer")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing layer"))?
            .to_string();
        let mut db = Database::new(&layer);
        db.kind = match j.get("space").and_then(Json::as_str) {
            Some(name) => SpaceKind::parse(name)
                .ok_or_else(|| anyhow!("unknown space kind '{name}'"))?,
            // logs written before the knob-based ConfigSpace carry no
            // space field and are paper-kind by construction
            None => SpaceKind::Paper,
        };
        db.meta = match j.get("shape") {
            Some(s) => Some(LayerMeta::from_json(s)?),
            None => None,
        };
        db.target = match j.get("target") {
            Some(t) => Some(TargetMeta::from_json(t).ok_or_else(|| {
                anyhow!("malformed target stamp")
            })?),
            // pre-registry logs carry no stamp: loadable, matched as
            // same-hardware sources
            None => None,
        };
        for r in j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing records"))?
        {
            let geti = |k: &str| {
                r.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("missing {k}"))
            };
            let mut schedule = Schedule::default();
            if let Some(knobs) = r.get("knobs").and_then(Json::as_obj) {
                for (name, val) in knobs {
                    if let Some(v) = val.as_usize() {
                        // unknown names (future knobs) are skipped; a
                        // knob this build knows but the log's own kind
                        // does not declare keeps its paper default
                        schedule.set_knob(name, v);
                    }
                }
                // ...but every knob the log's declared space kind
                // enumerates must be present and numeric — silently
                // defaulting a missing/corrupt TH to 1 would pair a
                // wrong schedule with a real cycles label and poison
                // warm-start training without any diagnostic
                for name in db.kind.knob_names() {
                    if knobs.get(*name).and_then(Json::as_usize)
                        .is_none()
                    {
                        return Err(anyhow!(
                            "record missing {} knob '{name}'",
                            db.kind.name()
                        ));
                    }
                }
            } else {
                // legacy flat-field format (pre-ConfigSpace logs)
                schedule = Schedule {
                    tile_h: geti("th")?,
                    tile_w: geti("tw")?,
                    tile_oc: geti("oc")?,
                    tile_ic: geti("ic")?,
                    n_vthreads: geti("vt")?,
                    ..Default::default()
                };
            }
            let hidden: Vec<f64> = r
                .get("hidden")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let outcome = match r.get("outcome").and_then(Json::as_str) {
                Some("valid") => Outcome::Valid {
                    cycles: r
                        .get("cycles")
                        .and_then(Json::as_i64)
                        .ok_or_else(|| anyhow!("missing cycles"))?
                        as u64,
                },
                Some("crash") => Outcome::Crash,
                Some("wrong") => Outcome::WrongOutput,
                other => return Err(anyhow!("bad outcome {other:?}")),
            };
            let fidelity = match r.get("fidelity").and_then(Json::as_str) {
                Some("coarse") => Fidelity::Coarse,
                Some("full") => Fidelity::Full,
                Some(other) => {
                    return Err(anyhow!("bad fidelity {other:?}"))
                }
                // legacy logs predate the tier split: everything in
                // them was measured by the full simulator
                None => Fidelity::Full,
            };
            db.push(TrialRecord {
                space_index: geti("i")?,
                schedule,
                // visible features are derived state: rebuild them in
                // this log's own feature layout (transfer re-derives
                // them again in the *target* layout)
                visible: db.kind.visible_features(&schedule),
                hidden,
                outcome,
                fidelity,
            });
        }
        Ok(db)
    }

    /// Write the log to `path` as pretty-printed JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string_pretty())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }

    /// Read a tuning log from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }
}

// ------------------------------------------------------------ transfer --

/// Sources below this shape similarity are never transferred (a distant
/// layer's records are noise, not signal; the threshold admits sibling
/// layers of the same network and near-shape layers of other networks).
pub const MIN_TRANSFER_SIMILARITY: f64 = 0.25;

/// Cross-run transfer store: every tuning log found in a directory (one
/// [`Database`] per layer, as written by `tune --db` / `tune-net --out`),
/// ready to warm-start new runs on any layer of any registered network.
#[derive(Clone, Debug, Default)]
pub struct TransferDb {
    /// Loaded per-layer logs, directory order (sorted by file name).
    /// `Arc`-shared so cloning a store — which the fleet scheduler does
    /// once per target to snapshot its growing transfer chain — copies
    /// pointers, not record vectors.
    pub sources: Vec<Arc<Database>>,
    /// `.json` files in the scanned directory that were not parseable
    /// tuning logs (skipped, not fatal).
    pub skipped: usize,
}

impl TransferDb {
    /// Empty store.
    pub fn new() -> Self {
        TransferDb::default()
    }

    /// Add an in-memory source log (empty logs are ignored).
    pub fn add(&mut self, db: Database) {
        if !db.is_empty() {
            self.sources.push(Arc::new(db));
        }
    }

    /// Load every `*.json` tuning log in `dir` (non-recursive). Files
    /// that do not parse as tuning logs are counted in `skipped`; the
    /// only hard error is an unreadable directory. File names are sorted
    /// so the store — and everything warm-started from it — is
    /// deterministic regardless of directory enumeration order.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<TransferDb> {
        let dir = dir.as_ref();
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().and_then(|e| e.to_str()) == Some("json")
            })
            .collect();
        paths.sort();
        let mut store = TransferDb::new();
        for p in &paths {
            match Database::load(p) {
                Ok(db) if !db.is_empty() => {
                    store.sources.push(Arc::new(db))
                }
                Ok(_) => {}
                Err(_) => store.skipped += 1,
            }
        }
        Ok(store)
    }

    /// Number of source logs loaded.
    pub fn n_layers(&self) -> usize {
        self.sources.len()
    }

    /// Total records across all source logs.
    pub fn total_records(&self) -> usize {
        self.sources.iter().map(|d| d.len()).sum()
    }

    /// Whether the store holds no source logs.
    pub fn is_empty(&self) -> bool {
        self.sources.is_empty()
    }

    /// Assemble a warm-start database for `layer` on hardware `hw`, in
    /// the **target run's** space kind: records from the most similar
    /// stored layers (shape similarity ≥ [`MIN_TRANSFER_SIMILARITY`],
    /// best source first), capped at `max_records`.
    ///
    /// Hardware distance: sources are ordered by `shape_similarity ×
    /// hw_similarity` (see [`TargetMeta::hw_similarity`]), so same-target
    /// logs always lead, and a cross-target source additionally
    /// contributes at most `ceil(len × hw_similarity)` of its records —
    /// capacity-aware down-weighting instead of exclusion. On top of
    /// that, every *valid-labelled* record arriving from a target with
    /// different capacities is audited against `hw`'s static capacity
    /// check: a config that cannot even ideally fit the target's buffers
    /// is relabelled `Crash` before it trains anything. Model V is the
    /// point of the audit — a bigger-buffered source log would otherwise
    /// import its validity boundary at full confidence and pre-train V
    /// to accept configs the target hardware must reject (the V veto
    /// would then steer profiling straight into the crash region).
    /// Unstamped (pre-registry) sources are treated as same-hardware.
    ///
    /// Valid records have their cycle counts rescaled by the target/source
    /// MAC ratio so the `log2(cycles)` labels Model P trains on live on
    /// the target *layer's* scale — transfer moves the *shape* of the
    /// performance landscape, the MAC ratio moves its level. No
    /// hardware-speed rescale is applied on top: a cross-target source
    /// (e.g. a narrower-DMA machine) carries a roughly uniform
    /// per-source level offset in log2 space, which barely perturbs
    /// P's within-layer *ranking* — and any scalar correction would be
    /// wrong for the compute-bound half of the space anyway. The
    /// hardware down-weighting below is what bounds that residual
    /// bias. Validity labels transfer unscaled (the boundary is
    /// scratchpad-pressure driven, a near-layer-independent function
    /// of the schedule) but are capacity-audited — see above.
    /// Sources without shape metadata (legacy logs) are used only when
    /// their layer name matches exactly.
    ///
    /// Cross-space-version transfer: visible features are re-derived
    /// from the stored knob values in the *target* kind's feature layout
    /// (knobs a source record does not carry default to their
    /// paper-fixed values, and source knobs outside the target universe
    /// were already skipped at load). Hidden features transfer when the
    /// source layout covers the target's (extended ⊇ paper: truncated);
    /// otherwise they are cleared — such records still pre-train the
    /// visible-only P and V, and [`crate::tuner::train::TrainSet::extend_a`]
    /// skips them.
    ///
    /// Returns `None` when nothing transfers. The returned database's
    /// `space_index` values refer to the *source* layers' spaces and are
    /// meaningless for the target — warm databases are training-only and
    /// must never drive measurement bookkeeping.
    pub fn warm_start_for(
        &self,
        layer: &ConvLayer,
        kind: SpaceKind,
        hw: &VtaConfig,
        max_records: usize,
    ) -> Option<Database> {
        let target = LayerMeta::of(layer);
        let hw_meta = TargetMeta::of(hw);
        let mut scored: Vec<(f64, f64, &Database)> = self
            .sources
            .iter()
            .filter_map(|src| {
                let sim = match &src.meta {
                    Some(m) => target.similarity(m),
                    None if src.layer == layer.name => 1.0,
                    None => return None,
                };
                if sim < MIN_TRANSFER_SIMILARITY {
                    return None;
                }
                let hw_sim = src
                    .target
                    .as_ref()
                    .map_or(1.0, |t| t.hw_similarity(&hw_meta));
                Some((sim * hw_sim, hw_sim, src.as_ref()))
            })
            .collect();
        // best source first; ties keep load order (sort is stable)
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut warm = Database::for_layer_on(layer, kind, hw);
        for (_, hw_sim, src) in scored {
            if warm.len() >= max_records {
                break;
            }
            // only measured outcomes transfer: a coarse estimate from
            // a prior run is a ranking device, not a label another
            // run's models may treat as ground truth
            let full: Vec<&TrialRecord> = src
                .records
                .iter()
                .filter(|r| r.fidelity == Fidelity::Full)
                .map(Arc::as_ref)
                .collect();
            if full.is_empty() {
                continue;
            }
            let ratio = match &src.meta {
                Some(m) => target.macs() as f64 / m.macs() as f64,
                None => 1.0,
            };
            // hidden features project onto the target layout only when
            // the SOURCE's declared layout covers it (extended = paper
            // prefix + tail); gating on the kind — not on raw vector
            // length — keeps a future non-prefix-compatible layout (or
            // a malformed log) from training model A on misaligned
            // columns. Unprojectable records keep training P/V.
            let projectable = src.kind == kind
                || (src.kind == SpaceKind::Extended
                    && kind == SpaceKind::Paper);
            // the source ran on different capacities iff its stamp's
            // geometry differs from hw's; that both triggers the
            // validity audit and scales the per-source record budget
            let cross_capacity = src
                .target
                .as_ref()
                .is_some_and(|t| !t.same_capacities(&hw_meta));
            let budget = if cross_capacity {
                ((full.len() as f64 * hw_sim).ceil() as usize)
                    .clamp(1, full.len())
            } else {
                full.len()
            };
            // deterministic stride subsample over the WHOLE log: logs
            // are chronological, so a prefix-take would keep only the
            // random-warmup records and always drop the model-guided
            // tail — exactly the highest-quality labels. With
            // `budget == len` this is the identity walk (same-target
            // transfer is unchanged record-for-record).
            for k in 0..budget {
                if warm.len() >= max_records {
                    break;
                }
                let rec = full[k * full.len() / budget];
                let mut r = rec.clone();
                r.visible = kind.visible_features(&r.schedule);
                if projectable
                    && r.hidden.len() == features::hidden_len(src.kind)
                {
                    r.hidden.truncate(features::hidden_len(kind));
                } else {
                    r.hidden.clear(); // trains P/V only
                }
                // capacity audit (see the method docs): a "valid" label
                // minted on different hardware only survives if the
                // config can at least ideally fit the target's buffers
                if cross_capacity && r.outcome.is_valid() {
                    let a = crate::compiler::passes::analyze(
                        hw, layer, &r.schedule,
                    );
                    if !crate::compiler::validity::static_check(hw, &a)
                        .is_plausible()
                    {
                        r.outcome = Outcome::Crash;
                    }
                }
                if let Outcome::Valid { cycles } = r.outcome {
                    let scaled = (cycles as f64 * ratio).round().max(1.0);
                    r.outcome = Outcome::Valid { cycles: scaled as u64 };
                }
                warm.push(r);
            }
        }
        if warm.is_empty() {
            None
        } else {
            Some(warm)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, outcome: Outcome) -> TrialRecord {
        let schedule = Schedule { tile_h: i + 1, tile_w: 2, tile_oc: 16,
                                  tile_ic: 16, n_vthreads: 1,
                                  ..Default::default() };
        TrialRecord {
            space_index: i,
            schedule,
            visible: SpaceKind::Paper.visible_features(&schedule),
            hidden: vec![1.0, 2.0, 3.0],
            outcome,
            fidelity: Fidelity::Full,
        }
    }

    fn coarse_rec(i: usize, outcome: Outcome) -> TrialRecord {
        TrialRecord { hidden: vec![], fidelity: Fidelity::Coarse,
                      ..rec(i, outcome) }
    }

    #[test]
    fn counts_and_best_cycles() {
        // (the per-model training views live in `tuner::train` now —
        // see its tests for the row-assembly semantics)
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(rec(1, Outcome::Crash));
        db.push(rec(2, Outcome::Valid { cycles: 2048 }));
        db.push(rec(3, Outcome::WrongOutput));
        assert_eq!(db.n_valid(), 2);
        assert_eq!(db.best_cycles(), Some(1024));
    }

    #[test]
    fn json_round_trip() {
        let mut db = Database::new("conv3");
        db.push(rec(0, Outcome::Valid { cycles: 5000 }));
        db.push(rec(7, Outcome::Crash));
        db.push(rec(9, Outcome::WrongOutput));
        let j = db.to_json();
        let back = Database::from_json(&j).unwrap();
        assert_eq!(back.layer, "conv3");
        assert_eq!(back.kind, SpaceKind::Paper);
        assert_eq!(back.len(), 3);
        assert_eq!(back.records[0].outcome,
                   Outcome::Valid { cycles: 5000 });
        assert_eq!(back.records[1].schedule.tile_h, 8);
        assert_eq!(back.records[2].outcome, Outcome::WrongOutput);
        assert_eq!(back.records[0].hidden, vec![1.0, 2.0, 3.0]);
        assert_eq!(back.records[0].visible, db.records[0].visible);
    }

    #[test]
    fn json_serializes_knobs_by_name_and_skips_unknown_on_load() {
        let mut db = Database::new("x");
        db.kind = SpaceKind::Extended;
        let mut r = rec(3, Outcome::Crash);
        r.schedule.n_load_slots = 1;
        r.schedule.k_unroll = 4;
        db.push(r);
        let text = db.to_json().to_string_pretty();
        assert!(text.contains("\"kernelUnroll\": 4"), "{text}");
        assert!(text.contains("\"nLoadSlots\": 1"), "{text}");
        assert!(text.contains("\"space\": \"extended\""), "{text}");
        let back = Database::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.kind, SpaceKind::Extended);
        assert_eq!(back.records[0].schedule.k_unroll, 4);
        assert_eq!(back.records[0].visible.len(),
                   SpaceKind::Extended.n_visible());

        // a log from a hypothetical future space version carrying an
        // extra knob: the unknown name is skipped, everything this
        // build declares still lands
        let future = text.replace(
            "\"nLoadSlots\": 1",
            "\"knobFromTheFuture\": 9, \"nLoadSlots\": 1",
        );
        let back2 =
            Database::from_json(&Json::parse(&future).unwrap()).unwrap();
        assert_eq!(back2.records[0].schedule.k_unroll, 4);
        assert_eq!(back2.records[0].schedule.n_load_slots, 1);

        // ...but a knob the log's OWN kind declares must be present:
        // silently defaulting it would poison warm-start training
        let missing = text.replace("\"kernelUnroll\": 4,", "");
        assert!(missing.len() < text.len(), "replace must hit");
        assert!(
            Database::from_json(&Json::parse(&missing).unwrap()).is_err(),
            "missing declared knob must be a load error"
        );
    }

    #[test]
    fn legacy_flat_field_logs_still_load() {
        // pre-ConfigSpace log format: flat th/tw/oc/ic/vt, no space tag
        let text = r#"{
          "layer": "conv1",
          "records": [
            { "i": 5, "th": 8, "tw": 4, "oc": 32, "ic": 16, "vt": 2,
              "hidden": [1.0], "outcome": "valid", "cycles": 777 }
          ]
        }"#;
        let db = Database::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(db.kind, SpaceKind::Paper);
        let r = &db.records[0];
        assert_eq!((r.schedule.tile_h, r.schedule.tile_w), (8, 4));
        assert_eq!(r.schedule.n_load_slots, 2, "paper default");
        assert_eq!(r.visible, SpaceKind::Paper
            .visible_features(&r.schedule));
        assert_eq!(r.outcome, Outcome::Valid { cycles: 777 });
    }

    #[test]
    fn file_round_trip() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 123 }));
        let path = std::env::temp_dir().join("ml2tuner_db_test.json");
        db.save(&path).unwrap();
        let back = Database::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert!(back.meta.is_none(), "name-only db has no shape");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn layer_meta_round_trips_through_json() {
        let layer = crate::workloads::resnet18::layer("conv3").unwrap();
        let mut db = Database::for_layer(&layer);
        db.push(rec(0, Outcome::Valid { cycles: 99 }));
        let back = Database::from_json(&db.to_json()).unwrap();
        assert_eq!(back.meta, Some(LayerMeta::of(&layer)));
        assert_eq!(back.layer, "conv3");
    }

    #[test]
    fn similarity_is_identity_at_equal_shapes_and_orders_neighbors() {
        let pw5 =
            crate::workloads::mobilenet::layer("pw5").unwrap();
        let pw4 =
            crate::workloads::mobilenet::layer("pw4").unwrap();
        let far =
            crate::workloads::gemm::layer("gemm_4096x64x64").unwrap();
        let (a, b, c) =
            (LayerMeta::of(&pw5), LayerMeta::of(&pw4), LayerMeta::of(&far));
        assert_eq!(a.similarity(&a), 1.0);
        assert!(a.similarity(&b) > a.similarity(&c),
                "sibling pointwise layer must beat a distant GEMM");
        assert!(a.similarity(&c) < MIN_TRANSFER_SIMILARITY,
                "distant shapes fall below the transfer threshold");
    }

    fn full_hidden_rec(i: usize, outcome: Outcome) -> TrialRecord {
        let mut r = rec(i, outcome);
        r.hidden = vec![1.0; features::hidden_len(SpaceKind::Paper)];
        r
    }

    #[test]
    fn warm_start_scales_valid_cycles_by_mac_ratio() {
        // pw4 (14x14, 256->512) has exactly half the MACs of pw5
        // (14x14, 512->512): transferred labels must double.
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        let pw5 = crate::workloads::mobilenet::layer("pw5").unwrap();
        assert_eq!(pw5.macs(), 2 * pw4.macs());
        let mut src = Database::for_layer(&pw4);
        src.push(full_hidden_rec(0, Outcome::Valid { cycles: 1000 }));
        src.push(full_hidden_rec(1, Outcome::Crash));
        let mut store = TransferDb::new();
        store.add(src);
        let warm =
            store.warm_start_for(&pw5, SpaceKind::Paper,
                                 &VtaConfig::zcu102(), 100).unwrap();
        assert_eq!(warm.layer, "pw5");
        assert_eq!(warm.len(), 2);
        assert_eq!(warm.records[0].outcome,
                   Outcome::Valid { cycles: 2000 });
        assert_eq!(warm.records[1].outcome, Outcome::Crash,
                   "validity labels transfer unscaled");
    }

    #[test]
    fn warm_start_prefers_similar_sources_and_respects_cap() {
        let pw5 = crate::workloads::mobilenet::layer("pw5").unwrap();
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        let far = crate::workloads::gemm::layer("gemm_4096x64x64").unwrap();
        let pw3 = crate::workloads::mobilenet::layer("pw3").unwrap();
        let mut store = TransferDb::new();
        for (layer, base) in [(&far, 0), (&pw3, 10), (&pw4, 20)] {
            let mut db = Database::for_layer(layer);
            for i in 0..5 {
                db.push(full_hidden_rec(base + i,
                                        Outcome::Valid { cycles: 500 }));
            }
            store.add(db);
        }
        let warm =
            store.warm_start_for(&pw5, SpaceKind::Paper,
                                 &VtaConfig::zcu102(), 7).unwrap();
        assert_eq!(warm.len(), 7, "cap respected");
        // most similar source (pw4) first: its 5 records lead
        assert!(warm.records[..5]
            .iter()
            .all(|r| (20..25).contains(&r.space_index)));
        // the distant GEMM shape is below the threshold — excluded, so
        // the remainder comes from pw3
        assert!(warm.records[5..]
            .iter()
            .all(|r| (10..15).contains(&r.space_index)));
    }

    #[test]
    fn foreign_hidden_layouts_transfer_as_visible_only_records() {
        // a record whose hidden vector cannot be projected onto the
        // target layout still pre-trains the visible-only P and V; its
        // hidden features are cleared so the A-view skips it
        let pw5 = crate::workloads::mobilenet::layer("pw5").unwrap();
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        let mut src = Database::for_layer(&pw4);
        src.push(rec(0, Outcome::Valid { cycles: 100 })); // 3-long hidden
        let mut store = TransferDb::new();
        store.add(src);
        let warm =
            store.warm_start_for(&pw5, SpaceKind::Paper,
                                 &VtaConfig::zcu102(), 10).unwrap();
        assert_eq!(warm.len(), 1);
        assert!(warm.records[0].hidden.is_empty());
        use crate::tuner::train::{Provenance, TrainSet};
        let mut a = TrainSet::new();
        a.extend_a(&warm, Provenance::Warm);
        assert!(a.is_empty(), "A must not train on cleared hidden");
        let mut p = TrainSet::new();
        p.extend_p(&warm, Provenance::Warm);
        assert_eq!(p.len(), 1, "P still trains on the record");
    }

    #[test]
    fn warm_start_rederives_features_across_space_versions() {
        let pw5 = crate::workloads::mobilenet::layer("pw5").unwrap();
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        // paper-kind source log → extended-kind target run: visible
        // grows to the extended layout (defaults for the new knobs),
        // hidden cannot be projected up and clears
        let mut paper_src = Database::for_layer(&pw4);
        paper_src.push(full_hidden_rec(0, Outcome::Valid { cycles: 64 }));
        let mut store = TransferDb::new();
        store.add(paper_src);
        let warm = store
            .warm_start_for(&pw5, SpaceKind::Extended,
                            &VtaConfig::zcu102(), 10)
            .unwrap();
        assert_eq!(warm.kind, SpaceKind::Extended);
        let r = &warm.records[0];
        assert_eq!(r.visible.len(), SpaceKind::Extended.n_visible());
        assert_eq!(r.visible,
                   SpaceKind::Extended.visible_features(&r.schedule));
        assert!(r.hidden.is_empty());

        // extended-kind source → paper-kind target: visible shrinks to
        // the paper layout, hidden truncates to the paper prefix
        let mut ext_src = Database::for_layer_in(&pw4,
                                                 SpaceKind::Extended);
        let mut er = rec(1, Outcome::Valid { cycles: 32 });
        er.schedule.k_unroll = 4;
        er.hidden =
            (0..features::hidden_len(SpaceKind::Extended))
                .map(|i| i as f64)
                .collect();
        er.visible = SpaceKind::Extended.visible_features(&er.schedule);
        ext_src.push(er);
        let mut store2 = TransferDb::new();
        store2.add(ext_src);
        let warm2 = store2
            .warm_start_for(&pw5, SpaceKind::Paper,
                            &VtaConfig::zcu102(), 10)
            .unwrap();
        let r2 = &warm2.records[0];
        assert_eq!(r2.visible.len(), SpaceKind::Paper.n_visible());
        assert_eq!(r2.hidden.len(),
                   features::hidden_len(SpaceKind::Paper));
        assert_eq!(r2.hidden[3], 3.0, "prefix preserved");
    }

    #[test]
    fn target_stamp_round_trips_and_legacy_logs_have_none() {
        let layer = crate::workloads::resnet18::layer("conv3").unwrap();
        let mut db = Database::for_layer_on(&layer, SpaceKind::Paper,
                                            &VtaConfig::zcu104());
        db.push(rec(0, Outcome::Valid { cycles: 42 }));
        let text = db.to_json().to_string_pretty();
        assert!(text.contains("\"zcu104\""), "{text}");
        let back = Database::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.target,
                   Some(TargetMeta::of(&VtaConfig::zcu104())));
        // pre-registry logs (no stamp) still load, with None
        let mut legacy = Database::for_layer(&layer);
        legacy.push(rec(0, Outcome::Valid { cycles: 42 }));
        let back2 =
            Database::from_json(&legacy.to_json()).unwrap();
        assert_eq!(back2.target, None);
    }

    #[test]
    fn cross_target_transfer_audits_valid_labels_against_capacity() {
        // conv1 (56×56×64, 3×3): tile_h = 28, tile_w = 28, tic = 64 has
        // an input halo of 30·30·4 = 3600 vectors — statically fine on
        // the zcu102 (4096) but impossible on edge-small (1024). A
        // source log minted on the zcu102 that labels it valid must NOT
        // hand edge-small's model V a "valid" there.
        let conv1 = crate::workloads::resnet18::layer("conv1").unwrap();
        let edge = VtaConfig::edge_small();
        let big = Schedule { tile_h: 28, tile_w: 28, tile_oc: 16,
                             tile_ic: 64, n_vthreads: 1,
                             ..Default::default() };
        let small = Schedule { tile_h: 4, tile_w: 4, tile_oc: 16,
                               tile_ic: 64, n_vthreads: 1,
                               ..Default::default() };
        let src_of = |i: usize, s: Schedule| {
            let mut src = Database::for_layer_on(
                &conv1, SpaceKind::Paper, &VtaConfig::zcu102(),
            );
            src.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: SpaceKind::Paper.visible_features(&s),
                hidden: vec![1.0;
                             features::hidden_len(SpaceKind::Paper)],
                outcome: Outcome::Valid { cycles: 1000 },
                fidelity: Fidelity::Full,
            });
            src
        };
        let mut store = TransferDb::new();
        store.add(src_of(0, big));
        store.add(src_of(1, small));
        let warm = store
            .warm_start_for(&conv1, SpaceKind::Paper, &edge, 10)
            .unwrap();
        assert_eq!(warm.len(), 2);
        assert_eq!(warm.records[0].outcome, Outcome::Crash,
                   "capacity-impossible valid label must be audited out");
        assert_eq!(warm.records[0].valid_label(), 0.0);
        assert!(warm.records[1].outcome.is_valid(),
                "a config that fits edge-small transfers its label");
        // same-capacity transfer never audits: zcu102 → zcu102 keeps
        // the label even though the tile would overflow *edge-small*
        let mut store2 = TransferDb::new();
        store2.add(src_of(0, big));
        let same = store2
            .warm_start_for(&conv1, SpaceKind::Paper,
                            &VtaConfig::zcu102(), 10)
            .unwrap();
        assert!(same.records[0].outcome.is_valid());
    }

    #[test]
    fn cross_target_v_does_not_cross_the_veto_margin() {
        // End-to-end version of the audit: a zcu102 source log full of
        // valid labels whose big-tile half is impossible on edge-small.
        // After transfer, a model V trained on the warm database alone
        // must veto the impossible region at the default margin.
        use crate::tuner::models::{FitOpts, ModelV};
        use crate::tuner::train::{Provenance, TrainSet};
        use crate::tuner::DEFAULT_V_MARGIN;
        let conv1 = crate::workloads::resnet18::layer("conv1").unwrap();
        let edge = VtaConfig::edge_small();
        let mut src = Database::for_layer_on(&conv1, SpaceKind::Paper,
                                             &VtaConfig::zcu102());
        // th sweeps 1..=28 (tw fixed 28): inp halo = (th+2)·30·4 vecs,
        // > 1024 — edge-small-Hopeless — exactly when th ≥ 7
        for i in 0..480usize {
            let th = 1 + (i % 28);
            let s = Schedule { tile_h: th, tile_w: 28, tile_oc: 16,
                               tile_ic: 64, n_vthreads: 1,
                               ..Default::default() };
            src.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: SpaceKind::Paper.visible_features(&s),
                hidden: vec![1.0;
                             features::hidden_len(SpaceKind::Paper)],
                outcome: Outcome::Valid {
                    cycles: 1_000_000 / th as u64,
                },
                fidelity: Fidelity::Full,
            });
        }
        let n_src = src.len();
        let mut store = TransferDb::new();
        store.add(src);
        let warm = store
            .warm_start_for(&conv1, SpaceKind::Paper, &edge, 400)
            .unwrap();
        // down-weighting: a cross-capacity source contributes at most
        // ceil(len × hw_sim) records
        let hw_sim = TargetMeta::of(&VtaConfig::zcu102())
            .hw_similarity(&TargetMeta::of(&edge));
        assert!(hw_sim < 1.0);
        let budget = (n_src as f64 * hw_sim).ceil() as usize;
        assert_eq!(warm.len(), budget,
                   "cross-target records must be down-weighted");
        // every surviving big-tile record is relabelled invalid
        for r in &warm.records {
            assert_eq!(r.outcome.is_valid(), r.schedule.tile_h < 7,
                       "th={} label", r.schedule.tile_h);
        }
        let mut set = TrainSet::new();
        set.extend_v(&warm, Provenance::Warm);
        let v = ModelV::fit(&set, &FitOpts::new(80, 1)).unwrap();
        let feats = |th: usize| {
            let s = Schedule { tile_h: th, tile_w: 28, tile_oc: 16,
                               tile_ic: 64, n_vthreads: 1,
                               ..Default::default() };
            SpaceKind::Paper.visible_features(&s)
        };
        assert!(!v.predict_valid(&feats(20), DEFAULT_V_MARGIN),
                "V pre-trained past the veto margin on an impossible \
                 config");
        assert!(v.predict_valid(&feats(2), DEFAULT_V_MARGIN),
                "V must still accept configs that fit the target");
    }

    #[test]
    fn same_target_sources_lead_cross_target_ones() {
        // two sources with the SAME layer shape: one minted on
        // edge-small itself, one on the (distant) zcu102 — the
        // same-target log's records must come first in the warm set
        let conv5 = crate::workloads::resnet18::layer("conv5").unwrap();
        let edge = VtaConfig::edge_small();
        let mut native = Database::for_layer_on(&conv5, SpaceKind::Paper,
                                                &edge);
        let mut foreign = Database::for_layer_on(&conv5, SpaceKind::Paper,
                                                 &VtaConfig::zcu102());
        for i in 0..4 {
            native.push(full_hidden_rec(i, Outcome::Crash));
            foreign.push(full_hidden_rec(100 + i, Outcome::Crash));
        }
        let mut store = TransferDb::new();
        store.add(foreign); // load order favours the foreign log...
        store.add(native);
        let warm = store
            .warm_start_for(&conv5, SpaceKind::Paper, &edge, 100)
            .unwrap();
        assert!(warm.records[..4]
                    .iter()
                    .all(|r| r.space_index < 100),
                "...but hardware distance must rank the native log \
                 first");
    }

    #[test]
    fn fidelity_round_trips_and_legacy_defaults_full() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 100 }));
        db.push(coarse_rec(1, Outcome::Valid { cycles: 90 }));
        db.push(coarse_rec(2, Outcome::Crash));
        let text = db.to_json().to_string_pretty();
        assert_eq!(text.matches("\"fidelity\": \"coarse\"").count(), 2,
                   "full records carry no tag: {text}");
        let back = Database::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.records[0].fidelity, Fidelity::Full);
        assert_eq!(back.records[1].fidelity, Fidelity::Coarse);
        assert_eq!(back.records[2].fidelity, Fidelity::Coarse);
        // a pre-tier log (no fidelity field anywhere) loads as Full
        let legacy = text.replace("\"fidelity\": \"coarse\",", "")
            .replace(",\n      \"fidelity\": \"coarse\"", "");
        let old = Database::from_json(&Json::parse(&legacy).unwrap())
            .unwrap();
        assert!(old.records.iter()
                    .all(|r| r.fidelity == Fidelity::Full));
    }

    #[test]
    fn best_cycles_never_reads_a_coarse_estimate() {
        let mut db = Database::new("conv1");
        db.push(rec(0, Outcome::Valid { cycles: 1024 }));
        db.push(coarse_rec(2, Outcome::Valid { cycles: 16 }));
        assert_eq!(db.best_cycles(), Some(1024));
    }

    #[test]
    fn transfer_never_exports_coarse_records() {
        let pw4 = crate::workloads::mobilenet::layer("pw4").unwrap();
        let pw5 = crate::workloads::mobilenet::layer("pw5").unwrap();
        let mut src = Database::for_layer(&pw4);
        src.push(coarse_rec(0, Outcome::Valid { cycles: 10 }));
        src.push(full_hidden_rec(1, Outcome::Valid { cycles: 1000 }));
        src.push(coarse_rec(2, Outcome::Crash));
        let mut store = TransferDb::new();
        store.add(src);
        let warm = store
            .warm_start_for(&pw5, SpaceKind::Paper,
                            &VtaConfig::zcu102(), 100)
            .unwrap();
        assert_eq!(warm.len(), 1, "only the measured record transfers");
        assert_eq!(warm.records[0].space_index, 1);
        // an all-coarse source transfers nothing at all
        let mut src2 = Database::for_layer(&pw4);
        src2.push(coarse_rec(0, Outcome::Valid { cycles: 10 }));
        let mut store2 = TransferDb::new();
        store2.add(src2);
        assert!(store2
            .warm_start_for(&pw5, SpaceKind::Paper,
                            &VtaConfig::zcu102(), 100)
            .is_none());
    }
}
