//! Configuration explorer — the candidate-selection core of paper Fig. 1.
//!
//! Ranks unmeasured configurations by model P (ascending predicted
//! log-cycles), mixes in ε-greedy random exploration, and optionally vetoes
//! candidates model V predicts invalid ("Even if Model P predicts a
//! configuration as highly optimal, ML²Tuner avoids profiling it if Model V
//! predicts it to be invalid", §2).
//!
//! The decode+score sweep is the tuner's dominant non-profiling cost (the
//! whole space is consulted every round), so it runs batched and sharded:
//! fixed [`SCORE_CHUNK`]-index chunks each fill one reusable
//! [`FeatureMatrix`] (no per-candidate `Vec`), the models' flattened
//! ensembles score each chunk in one batched walk, and chunks fan out
//! across the engine's `--jobs` worker pool with an ordered merge — so
//! scores, rankings, and therefore traces are **bit-identical** for any
//! worker count and to the old row-at-a-time sweep
//! (`tests/flat_inference.rs` pins both).

use std::time::Instant;

use super::models::{ModelP, ModelV};
use super::space::SearchSpace;
use super::DEFAULT_V_MARGIN;
use crate::gbdt::FeatureMatrix;
use crate::obs::{Counter, Recorder, Stage};
use crate::util::par::par_map_with;
use crate::util::rng::Rng;

/// Explorer policy knobs.
pub struct Explorer<'r> {
    /// ε-greedy exploration fraction of each selected batch.
    pub epsilon: f64,
    /// Model-V veto margin (see `TunerConfig::v_margin`).
    pub v_margin: f64,
    /// Worker threads for the decode+score sweep (the engine's `--jobs`;
    /// results merge in fixed chunk order, so rankings are invariant in
    /// this value).
    pub jobs: usize,
    /// Telemetry recorder for sweep spans / chunk timings (pure
    /// observation — never consulted for any decision).
    pub recorder: Option<&'r Recorder>,
}

/// What one selection round observed about model V: the veto count and
/// the V margins of the picked candidates (parallel to the returned
/// indices), from which the loop computes the round's precision/recall
/// confusion once the picks are profiled.
#[derive(Clone, Debug, Default)]
pub struct SelectStats {
    /// Candidates V filtered out this round.
    pub vetoes: u64,
    /// V margins of the picked candidates, pick order.
    pub margins: Vec<f64>,
}

/// Per-round scoring budget: above this many unmeasured candidates the
/// explorer ranks a uniform random subsample instead of the whole space
/// (AutoTVM-style), bounding each round's decode+predict sweep and its
/// transient allocations on very large extended spaces.
///
/// The bound sits above every registered *paper* space (those are capped
/// < 300k by `workloads::registry` tests), so paper-space runs never
/// take this branch and their traces stay byte-identical to the
/// pre-ConfigSpace implementation; only 6x extended spaces of the
/// largest layers are subsampled.
pub const MAX_SCORED_CANDIDATES: usize = 400_000;

/// Candidates per parallel scoring chunk: large enough to amortize the
/// chunk's feature matrix and score buffers over thousands of
/// candidates, small enough to keep every `--jobs` worker busy on
/// mid-size spaces.
pub const SCORE_CHUNK: usize = 4096;

/// Per-worker buffers of the scoring sweep, created once per worker by
/// `par_map_with` and reused across every chunk that worker pulls. Each
/// buffer is cleared (or fully overwritten) per chunk, so reuse never
/// changes a score — only the allocation count.
struct SweepScratch {
    /// One decoded visible-feature row.
    feats: Vec<f64>,
    /// Row-major chunk matrix for the batch kernels.
    m: FeatureMatrix,
    /// Model-P scores, one per chunk row.
    scores: Vec<f64>,
    /// Model-V margins, one per chunk row (0.0 without a V model).
    margins: Vec<f64>,
}

/// Decode and score `candidates` against model P (and model V's margin
/// when given): returns one `(p_score, v_margin, index)` triple per
/// candidate, in input order. Without a V model the margin slot is 0.0.
///
/// This is the explorer's hot path — per fixed-size chunk it fills a
/// per-worker reusable row-major [`FeatureMatrix`] (see [`SweepScratch`])
/// and runs the flattened batch kernels; chunks fan out over `jobs`
/// workers and merge back in chunk order, so the result is invariant in
/// `jobs` and bit-identical to a sequential per-row sweep.
pub fn score_candidates(
    space: &SearchSpace,
    p: &ModelP,
    v: Option<&ModelV>,
    candidates: &[usize],
    jobs: usize,
    recorder: Option<&Recorder>,
) -> Vec<(f64, f64, usize)> {
    let _sweep = recorder.map(|r| r.span(Stage::Sweep));
    let chunks: Vec<&[usize]> = candidates.chunks(SCORE_CHUNK).collect();
    let init = || SweepScratch {
        feats: Vec::with_capacity(space.n_visible()),
        m: FeatureMatrix::with_capacity(space.n_visible(), SCORE_CHUNK),
        scores: Vec::with_capacity(SCORE_CHUNK),
        margins: Vec::with_capacity(SCORE_CHUNK),
    };
    let scored: Vec<Vec<(f64, f64, usize)>> =
        par_map_with(jobs, chunks.len(), init, |s, c| {
            let chunk = chunks[c];
            let t0 = Instant::now();
            s.m.clear();
            for &i in chunk {
                space.visible_into(i, &mut s.feats);
                s.m.push_row_f64(&s.feats);
            }
            p.predict_batch_into(&s.m, &mut s.scores);
            match v {
                Some(vm) => vm.margin_batch_into(&s.m, &mut s.margins),
                None => {
                    s.margins.clear();
                    s.margins.resize(chunk.len(), 0.0);
                }
            }
            let out: Vec<(f64, f64, usize)> = chunk
                .iter()
                .zip(&s.scores)
                .zip(&s.margins)
                .map(|((&i, &sc), &mg)| (sc, mg, i))
                .collect();
            if let Some(r) = recorder {
                r.record_duration_ns(Stage::SweepChunk,
                                     t0.elapsed().as_nanos() as u64);
                r.add(Counter::SweepCandidates, chunk.len() as u64);
            }
            out
        });
    scored.into_iter().flatten().collect()
}

/// Incremental pool of untaken rank positions with O(log n) k-th
/// -smallest selection and removal (a Fenwick tree over position
/// occupancy). Replaces the ε-exploration inner loop's O(n) rebuild of
/// the untaken-position list per hit — O(n²) over a ranking walk — while
/// selecting exactly the same position for the same draw: `kth(j)` is
/// the j-th untaken position in ascending order, which is what indexing
/// the rebuilt list at `j` returned.
struct FreePool {
    /// 1-based Fenwick tree; `tree[i]` counts untaken positions in the
    /// block `(i - lowbit(i), i]`.
    tree: Vec<u32>,
    len: usize,
    remaining: usize,
}

impl FreePool {
    /// All `n` positions start untaken. O(n) build.
    fn new(n: usize) -> FreePool {
        let mut tree = vec![0u32; n + 1];
        for i in 1..=n {
            tree[i] += 1;
            let j = i + (i & i.wrapping_neg());
            if j <= n {
                tree[j] += tree[i];
            }
        }
        FreePool { tree, len: n, remaining: n }
    }

    fn remaining(&self) -> usize {
        self.remaining
    }

    /// The k-th (0-based) untaken position, ascending; `None` when
    /// `k >= remaining()`.
    fn kth(&self, k: usize) -> Option<usize> {
        if k >= self.remaining {
            return None;
        }
        let mut bit = 1usize;
        while bit << 1 <= self.len {
            bit <<= 1;
        }
        let mut pos = 0usize;
        let mut rank = (k + 1) as u32;
        while bit > 0 {
            let next = pos + bit;
            if next <= self.len && self.tree[next] < rank {
                rank -= self.tree[next];
                pos = next;
            }
            bit >>= 1;
        }
        Some(pos)
    }

    /// Mark the 0-based position taken (must currently be untaken).
    fn take(&mut self, pos: usize) {
        let mut i = pos + 1;
        while i <= self.len {
            self.tree[i] -= 1;
            i += i & i.wrapping_neg();
        }
        self.remaining -= 1;
    }
}

impl<'r> Explorer<'r> {
    /// Single-threaded explorer with the default V margin.
    pub fn new(epsilon: f64) -> Self {
        Explorer {
            epsilon,
            v_margin: DEFAULT_V_MARGIN,
            jobs: 1,
            recorder: None,
        }
    }

    /// Override the model-V veto margin.
    pub fn with_v_margin(mut self, v_margin: f64) -> Self {
        self.v_margin = v_margin;
        self
    }

    /// Shard the scoring sweep across `jobs` workers (traces are
    /// invariant in this — see [`score_candidates`]).
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Record sweep spans / chunk timings on `recorder` (observation
    /// only; selection is identical with or without it).
    pub fn with_recorder(mut self, recorder: &'r Recorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Select up to `count` unmeasured candidates.
    ///
    /// Walks the P-ranking best-first; each slot is replaced by a uniform
    /// random unmeasured candidate with probability ε. With a V model,
    /// predicted-invalid candidates are skipped; if the ranking is
    /// exhausted before `count` survivors are found, the best skipped ones
    /// fill the remainder (the explorer must always make progress).
    pub fn select(
        &self,
        space: &SearchSpace,
        p: &ModelP,
        v: Option<&ModelV>,
        count: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        self.select_with_stats(space, p, v, count, rng).0
    }

    /// [`select`](Self::select) plus the round's [`SelectStats`]
    /// (vetoes + picked-candidate margins). The stats are `None` on the
    /// space-nearly-exhausted shortcut, where no scoring happens. The
    /// rng stream and the returned picks are byte-identical to
    /// `select`'s.
    pub fn select_with_stats(
        &self,
        space: &SearchSpace,
        p: &ModelP,
        v: Option<&ModelV>,
        count: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, Option<SelectStats>) {
        let n_left = space.n_unmeasured();
        if n_left <= count {
            return (space.unmeasured(), None);
        }
        let unmeasured: Vec<usize> = if n_left > MAX_SCORED_CANDIDATES {
            // bound the model sweep on huge spaces (see
            // MAX_SCORED_CANDIDATES) by rejection-sampling distinct
            // unmeasured indices directly — O(sample) memory, never
            // O(space); with > 400k unmeasured points the rejection
            // rate is negligible. Deterministic per rng stream.
            let mut seen = std::collections::HashSet::with_capacity(
                MAX_SCORED_CANDIDATES,
            );
            let mut sampled = Vec::with_capacity(MAX_SCORED_CANDIDATES);
            while sampled.len() < MAX_SCORED_CANDIDATES {
                let i = rng.below(space.len());
                if !space.is_measured(i) && seen.insert(i) {
                    sampled.push(i);
                }
            }
            sampled
        } else {
            space.unmeasured()
        };
        // Rank by predicted log-cycles ascending. Tree ensembles cannot
        // extrapolate, so large swaths of the space tie at the best leaf
        // value — including invalid regions adjacent to the optimum. Ties
        // are broken by V's margin (most-confidently-valid first), which is
        // the "iteratively applies models P and V" of paper §2 and avoids
        // the degenerate behaviour of walking an invalid-dominated tie
        // front and harvesting exactly V's false positives.
        let mut scored = score_candidates(space, p, v, &unmeasured,
                                          self.jobs, self.recorder);
        scored.sort_by(|a, b| {
            // ascending P score, then descending V margin — the same
            // total preorder the old (score, -margin) tie key induced
            (a.0, -a.1).partial_cmp(&(b.0, -b.1)).unwrap()
        });
        let mut picked: Vec<usize> = Vec::with_capacity(count);
        let mut margins: Vec<f64> = Vec::with_capacity(count);
        let mut vetoes = 0u64;
        let mut taken = vec![false; scored.len()];
        let mut pool = FreePool::new(scored.len());
        let mut skipped: Vec<usize> = Vec::new(); // rank positions V vetoed
        let mut pos = 0usize;
        while picked.len() < count && pos < scored.len() {
            if rng.bool(self.epsilon) {
                if pool.remaining() == 0 {
                    // every rank position is already taken (possible
                    // under a veto-all margin once the walk exhausts
                    // the ranking): break to the fallback fills
                    // instead of drawing from an empty pool — the old
                    // free-list rebuild panicked (`below(0)`) here
                    break;
                }
                // ε-exploration: uniform random untaken candidate (the
                // j-th untaken rank position, via the incremental pool)
                let j = rng.below(pool.remaining());
                if let Some(k) = pool.kth(j) {
                    pool.take(k);
                    taken[k] = true;
                    picked.push(scored[k].2);
                    margins.push(scored[k].1);
                }
                continue;
            }
            // next untaken position in the ranking
            while pos < scored.len() && taken[pos] {
                pos += 1;
            }
            if pos >= scored.len() {
                break;
            }
            let (_, margin, idx) = scored[pos];
            taken[pos] = true;
            pool.take(pos);
            // the precomputed margin is exactly what predict_valid
            // recomputed per candidate before the batched sweep
            let vetoed = v.is_some() && margin <= self.v_margin;
            if vetoed {
                vetoes += 1;
                skipped.push(pos);
            } else {
                picked.push(idx);
                margins.push(margin);
            }
            pos += 1;
        }
        // not enough survivors: fall back to the best vetoed candidates
        for k in skipped {
            if picked.len() >= count {
                break;
            }
            picked.push(scored[k].2);
            margins.push(scored[k].1);
        }
        // still short (tiny spaces): fill with remaining ranking order
        if picked.len() < count {
            for k in 0..scored.len() {
                if picked.len() >= count {
                    break;
                }
                if !taken[k] {
                    taken[k] = true;
                    picked.push(scored[k].2);
                    margins.push(scored[k].1);
                }
            }
        }
        if let Some(r) = self.recorder {
            r.add(Counter::VVetoes, vetoes);
        }
        (picked, Some(SelectStats { vetoes, margins }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::Schedule;
    use crate::tuner::database::{Database, Fidelity, Outcome, TrialRecord};
    use crate::tuner::models::FitOpts;
    use crate::tuner::train::{Provenance, TrainSet};
    use crate::workloads::resnet18;

    /// Train P/V on a synthetic labelling of the real conv5 space.
    fn trained_models() -> (SearchSpace, ModelP, ModelV) {
        let layer = resnet18::layer("conv5").unwrap();
        let space = SearchSpace::new(&layer);
        let mut db = Database::new("conv5");
        for i in (0..space.len()).step_by(3) {
            let s: Schedule = space.schedule(i);
            let valid = s.tile_h * s.n_vthreads <= 28;
            let cycles = (1_000_000 / (s.tile_h * s.tile_w)
                + 5_000 * s.n_vthreads) as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: space.visible(i),
                hidden: vec![],
                outcome: if valid {
                    Outcome::Valid { cycles }
                } else {
                    Outcome::Crash
                },
                fidelity: Fidelity::Full,
            });
        }
        let opts = FitOpts::new(60, 1);
        let mut pset = TrainSet::new();
        pset.extend_p(&db, Provenance::Cold);
        let mut vset = TrainSet::new();
        vset.extend_v(&db, Provenance::Cold);
        let p = ModelP::fit(&pset, &opts).unwrap();
        let v = ModelV::fit(&vset, &opts).unwrap();
        (space, p, v)
    }

    #[test]
    fn selects_requested_count_without_duplicates() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(1);
        let e = Explorer::new(0.05);
        let picks = e.select(&space, &p, Some(&v), 20, &mut rng);
        assert_eq!(picks.len(), 20);
        let mut u = picks.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20, "no duplicates");
    }

    #[test]
    fn v_filter_shifts_selection_toward_valid() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(2);
        let e = Explorer::new(0.0);
        let with_v = e.select(&space, &p, Some(&v), 30, &mut rng);
        let without_v = e.select(&space, &p, None, 30, &mut rng);
        let count_pred_valid = |picks: &[usize]| {
            picks
                .iter()
                .filter(|&&i| {
                    v.predict_valid(&space.visible(i),
                                    crate::tuner::DEFAULT_V_MARGIN)
                })
                .count()
        };
        assert!(count_pred_valid(&with_v) >= count_pred_valid(&without_v));
        assert_eq!(count_pred_valid(&with_v), 30);
    }

    #[test]
    fn respects_measured_mask() {
        let (mut space, p, v) = trained_models();
        let mut rng = Rng::new(3);
        let e = Explorer::new(0.1);
        let first = e.select(&space, &p, Some(&v), 10, &mut rng);
        for &i in &first {
            space.mark_measured(i);
        }
        let second = e.select(&space, &p, Some(&v), 10, &mut rng);
        for i in &second {
            assert!(!first.contains(i), "re-proposed measured config");
        }
    }

    #[test]
    fn extreme_margin_vetoes_everything_but_fallback_fills() {
        // v_margin above the hinge range vetoes every candidate; the
        // explorer must still make progress via the skipped-best
        // fallback, in P-ranking order
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(9);
        let veto_all = Explorer::new(0.0).with_v_margin(2.0);
        let picks = veto_all.select(&space, &p, Some(&v), 10, &mut rng);
        assert_eq!(picks.len(), 10);
        // an accept-all margin shares the exact same P/V ranking, so the
        // all-vetoed fallback must reproduce its best-first picks
        let mut rng2 = Rng::new(9);
        let accept_all = Explorer::new(0.0).with_v_margin(-2.0);
        let loose = accept_all.select(&space, &p, Some(&v), 10, &mut rng2);
        assert_eq!(picks, loose,
                   "all-vetoed fallback must degrade to the ranking head");
    }

    #[test]
    fn epsilon_one_is_fully_random_but_valid_count() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(4);
        let e = Explorer::new(1.0);
        let picks = e.select(&space, &p, Some(&v), 15, &mut rng);
        assert_eq!(picks.len(), 15);
    }

    #[test]
    fn selection_is_invariant_in_jobs() {
        let (space, p, v) = trained_models();
        let mut picks: Vec<Vec<usize>> = Vec::new();
        for jobs in [1, 2, 8] {
            let mut rng = Rng::new(6);
            let e = Explorer::new(0.1).with_jobs(jobs);
            picks.push(e.select(&space, &p, Some(&v), 25, &mut rng));
        }
        assert_eq!(picks[0], picks[1]);
        assert_eq!(picks[0], picks[2]);
    }

    #[test]
    fn score_candidates_is_jobs_invariant_and_matches_row_path() {
        let (space, p, v) = trained_models();
        let idx: Vec<usize> =
            (0..space.len()).step_by(2).collect();
        let seq = score_candidates(&space, &p, Some(&v), &idx, 1, None);
        let par = score_candidates(&space, &p, Some(&v), &idx, 4, None);
        assert_eq!(seq.len(), idx.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
            assert_eq!(a.2, b.2);
        }
        // batched sweep == the old per-row predict on a sample
        for &(s, mg, i) in seq.iter().step_by(101) {
            let feats = space.visible(i);
            assert_eq!(s.to_bits(), p.predict(&feats).to_bits());
            assert_eq!(mg.to_bits(), v.margin(&feats).to_bits());
        }
    }

    #[test]
    fn select_with_stats_matches_select_and_reports_margins() {
        let (space, p, v) = trained_models();
        let e = Explorer::new(0.1);
        let mut rng_a = Rng::new(7);
        let plain = e.select(&space, &p, Some(&v), 20, &mut rng_a);
        let mut rng_b = Rng::new(7);
        let (picked, stats) =
            e.select_with_stats(&space, &p, Some(&v), 20, &mut rng_b);
        assert_eq!(plain, picked, "stats variant must not change picks");
        let stats = stats.expect("scoring ran, stats must be present");
        assert_eq!(stats.margins.len(), picked.len(),
                   "one margin per picked candidate");
        // margins must be the sweep's margins for exactly those picks
        for (&i, &m) in picked.iter().zip(&stats.margins) {
            assert_eq!(m.to_bits(), v.margin(&space.visible(i)).to_bits());
        }
        // a veto-all margin reports every walked candidate as vetoed
        let mut rng_c = Rng::new(7);
        let (_, vstats) = Explorer::new(0.0)
            .with_v_margin(2.0)
            .select_with_stats(&space, &p, Some(&v), 10, &mut rng_c);
        assert!(vstats.unwrap().vetoes > 0);
    }

    #[test]
    fn recorder_attachment_does_not_change_selection() {
        let (space, p, v) = trained_models();
        let rec = crate::obs::Recorder::new();
        let mut rng_a = Rng::new(11);
        let without = Explorer::new(0.1)
            .with_jobs(2)
            .select(&space, &p, Some(&v), 20, &mut rng_a);
        let mut rng_b = Rng::new(11);
        let with = Explorer::new(0.1)
            .with_jobs(2)
            .with_recorder(&rec)
            .select(&space, &p, Some(&v), 20, &mut rng_b);
        assert_eq!(without, with);
        assert!(rec.get(Counter::SweepCandidates) > 0);
        assert_eq!(rec.stage_total(Stage::Sweep).count, 1);
        assert!(rec.stage_total(Stage::SweepChunk).count >= 1);
    }

    #[test]
    fn free_pool_matches_naive_untaken_list() {
        let mut pool = FreePool::new(13);
        let mut taken = vec![false; 13];
        // deterministic take pattern exercising ends and middle
        for &t in &[0usize, 12, 6, 1, 11, 5, 7] {
            pool.take(t);
            taken[t] = true;
            let free: Vec<usize> =
                (0..13).filter(|&k| !taken[k]).collect();
            assert_eq!(pool.remaining(), free.len());
            for (j, &want) in free.iter().enumerate() {
                assert_eq!(pool.kth(j), Some(want), "after taking {t}");
            }
            assert_eq!(pool.kth(free.len()), None);
        }
    }

    #[test]
    fn free_pool_empty_and_exhausted() {
        let empty = FreePool::new(0);
        assert_eq!(empty.remaining(), 0);
        assert_eq!(empty.kth(0), None);
        let mut one = FreePool::new(1);
        assert_eq!(one.kth(0), Some(0));
        one.take(0);
        assert_eq!(one.remaining(), 0);
        assert_eq!(one.kth(0), None);
    }
}
