//! Configuration explorer — the candidate-selection core of paper Fig. 1.
//!
//! Ranks unmeasured configurations by model P (ascending predicted
//! log-cycles), mixes in ε-greedy random exploration, and optionally vetoes
//! candidates model V predicts invalid ("Even if Model P predicts a
//! configuration as highly optimal, ML²Tuner avoids profiling it if Model V
//! predicts it to be invalid", §2).

use super::models::{ModelP, ModelV};
use super::space::SearchSpace;
use super::DEFAULT_V_MARGIN;
use crate::util::rng::Rng;

/// Explorer policy knobs.
pub struct Explorer {
    pub epsilon: f64,
    /// Model-V veto margin (see `TunerConfig::v_margin`).
    pub v_margin: f64,
}

/// Per-round scoring budget: above this many unmeasured candidates the
/// explorer ranks a uniform random subsample instead of the whole space
/// (AutoTVM-style), bounding each round's decode+predict sweep and its
/// transient allocations on very large extended spaces.
///
/// The bound sits above every registered *paper* space (those are capped
/// < 300k by `workloads::registry` tests), so paper-space runs never
/// take this branch and their traces stay byte-identical to the
/// pre-ConfigSpace implementation; only 6x extended spaces of the
/// largest layers are subsampled.
pub const MAX_SCORED_CANDIDATES: usize = 400_000;

impl Explorer {
    pub fn new(epsilon: f64) -> Self {
        Explorer { epsilon, v_margin: DEFAULT_V_MARGIN }
    }

    pub fn with_v_margin(mut self, v_margin: f64) -> Self {
        self.v_margin = v_margin;
        self
    }

    /// Select up to `count` unmeasured candidates.
    ///
    /// Walks the P-ranking best-first; each slot is replaced by a uniform
    /// random unmeasured candidate with probability ε. With a V model,
    /// predicted-invalid candidates are skipped; if the ranking is
    /// exhausted before `count` survivors are found, the best skipped ones
    /// fill the remainder (the explorer must always make progress).
    pub fn select(
        &self,
        space: &SearchSpace,
        p: &ModelP,
        v: Option<&ModelV>,
        count: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let n_left = space.n_unmeasured();
        if n_left <= count {
            return space.unmeasured();
        }
        let unmeasured: Vec<usize> = if n_left > MAX_SCORED_CANDIDATES {
            // bound the model sweep on huge spaces (see
            // MAX_SCORED_CANDIDATES) by rejection-sampling distinct
            // unmeasured indices directly — O(sample) memory, never
            // O(space); with > 400k unmeasured points the rejection
            // rate is negligible. Deterministic per rng stream.
            let mut seen = std::collections::HashSet::with_capacity(
                MAX_SCORED_CANDIDATES,
            );
            let mut sampled = Vec::with_capacity(MAX_SCORED_CANDIDATES);
            while sampled.len() < MAX_SCORED_CANDIDATES {
                let i = rng.below(space.len());
                if !space.is_measured(i) && seen.insert(i) {
                    sampled.push(i);
                }
            }
            sampled
        } else {
            space.unmeasured()
        };
        // Rank by predicted log-cycles ascending. Tree ensembles cannot
        // extrapolate, so large swaths of the space tie at the best leaf
        // value — including invalid regions adjacent to the optimum. Ties
        // are broken by V's margin (most-confidently-valid first), which is
        // the "iteratively applies models P and V" of paper §2 and avoids
        // the degenerate behaviour of walking an invalid-dominated tie
        // front and harvesting exactly V's false positives.
        let mut scored: Vec<(f64, f64, usize)> = unmeasured
            .iter()
            .map(|&i| {
                let feats = space.visible(i);
                let tie = v.map_or(0.0, |m| -m.margin(&feats));
                (p.predict(&feats), tie, i)
            })
            .collect();
        scored.sort_by(|a, b| {
            (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap()
        });
        let scored: Vec<(f64, usize)> =
            scored.into_iter().map(|(s, _, i)| (s, i)).collect();
        let mut picked: Vec<usize> = Vec::with_capacity(count);
        let mut taken = vec![false; scored.len()];
        let mut skipped: Vec<usize> = Vec::new(); // rank positions V vetoed
        let mut pos = 0usize;
        while picked.len() < count && pos < scored.len() {
            if rng.bool(self.epsilon) {
                // ε-exploration: uniform random untaken candidate
                let free: Vec<usize> = (0..scored.len())
                    .filter(|&k| !taken[k])
                    .collect();
                if let Some(&k) = free.get(rng.below(free.len())) {
                    taken[k] = true;
                    picked.push(scored[k].1);
                }
                continue;
            }
            // next untaken position in the ranking
            while pos < scored.len() && taken[pos] {
                pos += 1;
            }
            if pos >= scored.len() {
                break;
            }
            let idx = scored[pos].1;
            taken[pos] = true;
            let vetoed = v.is_some_and(|m| {
                !m.predict_valid(&space.visible(idx), self.v_margin)
            });
            if vetoed {
                skipped.push(pos);
            } else {
                picked.push(idx);
            }
            pos += 1;
        }
        // not enough survivors: fall back to the best vetoed candidates
        for k in skipped {
            if picked.len() >= count {
                break;
            }
            picked.push(scored[k].1);
        }
        // still short (tiny spaces): fill with remaining ranking order
        if picked.len() < count {
            for k in 0..scored.len() {
                if picked.len() >= count {
                    break;
                }
                if !taken[k] {
                    taken[k] = true;
                    picked.push(scored[k].1);
                }
            }
        }
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::Schedule;
    use crate::tuner::database::{Database, Outcome, TrialRecord};
    use crate::workloads::resnet18;

    /// Train P/V on a synthetic labelling of the real conv5 space.
    fn trained_models() -> (SearchSpace, ModelP, ModelV) {
        let layer = resnet18::layer("conv5").unwrap();
        let space = SearchSpace::new(&layer);
        let mut db = Database::new("conv5");
        for i in (0..space.len()).step_by(3) {
            let s: Schedule = space.schedule(i);
            let valid = s.tile_h * s.n_vthreads <= 28;
            let cycles = (1_000_000 / (s.tile_h * s.tile_w)
                + 5_000 * s.n_vthreads) as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: space.visible(i),
                hidden: vec![],
                outcome: if valid {
                    Outcome::Valid { cycles }
                } else {
                    Outcome::Crash
                },
            });
        }
        let p = ModelP::train(&db, 60, 1).unwrap();
        let v = ModelV::train(&db, 60, 1).unwrap();
        (space, p, v)
    }

    #[test]
    fn selects_requested_count_without_duplicates() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(1);
        let e = Explorer::new(0.05);
        let picks = e.select(&space, &p, Some(&v), 20, &mut rng);
        assert_eq!(picks.len(), 20);
        let mut u = picks.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20, "no duplicates");
    }

    #[test]
    fn v_filter_shifts_selection_toward_valid() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(2);
        let e = Explorer::new(0.0);
        let with_v = e.select(&space, &p, Some(&v), 30, &mut rng);
        let without_v = e.select(&space, &p, None, 30, &mut rng);
        let count_pred_valid = |picks: &[usize]| {
            picks
                .iter()
                .filter(|&&i| {
                    v.predict_valid(&space.visible(i),
                                    crate::tuner::DEFAULT_V_MARGIN)
                })
                .count()
        };
        assert!(count_pred_valid(&with_v) >= count_pred_valid(&without_v));
        assert_eq!(count_pred_valid(&with_v), 30);
    }

    #[test]
    fn respects_measured_mask() {
        let (mut space, p, v) = trained_models();
        let mut rng = Rng::new(3);
        let e = Explorer::new(0.1);
        let first = e.select(&space, &p, Some(&v), 10, &mut rng);
        for &i in &first {
            space.mark_measured(i);
        }
        let second = e.select(&space, &p, Some(&v), 10, &mut rng);
        for i in &second {
            assert!(!first.contains(i), "re-proposed measured config");
        }
    }

    #[test]
    fn extreme_margin_vetoes_everything_but_fallback_fills() {
        // v_margin above the hinge range vetoes every candidate; the
        // explorer must still make progress via the skipped-best
        // fallback, in P-ranking order
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(9);
        let veto_all = Explorer::new(0.0).with_v_margin(2.0);
        let picks = veto_all.select(&space, &p, Some(&v), 10, &mut rng);
        assert_eq!(picks.len(), 10);
        // an accept-all margin shares the exact same P/V ranking, so the
        // all-vetoed fallback must reproduce its best-first picks
        let mut rng2 = Rng::new(9);
        let accept_all = Explorer::new(0.0).with_v_margin(-2.0);
        let loose = accept_all.select(&space, &p, Some(&v), 10, &mut rng2);
        assert_eq!(picks, loose,
                   "all-vetoed fallback must degrade to the ranking head");
    }

    #[test]
    fn epsilon_one_is_fully_random_but_valid_count() {
        let (space, p, v) = trained_models();
        let mut rng = Rng::new(4);
        let e = Explorer::new(1.0);
        let picks = e.select(&space, &p, Some(&v), 15, &mut rng);
        assert_eq!(picks.len(), 15);
    }
}
