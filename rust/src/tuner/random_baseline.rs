//! Random-sampling baseline — the paper's "preliminary experiment" column
//! (Table 2b: random sampling on conv1 gives 0.926 invalidity on the
//! authors' board).

use super::report::TuningTrace;
use super::{salt, Tuner, TunerConfig, TuningEnv};
use crate::engine::Engine;
use crate::obs::Stage;
use crate::util::rng::Rng;

/// Uniform random search over the unmeasured space.
pub struct RandomTuner {
    /// Tuning-loop knobs.
    pub cfg: TunerConfig,
}

impl RandomTuner {
    /// Baseline over the given knobs.
    pub fn new(cfg: TunerConfig) -> Self {
        RandomTuner { cfg }
    }
}

impl Tuner for RandomTuner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn tune_with(
        &mut self,
        env: &TuningEnv,
        engine: &Engine,
    ) -> TuningTrace {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed ^ salt::RANDOM);
        let mut space = env.space.clone();
        let mut trace = TuningTrace::new(env.layer.name, self.name());
        let mut round = 0u64;
        while trace.len() < cfg.max_trials && space.n_unmeasured() > 0 {
            round += 1;
            let scope = engine.recorder().begin_round();
            let before = trace.len();
            let n = cfg.n_per_round.min(cfg.max_trials - trace.len());
            let batch = {
                let _select = engine.recorder().span(Stage::Select);
                space.sample_unmeasured(&mut rng, n)
            };
            engine.profile_into(env, &batch, &mut space, None, &mut trace);
            engine.recorder().end_round(scope, || {
                super::round_event(env, &trace, before, round,
                                   cfg.v_margin, None)
            });
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    #[test]
    fn samples_without_replacement() {
        let env = TuningEnv::new(VtaConfig::zcu102(),
                                 resnet18::layer("conv5").unwrap());
        let cfg = TunerConfig { max_trials: 100, seed: 3,
                                ..Default::default() };
        let trace = RandomTuner::new(cfg).tune(&env);
        assert_eq!(trace.len(), 100);
        let mut idx: Vec<usize> =
            trace.trials.iter().map(|t| t.space_index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 100);
    }

    #[test]
    fn exhausts_small_budgets() {
        let env = TuningEnv::new(VtaConfig::zcu102(),
                                 resnet18::layer("conv5").unwrap());
        let cfg = TunerConfig { max_trials: 7, n_per_round: 10,
                                ..Default::default() };
        let trace = RandomTuner::new(cfg).tune(&env);
        assert_eq!(trace.len(), 7);
    }
}
