//! The ML²Tuner loop (paper §2, Fig. 1).
//!
//! Per iteration:
//! 1. train **P** on the valid records and **V** on all records;
//! 2. explorer accumulates `(α+1)·N` candidates — P-ranked, V-filtered,
//!    ε-greedy;
//! 3. compile all of them, extract hidden features;
//! 4. train **A** (visible ⊕ hidden) and keep the `N` best re-ranked
//!    candidates;
//! 5. profile them; outcomes train V, execution times train P/A.
//!
//! Ablation switches (`use_v`, `use_a`) expose the paper's design levers:
//! `use_v=false, use_a=false` degenerates to the TVM approach with a
//! valid-only P (an intermediate the ablation bench reports).

use super::database::Database;
use super::explorer::Explorer;
use super::models::{ModelA, ModelP, ModelV};
use super::report::TuningTrace;
use super::{Tuner, TunerConfig, TuningEnv};
use crate::compiler::features::combined_features;
use crate::util::rng::Rng;

/// The multi-level tuner.
pub struct Ml2Tuner {
    pub cfg: TunerConfig,
    /// Ablation: apply the validity filter (model V).
    pub use_v: bool,
    /// Ablation: apply hidden-feature re-ranking (model A).
    pub use_a: bool,
}

impl Ml2Tuner {
    pub fn new(cfg: TunerConfig) -> Self {
        Ml2Tuner { cfg, use_v: true, use_a: true }
    }

    pub fn without_v(mut self) -> Self {
        self.use_v = false;
        self
    }

    pub fn without_a(mut self) -> Self {
        self.use_a = false;
        self
    }
}

impl Tuner for Ml2Tuner {
    fn name(&self) -> &'static str {
        match (self.use_v, self.use_a) {
            (true, true) => "ml2tuner",
            (false, true) => "ml2tuner-noV",
            (true, false) => "ml2tuner-noA",
            (false, false) => "ml2tuner-Ponly",
        }
    }

    fn tune(&mut self, env: &TuningEnv) -> TuningTrace {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed ^ 0x4d4c_3254);
        let mut space = env.space.clone();
        let mut db = Database::new(env.layer.name);
        let mut trace = TuningTrace::new(env.layer.name, self.name());
        let explorer = Explorer::new(cfg.epsilon);
        let mut round = 0u64;
        while trace.len() < cfg.max_trials && space.n_unmeasured() > 0 {
            round += 1;
            let remaining = cfg.max_trials - trace.len();
            let n = cfg.n_per_round.min(remaining);
            // ---- candidate selection -----------------------------------
            let models_ready = db.n_valid() >= 2
                && db.len() >= cfg.min_train
                && ModelP::train(&db, 1, 0).is_some();
            let batch: Vec<usize> = if !models_ready {
                space.sample_unmeasured(&mut rng, n)
            } else {
                let p = ModelP::train(&db, cfg.boost_rounds,
                                      cfg.seed ^ round)
                    .expect("P trainable");
                let v = if self.use_v {
                    ModelV::train(&db, cfg.boost_rounds, cfg.seed ^ round)
                } else {
                    None
                };
                let pool_n = if self.use_a { cfg.pool_size() } else { n };
                let pool = explorer.select(&space, &p, v.as_ref(), pool_n,
                                           &mut rng);
                if self.use_a && pool.len() > n {
                    // compile everything, harvest hidden features, re-rank
                    let a = ModelA::train(&db, cfg.boost_rounds,
                                          cfg.seed ^ round);
                    match a {
                        None => pool.into_iter().take(n).collect(),
                        Some(a) => {
                            let mut scored: Vec<(f64, usize)> = pool
                                .into_iter()
                                .map(|i| {
                                    let sched = space.schedule(i);
                                    let compiled = env
                                        .compiler
                                        .compile(&env.layer, &sched);
                                    let hidden = env
                                        .compiler
                                        .hidden_features(&compiled);
                                    let feats = combined_features(
                                        &sched.visible_features(),
                                        &hidden,
                                    );
                                    (a.predict(&feats), i)
                                })
                                .collect();
                            scored.sort_by(|x, y| {
                                x.0.partial_cmp(&y.0).unwrap()
                            });
                            scored
                                .into_iter()
                                .take(n)
                                .map(|(_, i)| i)
                                .collect()
                        }
                    }
                } else {
                    pool.into_iter().take(n).collect()
                }
            };
            if batch.is_empty() {
                break;
            }
            // ---- profiling & training data ----------------------------
            for idx in batch {
                let rec = env.profile(idx);
                space.mark_measured(idx);
                db.push(rec.clone());
                trace.trials.push(rec);
                if trace.len() >= cfg.max_trials {
                    break;
                }
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn env() -> TuningEnv {
        TuningEnv::new(VtaConfig::zcu102(),
                       resnet18::layer("conv5").unwrap())
    }

    #[test]
    fn respects_budget_and_no_duplicates() {
        let cfg = TunerConfig { max_trials: 60, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> =
            trace.trials.iter().map(|t| t.space_index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "no config profiled twice");
    }

    #[test]
    fn finds_a_valid_config() {
        let cfg = TunerConfig { max_trials: 80, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert!(trace.best_cycles().is_some());
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TunerConfig { max_trials: 40, seed: 7,
                                ..Default::default() };
        let a = Ml2Tuner::new(cfg.clone()).tune(&env());
        let b = Ml2Tuner::new(cfg).tune(&env());
        let ai: Vec<usize> = a.trials.iter().map(|t| t.space_index).collect();
        let bi: Vec<usize> = b.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(ai, bi);
    }

    #[test]
    fn ablation_names() {
        let cfg = TunerConfig::default();
        assert_eq!(Ml2Tuner::new(cfg.clone()).name(), "ml2tuner");
        assert_eq!(Ml2Tuner::new(cfg.clone()).without_v().name(),
                   "ml2tuner-noV");
        assert_eq!(Ml2Tuner::new(cfg).without_v().without_a().name(),
                   "ml2tuner-Ponly");
    }
}
