//! The ML²Tuner loop (paper §2, Fig. 1).
//!
//! Per iteration:
//! 1. train **P** on the valid records and **V** on all records;
//! 2. explorer accumulates `(α+1)·N` candidates — P-ranked, V-filtered,
//!    ε-greedy;
//! 3. compile all of them, extract hidden features;
//! 4. train **A** (visible ⊕ hidden) and keep the `N` best re-ranked
//!    candidates;
//! 5. profile them; outcomes train V, execution times train P/A.
//!
//! Ablation switches (`use_v`, `use_a`) expose the paper's design levers:
//! `use_v=false, use_a=false` degenerates to the TVM approach with a
//! valid-only P (an intermediate the ablation bench reports).

use std::collections::HashMap;

use super::database::{Database, Fidelity, Outcome, TrialRecord};
use super::explorer::{Explorer, SelectStats};
use super::meta::MetaArtifact;
use super::models::{FitOpts, ModelA, ModelP, ModelV};
use super::report::TuningTrace;
use super::space::SearchSpace;
use super::train::{Provenance, TrainSet};
use super::{salt, Tuner, TunerConfig, TuningEnv};
use crate::engine::Engine;
use crate::gbdt::{Booster, FeatureMatrix};
use crate::obs::{Counter, Stage};
use crate::util::rng::Rng;
use crate::vta::coarse::CoarseEstimate;

/// Cross-round model carry-over: the last trained ensemble per model
/// plus the training-set row count it saw. Incremental mode
/// (`cfg.incremental`) continues these instead of refitting from
/// scratch; a caller that passes `None` gets the stateless (cold or
/// meta-refit) behaviour every round.
#[derive(Default)]
pub(crate) struct ModelState {
    /// Model P's last ensemble and its training row count.
    pub p: Option<(Booster, usize)>,
    /// Model V's last ensemble and its training row count.
    pub v: Option<(Booster, usize)>,
    /// Model A's last ensemble and its training row count.
    pub a: Option<(Booster, usize)>,
}

/// How one model trains this round: from which base, how many rounds,
/// and whether the meta level-recalibration applies.
struct FitPlan<'a> {
    base: Option<&'a Booster>,
    rounds: usize,
    recalibrate: bool,
    from_meta: bool,
}

/// Decide the round's training mode for one model.
///
/// Priority: continue the previous round's ensemble (incremental mode,
/// record set grew or held) → adapt the meta base (recalibrated) → cold
/// full fit. `--retrain-every R` forces the cold branch every `R`
/// rounds to bound drift from stale early trees; a meta base whose
/// feature width does not match this run's layout is ignored.
fn plan_fit<'a>(
    cfg: &TunerConfig,
    round: u64,
    prev: Option<&'a (Booster, usize)>,
    meta: Option<&'a Booster>,
    set_len: usize,
    width: usize,
) -> FitPlan<'a> {
    let meta = meta.filter(|b| b.n_features == width);
    let full_refit = !cfg.incremental
        || (cfg.retrain_every > 0
            && round % cfg.retrain_every as u64 == 0);
    if !full_refit {
        if let Some((b, rows)) = prev {
            if set_len >= *rows {
                return FitPlan {
                    base: Some(b),
                    rounds: (cfg.boost_rounds / 10).max(4),
                    recalibrate: false,
                    from_meta: false,
                };
            }
        }
    }
    if let Some(m) = meta {
        return FitPlan {
            base: Some(m),
            rounds: (cfg.boost_rounds / 5).max(8),
            recalibrate: true,
            from_meta: true,
        };
    }
    FitPlan {
        base: None,
        rounds: cfg.boost_rounds,
        recalibrate: false,
        from_meta: false,
    }
}

/// The multi-level tuner.
pub struct Ml2Tuner {
    /// Tuning-loop knobs.
    pub cfg: TunerConfig,
    /// Ablation: apply the validity filter (model V).
    pub use_v: bool,
    /// Ablation: apply hidden-feature re-ranking (model A).
    pub use_a: bool,
    /// Transferred records (see
    /// [`crate::tuner::database::TransferDb::warm_start_for`]) that
    /// pre-train P/V/A before the first profiled batch. Training-only:
    /// they never count against the budget or enter the trace.
    pub warm: Option<Database>,
    /// Corpus-trained base ensembles (see [`crate::tuner::meta`]) the
    /// per-round fits adapt instead of starting cold.
    pub meta: Option<MetaArtifact>,
}

impl Ml2Tuner {
    /// Full three-model tuner (V and A enabled, cold start).
    pub fn new(cfg: TunerConfig) -> Self {
        Ml2Tuner { cfg, use_v: true, use_a: true, warm: None,
                   meta: None }
    }

    /// Ablation: disable the model-V validity filter.
    pub fn without_v(mut self) -> Self {
        self.use_v = false;
        self
    }

    /// Ablation: disable the model-A re-ranking stage.
    pub fn without_a(mut self) -> Self {
        self.use_a = false;
        self
    }

    /// Warm-start the models from a transferred database. An empty
    /// database is a no-op (the run stays cold, named "ml2tuner"), so
    /// traces never claim a warm start that contributed nothing.
    pub fn with_warm_start(mut self, warm: Database) -> Self {
        if !warm.is_empty() {
            self.warm = Some(warm);
        }
        self
    }

    /// Adapt from a corpus-trained meta artifact (`--meta`). The
    /// artifact's space kind must match the run's — a mismatched
    /// artifact would feed the models the wrong feature layout, so the
    /// builder ignores it (the CLI resolves per-kind artifacts before
    /// getting here).
    pub fn with_meta(mut self, meta: MetaArtifact) -> Self {
        self.meta = Some(meta);
        self
    }
}

impl Tuner for Ml2Tuner {
    fn name(&self) -> &'static str {
        // warm-started / meta-adapted variants carry suffixes so
        // persisted traces always distinguish the run modes
        match (self.use_v, self.use_a, self.warm.is_some(),
               self.meta.is_some())
        {
            (true, true, false, false) => "ml2tuner",
            (false, true, false, false) => "ml2tuner-noV",
            (true, false, false, false) => "ml2tuner-noA",
            (false, false, false, false) => "ml2tuner-Ponly",
            (true, true, true, false) => "ml2tuner-warm",
            (false, true, true, false) => "ml2tuner-noV-warm",
            (true, false, true, false) => "ml2tuner-noA-warm",
            (false, false, true, false) => "ml2tuner-Ponly-warm",
            (true, true, false, true) => "ml2tuner-meta",
            (false, true, false, true) => "ml2tuner-noV-meta",
            (true, false, false, true) => "ml2tuner-noA-meta",
            (false, false, false, true) => "ml2tuner-Ponly-meta",
            (true, true, true, true) => "ml2tuner-warm-meta",
            (false, true, true, true) => "ml2tuner-noV-warm-meta",
            (true, false, true, true) => "ml2tuner-noA-warm-meta",
            (false, false, true, true) => "ml2tuner-Ponly-warm-meta",
        }
    }

    fn tune_with(
        &mut self,
        env: &TuningEnv,
        engine: &Engine,
    ) -> TuningTrace {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed ^ salt::ML2);
        let mut space = env.space.clone();
        let mut db =
            Database::for_layer_on(&env.layer, env.kind(), env.hw());
        let mut trace = TuningTrace::new(env.layer.name, self.name());
        let mut round = 0u64;
        let mut mstate = ModelState::default();
        while trace.len() < cfg.max_trials && space.n_unmeasured() > 0 {
            round += 1;
            let scope = engine.recorder().begin_round();
            let before = trace.len();
            let n = cfg.n_per_round.min(cfg.max_trials - trace.len());
            let (batch, stats, coarse) =
                select_batch(cfg, self.use_v, self.use_a, env, engine,
                             &space, &db, self.warm.as_ref(),
                             self.meta.as_ref(), Some(&mut mstate),
                             &mut rng, round, n);
            // tier-0 estimates of pruned candidates train the models
            // (down-weighted) but never touch the trace or the budget
            for c in coarse {
                db.push(c);
            }
            if batch.is_empty() {
                break;
            }
            // ---- profiling & training data ----------------------------
            // `batch.len() ≤ n ≤ remaining budget`, and the executor
            // returns records in batch order — the trace is identical for
            // any worker count.
            engine.profile_into(env, &batch, &mut space, Some(&mut db),
                                &mut trace);
            engine.recorder().end_round(scope, || {
                super::round_event(env, &trace, before, round,
                                   cfg.v_margin, stats)
            });
        }
        trace
    }
}

/// One round of ML²Tuner candidate selection (paper Fig. 1 steps 1–4):
/// train P (and V), accumulate the `(α+1)·N` pool, compile it through
/// the engine for hidden features, train A, and keep the `n` best
/// re-ranked candidates. Shared by [`Ml2Tuner`] and the network
/// scheduler's incremental [`crate::engine::LayerSession`].
///
/// When a `warm` database is given, its transferred records are merged
/// into every training set (warm rows first) and count toward the
/// `min_train` readiness gate — so a warm-started run is model-guided
/// from its very first batch instead of burning `min_train` random
/// trials. With `warm = None` the behaviour is byte-identical to the
/// cold tuner.
///
/// Besides the batch, returns the round's [`SelectStats`] (V veto count
/// + the picked candidates' V margins, re-aligned through the A
/// re-ranking) when model V actually filtered this round — the raw
/// material for the per-round precision/recall telemetry. `None` on the
/// model-not-ready fallback and on V-less rounds.
///
/// With `cfg.prescreen_factor ≥ 2` the explorer over-selects a
/// `factor×` pool, the tier-0 coarse estimator ranks it
/// ([`Engine::prescreen_into`]), and only the best statically-plausible
/// candidates proceed to the A-stage and profiling. The third return
/// value carries [`Fidelity::Coarse`] records for the pruned candidates
/// — the caller pushes them into its database (training signal) but
/// never into the trace or the budget. With the factor off it is always
/// empty and the selection path is structurally unchanged.
///
/// `meta` supplies corpus-trained base ensembles: the P readiness gate
/// widens to "meta P available", so a meta run is model-guided from
/// round 1, and each fit adapts the base (recalibrated continuation)
/// instead of training cold. `state` carries the previous round's
/// ensembles for `cfg.incremental` warm continuation; each fit updates
/// it. Both default the pre-meta behaviour when `None`/absent.
#[allow(clippy::too_many_arguments)]
pub(crate) fn select_batch(
    cfg: &TunerConfig,
    use_v: bool,
    use_a: bool,
    env: &TuningEnv,
    engine: &Engine,
    space: &SearchSpace,
    db: &Database,
    warm: Option<&Database>,
    meta: Option<&MetaArtifact>,
    mut state: Option<&mut ModelState>,
    rng: &mut Rng,
    round: u64,
    n: usize,
) -> (Vec<usize>, Option<SelectStats>, Vec<TrialRecord>) {
    let rec = engine.recorder();
    let _select = rec.span(Stage::Select);
    let warm = warm.filter(|w| !w.is_empty());
    let n_valid = db.n_valid() + warm.map_or(0, Database::n_valid);
    let n_seen = db.len() + warm.map_or(0, Database::len);
    // Train P once and reuse it (the readiness probe used to train a
    // throwaway model first); P is trainable iff ≥ 2 valid records —
    // or from round 1 when a meta base covers the gap.
    let meta_p = meta.and_then(|m| m.p.as_ref());
    let p = if (n_valid >= 2 && n_seen >= cfg.min_train)
        || meta_p.is_some()
    {
        let _train = rec.span(Stage::Train);
        let mut set = TrainSet::new();
        if let Some(w) = warm {
            set.extend_p(w, Provenance::Warm);
        }
        set.extend_p(db, Provenance::Cold);
        let prev = state.as_mut().and_then(|s| s.p.take());
        let plan = plan_fit(cfg, round, prev.as_ref(), meta_p,
                            set.len(), space.n_visible());
        let base_trees =
            plan.base.map_or(0, |b| b.trees.len());
        let opts = FitOpts {
            rounds: plan.rounds,
            seed: cfg.seed ^ round,
            base: plan.base,
            recalibrate: plan.recalibrate,
        };
        let model = ModelP::fit(&set, &opts);
        if let Some(m) = &model {
            if plan.base.is_some() {
                rec.add(Counter::TreesAppended,
                        m.booster.trees.len()
                            .saturating_sub(base_trees)
                            as u64);
                if plan.from_meta {
                    rec.add(Counter::MetaAdapted, 1);
                }
            }
            if let Some(s) = state.as_mut() {
                s.p = Some((m.booster.clone(), set.len()));
            }
        }
        model
    } else {
        None
    };
    let factor = cfg.prescreen_factor;
    let Some(p) = p else {
        // random warmup: with prescreen on, over-sample and keep the
        // tier-0 survivors so even the cold rounds skip doomed configs
        if factor >= 2 {
            let cand =
                space.sample_unmeasured(rng, n.saturating_mul(factor));
            if cand.len() > n {
                let mut coarse = Vec::new();
                let keep = prescreen_survivors(engine, env, space, &cand,
                                               n, &mut coarse);
                return (keep, None, coarse);
            }
            return (cand, None, Vec::new());
        }
        return (space.sample_unmeasured(rng, n), None, Vec::new());
    };
    let v = if use_v {
        let _train = rec.span(Stage::Train);
        let mut set = TrainSet::new();
        if let Some(w) = warm {
            set.extend_v(w, Provenance::Warm);
        }
        set.extend_v(db, Provenance::Cold);
        // the V bucket is capacity-exact (see `tuner::meta`): unseen
        // hardware simply gets no meta V
        let meta_v = meta.and_then(|m| m.v_for(env.hw()));
        let prev = state.as_mut().and_then(|s| s.v.take());
        let plan = plan_fit(cfg, round, prev.as_ref(), meta_v,
                            set.len(), space.n_visible());
        let base_trees = plan.base.map_or(0, |b| b.trees.len());
        let opts = FitOpts {
            rounds: plan.rounds,
            seed: cfg.seed ^ round,
            base: plan.base,
            // level recalibration is a perf-regressor correction; V's
            // hinge margin has no "level" to shift
            recalibrate: false,
        };
        let model = ModelV::fit(&set, &opts);
        if let Some(m) = &model {
            if plan.base.is_some() {
                rec.add(Counter::TreesAppended,
                        m.booster.trees.len()
                            .saturating_sub(base_trees)
                            as u64);
                if plan.from_meta {
                    rec.add(Counter::MetaAdapted, 1);
                }
            }
            if let Some(s) = state.as_mut() {
                s.v = Some((m.booster.clone(), set.len()));
            }
        }
        model
    } else {
        None
    };
    let pool_n = if use_a { cfg.pool_size() } else { n };
    // over-select a factor× pool for the tier-0 cut; the A-stage then
    // compiles only pool_n survivors, so compile cost never grows with
    // the factor
    let want = if factor >= 2 {
        pool_n.saturating_mul(factor)
    } else {
        pool_n
    };
    let (pool, pool_stats) = Explorer::new(cfg.epsilon)
        .with_v_margin(cfg.v_margin)
        .with_jobs(engine.jobs())
        .with_recorder(rec)
        .select_with_stats(space, &p, v.as_ref(), want, rng);
    let mut coarse: Vec<TrialRecord> = Vec::new();
    let ranked: Vec<usize> = if factor >= 2 && pool.len() > pool_n {
        prescreen_survivors(engine, env, space, &pool, pool_n,
                            &mut coarse)
    } else {
        pool.clone()
    };
    let batch: Vec<usize> = if use_a && ranked.len() > n {
        // Compile the whole pool (batched, cached), harvest hidden
        // features, re-rank with A. The engine's cache means the `n`
        // winners are NOT recompiled when profiled right after.
        let a = {
            let _train = rec.span(Stage::Train);
            let mut set = TrainSet::new();
            if let Some(w) = warm {
                set.extend_a(w, Provenance::Warm);
            }
            set.extend_a(db, Provenance::Cold);
            let meta_a = meta.and_then(|m| m.a.as_ref());
            let prev = state.as_mut().and_then(|s| s.a.take());
            let width = space.n_visible()
                + crate::compiler::features::hidden_len(env.kind());
            let plan = plan_fit(cfg, round, prev.as_ref(), meta_a,
                                set.len(), width);
            let base_trees = plan.base.map_or(0, |b| b.trees.len());
            let opts = FitOpts {
                rounds: plan.rounds,
                seed: cfg.seed ^ round,
                base: plan.base,
                recalibrate: plan.recalibrate,
            };
            let model = ModelA::fit(&set, &opts);
            if let Some(m) = &model {
                if plan.base.is_some() {
                    rec.add(Counter::TreesAppended,
                            m.booster.trees.len()
                                .saturating_sub(base_trees)
                                as u64);
                    if plan.from_meta {
                        rec.add(Counter::MetaAdapted, 1);
                    }
                }
                if let Some(s) = state.as_mut() {
                    s.a = Some((m.booster.clone(), set.len()));
                }
            }
            model
        };
        match a {
            None => ranked.iter().copied().take(n).collect(),
            Some(a) => {
                let compiled = engine.compile_batch(env, &ranked);
                // one reused buffer + one matrix for the whole pool:
                // each row is visible ⊕ hidden, exactly what
                // `combined_features` used to allocate per candidate
                let width = space.n_visible()
                    + compiled.first().map_or(0, |c| c.hidden.len());
                let mut feats: Vec<f64> = Vec::with_capacity(width);
                let mut m =
                    FeatureMatrix::with_capacity(width, ranked.len());
                for (&i, c) in ranked.iter().zip(&compiled) {
                    space.visible_into(i, &mut feats);
                    feats.extend_from_slice(&c.hidden);
                    m.push_row_f64(&feats);
                }
                let mut scores = Vec::with_capacity(ranked.len());
                a.predict_batch_into(&m, &mut scores);
                let mut scored: Vec<(f64, usize)> = scores
                    .into_iter()
                    .zip(ranked.iter().copied())
                    .collect();
                // stable sort: ties keep pool (P-ranking) order
                scored.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
                scored.into_iter().take(n).map(|(_, i)| i).collect()
            }
        }
    } else {
        ranked.iter().copied().take(n).collect()
    };
    // Re-align the explorer's pool-order margins to the final batch so
    // the round event can confront V's verdict with each profiled
    // outcome (pure bookkeeping — no effect on the batch itself).
    let stats = match (v.is_some(), pool_stats) {
        (true, Some(s)) => {
            let by_idx: HashMap<usize, f64> =
                pool.iter().copied().zip(s.margins).collect();
            Some(SelectStats {
                vetoes: s.vetoes,
                margins: batch
                    .iter()
                    .map(|i| by_idx.get(i).copied().unwrap_or(0.0))
                    .collect(),
            })
        }
        _ => None,
    };
    (batch, stats, coarse)
}

/// Rank `pool` with the tier-0 coarse estimator and keep the best
/// `keep` statically-plausible candidates, ordered by estimate (ties by
/// pool position, so the cut is deterministic and `--jobs`-invariant).
/// A Hopeless verdict can never survive. Pruned candidates are appended
/// to `coarse` as [`Fidelity::Coarse`] records: Hopeless prunes become
/// `Crash` labels for model V, finite estimates become down-weighted
/// `Valid` labels for model P.
///
/// Edge case: if *nothing* in the pool is statically plausible the
/// unfiltered prefix is returned instead, so the round still spends its
/// budget and the (certain-to-crash) profiles feed V full-fidelity
/// negatives.
fn prescreen_survivors(
    engine: &Engine,
    env: &TuningEnv,
    space: &SearchSpace,
    pool: &[usize],
    keep: usize,
    coarse: &mut Vec<TrialRecord>,
) -> Vec<usize> {
    let mut est: Vec<CoarseEstimate> = Vec::with_capacity(pool.len());
    engine.prescreen_into(env, pool, &mut est);
    let mut order: Vec<usize> = (0..pool.len()).collect();
    order.sort_by_key(|&k| (est[k].rank_key(), k));
    let mut kept = vec![false; pool.len()];
    let mut survivors = Vec::with_capacity(keep);
    for &k in &order {
        if survivors.len() >= keep || est[k].is_hopeless() {
            break; // Hopeless sorts last: nothing after it is plausible
        }
        kept[k] = true;
        survivors.push(pool[k]);
    }
    if survivors.is_empty() {
        return pool.iter().copied().take(keep).collect();
    }
    engine
        .recorder()
        .add(Counter::PrescreenSurvivors, survivors.len() as u64);
    for (k, &i) in pool.iter().enumerate() {
        if kept[k] {
            continue;
        }
        coarse.push(TrialRecord {
            space_index: i,
            schedule: space.schedule(i),
            visible: space.visible(i),
            hidden: vec![],
            outcome: match est[k] {
                CoarseEstimate::Hopeless => Outcome::Crash,
                CoarseEstimate::Cycles(c) => Outcome::Valid { cycles: c },
            },
            fidelity: Fidelity::Coarse,
        });
    }
    survivors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn env() -> TuningEnv {
        TuningEnv::new(VtaConfig::zcu102(),
                       resnet18::layer("conv5").unwrap())
    }

    #[test]
    fn respects_budget_and_no_duplicates() {
        let cfg = TunerConfig { max_trials: 60, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> =
            trace.trials.iter().map(|t| t.space_index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "no config profiled twice");
    }

    #[test]
    fn finds_a_valid_config() {
        let cfg = TunerConfig { max_trials: 80, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert!(trace.best_cycles().is_some());
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TunerConfig { max_trials: 40, seed: 7,
                                ..Default::default() };
        let a = Ml2Tuner::new(cfg.clone()).tune(&env());
        let b = Ml2Tuner::new(cfg).tune(&env());
        let ai: Vec<usize> = a.trials.iter().map(|t| t.space_index).collect();
        let bi: Vec<usize> = b.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(ai, bi);
    }

    #[test]
    fn ablation_names() {
        let cfg = TunerConfig::default();
        assert_eq!(Ml2Tuner::new(cfg.clone()).name(), "ml2tuner");
        assert_eq!(Ml2Tuner::new(cfg.clone()).without_v().name(),
                   "ml2tuner-noV");
        assert_eq!(Ml2Tuner::new(cfg.clone()).without_v().without_a().name(),
                   "ml2tuner-Ponly");
        // an empty warm database is a no-op: the run stays cold
        assert_eq!(
            Ml2Tuner::new(cfg.clone())
                .with_warm_start(Database::new("x"))
                .name(),
            "ml2tuner"
        );
        let s = crate::compiler::schedule::Schedule::default();
        let mut warm = Database::new("x");
        warm.push(TrialRecord {
            space_index: 0,
            schedule: s,
            visible: crate::compiler::schedule::SpaceKind::Paper
                .visible_features(&s),
            hidden: vec![],
            outcome: Outcome::Crash,
            fidelity: Fidelity::Full,
        });
        assert_eq!(Ml2Tuner::new(cfg).with_warm_start(warm).name(),
                   "ml2tuner-warm");
    }

    #[test]
    fn prescreen_runs_are_deterministic_and_respect_budget() {
        let cfg = TunerConfig { max_trials: 40, seed: 3,
                                prescreen_factor: 4,
                                ..Default::default() };
        let a = Ml2Tuner::new(cfg.clone()).tune(&env());
        let b = Ml2Tuner::new(cfg).tune(&env());
        assert_eq!(a.len(), 40, "prescreen must not eat the budget");
        let ai: Vec<usize> =
            a.trials.iter().map(|t| t.space_index).collect();
        let bi: Vec<usize> =
            b.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(ai, bi, "prescreen runs are deterministic per seed");
        let mut idx = ai.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 40, "no config profiled twice");
        // every trial in the trace is full-fidelity
        assert!(a.trials.iter().all(|t| t.fidelity == Fidelity::Full));
    }

    #[test]
    fn warm_start_runs_are_deterministic_and_respect_budget() {
        use crate::tuner::database::TransferDb;
        let e = env();
        // source log: a spread of profiled conv5 configurations
        let mut src = Database::for_layer(&e.layer);
        for i in 0..60 {
            src.push(e.profile(i * 37));
        }
        let mut store = TransferDb::new();
        store.add(src);
        let warm = store
            .warm_start_for(&e.layer,
                            crate::compiler::schedule::SpaceKind::Paper,
                            e.hw(), 100)
            .unwrap();
        let cfg = TunerConfig { max_trials: 30, seed: 3,
                                ..Default::default() };
        let a = Ml2Tuner::new(cfg.clone())
            .with_warm_start(warm.clone())
            .tune(&e);
        let b = Ml2Tuner::new(cfg).with_warm_start(warm).tune(&e);
        assert_eq!(a.tuner, "ml2tuner-warm");
        assert_eq!(a.len(), 30);
        let mut idx: Vec<usize> =
            a.trials.iter().map(|t| t.space_index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 30, "warm records must not be re-profiled \
                                   bookkeeping-wise");
        let ai: Vec<usize> = a.trials.iter().map(|t| t.space_index).collect();
        let bi: Vec<usize> = b.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(ai, bi, "warm-started runs are deterministic per seed");
    }
}
