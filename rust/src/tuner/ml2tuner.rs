//! The ML²Tuner loop (paper §2, Fig. 1).
//!
//! Per iteration:
//! 1. train **P** on the valid records and **V** on all records;
//! 2. explorer accumulates `(α+1)·N` candidates — P-ranked, V-filtered,
//!    ε-greedy;
//! 3. compile all of them, extract hidden features;
//! 4. train **A** (visible ⊕ hidden) and keep the `N` best re-ranked
//!    candidates;
//! 5. profile them; outcomes train V, execution times train P/A.
//!
//! Ablation switches (`use_v`, `use_a`) expose the paper's design levers:
//! `use_v=false, use_a=false` degenerates to the TVM approach with a
//! valid-only P (an intermediate the ablation bench reports).

use super::database::Database;
use super::explorer::Explorer;
use super::models::{ModelA, ModelP, ModelV};
use super::report::TuningTrace;
use super::space::SearchSpace;
use super::{salt, Tuner, TunerConfig, TuningEnv};
use crate::compiler::features::combined_features;
use crate::engine::Engine;
use crate::util::rng::Rng;

/// The multi-level tuner.
pub struct Ml2Tuner {
    pub cfg: TunerConfig,
    /// Ablation: apply the validity filter (model V).
    pub use_v: bool,
    /// Ablation: apply hidden-feature re-ranking (model A).
    pub use_a: bool,
}

impl Ml2Tuner {
    pub fn new(cfg: TunerConfig) -> Self {
        Ml2Tuner { cfg, use_v: true, use_a: true }
    }

    pub fn without_v(mut self) -> Self {
        self.use_v = false;
        self
    }

    pub fn without_a(mut self) -> Self {
        self.use_a = false;
        self
    }
}

impl Tuner for Ml2Tuner {
    fn name(&self) -> &'static str {
        match (self.use_v, self.use_a) {
            (true, true) => "ml2tuner",
            (false, true) => "ml2tuner-noV",
            (true, false) => "ml2tuner-noA",
            (false, false) => "ml2tuner-Ponly",
        }
    }

    fn tune_with(
        &mut self,
        env: &TuningEnv,
        engine: &Engine,
    ) -> TuningTrace {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed ^ salt::ML2);
        let mut space = env.space.clone();
        let mut db = Database::new(env.layer.name);
        let mut trace = TuningTrace::new(env.layer.name, self.name());
        let mut round = 0u64;
        while trace.len() < cfg.max_trials && space.n_unmeasured() > 0 {
            round += 1;
            let n = cfg.n_per_round.min(cfg.max_trials - trace.len());
            let batch = select_batch(cfg, self.use_v, self.use_a, env,
                                     engine, &space, &db, &mut rng, round,
                                     n);
            if batch.is_empty() {
                break;
            }
            // ---- profiling & training data ----------------------------
            // `batch.len() ≤ n ≤ remaining budget`, and the executor
            // returns records in batch order — the trace is identical for
            // any worker count.
            engine.profile_into(env, &batch, &mut space, Some(&mut db),
                                &mut trace);
        }
        trace
    }
}

/// One round of ML²Tuner candidate selection (paper Fig. 1 steps 1–4):
/// train P (and V), accumulate the `(α+1)·N` pool, compile it through
/// the engine for hidden features, train A, and keep the `n` best
/// re-ranked candidates. Shared by [`Ml2Tuner`] and the network
/// scheduler's incremental [`crate::engine::LayerSession`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn select_batch(
    cfg: &TunerConfig,
    use_v: bool,
    use_a: bool,
    env: &TuningEnv,
    engine: &Engine,
    space: &SearchSpace,
    db: &Database,
    rng: &mut Rng,
    round: u64,
    n: usize,
) -> Vec<usize> {
    // Train P once and reuse it (the readiness probe used to train a
    // throwaway model first); P is trainable iff ≥ 2 valid records.
    let p = if db.n_valid() >= 2 && db.len() >= cfg.min_train {
        ModelP::train(db, cfg.boost_rounds, cfg.seed ^ round)
    } else {
        None
    };
    let Some(p) = p else {
        return space.sample_unmeasured(rng, n);
    };
    let v = if use_v {
        ModelV::train(db, cfg.boost_rounds, cfg.seed ^ round)
    } else {
        None
    };
    let pool_n = if use_a { cfg.pool_size() } else { n };
    let pool =
        Explorer::new(cfg.epsilon).select(space, &p, v.as_ref(), pool_n,
                                          rng);
    if use_a && pool.len() > n {
        // Compile the whole pool (batched, cached), harvest hidden
        // features, re-rank with A. The engine's cache means the `n`
        // winners are NOT recompiled when profiled right after.
        match ModelA::train(db, cfg.boost_rounds, cfg.seed ^ round) {
            None => pool.into_iter().take(n).collect(),
            Some(a) => {
                let compiled = engine.compile_batch(env, &pool);
                let mut scored: Vec<(f64, usize)> = pool
                    .iter()
                    .zip(&compiled)
                    .map(|(&i, c)| {
                        let feats = combined_features(
                            &space.schedule(i).visible_features(),
                            &c.hidden,
                        );
                        (a.predict(&feats), i)
                    })
                    .collect();
                // stable sort: ties keep pool (P-ranking) order
                scored.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
                scored.into_iter().take(n).map(|(_, i)| i).collect()
            }
        }
    } else {
        pool.into_iter().take(n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn env() -> TuningEnv {
        TuningEnv::new(VtaConfig::zcu102(),
                       resnet18::layer("conv5").unwrap())
    }

    #[test]
    fn respects_budget_and_no_duplicates() {
        let cfg = TunerConfig { max_trials: 60, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert_eq!(trace.len(), 60);
        let mut idx: Vec<usize> =
            trace.trials.iter().map(|t| t.space_index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 60, "no config profiled twice");
    }

    #[test]
    fn finds_a_valid_config() {
        let cfg = TunerConfig { max_trials: 80, ..Default::default() };
        let mut t = Ml2Tuner::new(cfg);
        let trace = t.tune(&env());
        assert!(trace.best_cycles().is_some());
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TunerConfig { max_trials: 40, seed: 7,
                                ..Default::default() };
        let a = Ml2Tuner::new(cfg.clone()).tune(&env());
        let b = Ml2Tuner::new(cfg).tune(&env());
        let ai: Vec<usize> = a.trials.iter().map(|t| t.space_index).collect();
        let bi: Vec<usize> = b.trials.iter().map(|t| t.space_index).collect();
        assert_eq!(ai, bi);
    }

    #[test]
    fn ablation_names() {
        let cfg = TunerConfig::default();
        assert_eq!(Ml2Tuner::new(cfg.clone()).name(), "ml2tuner");
        assert_eq!(Ml2Tuner::new(cfg.clone()).without_v().name(),
                   "ml2tuner-noV");
        assert_eq!(Ml2Tuner::new(cfg).without_v().without_a().name(),
                   "ml2tuner-Ponly");
    }
}
