//! Tuning traces and derived metrics (curves, ratios, convergence).

use std::sync::Arc;

use super::database::{Outcome, TrialRecord};

/// Complete record of one tuning run, in profiling order.
#[derive(Clone, Debug, Default)]
pub struct TuningTrace {
    /// Layer name the run tuned.
    pub layer: String,
    /// Tuner name that produced the run.
    pub tuner: String,
    /// Every profiled trial, in order. `Arc`-shared with the run's
    /// [`super::database::Database`] — the engine stores one allocation
    /// per trial, never a deep copy.
    pub trials: Vec<Arc<TrialRecord>>,
}

impl TuningTrace {
    /// Empty trace for a (layer, tuner) pair.
    pub fn new(layer: &str, tuner: &str) -> Self {
        TuningTrace { layer: layer.to_string(), tuner: tuner.to_string(),
                      trials: Vec::new() }
    }

    /// Trials profiled so far.
    pub fn len(&self) -> usize {
        self.trials.len()
    }

    /// True if nothing has been profiled yet.
    pub fn is_empty(&self) -> bool {
        self.trials.is_empty()
    }

    /// Best valid cycles over the whole run.
    pub fn best_cycles(&self) -> Option<u64> {
        self.trials.iter().filter_map(|t| t.outcome.cycles()).min()
    }

    /// Best-so-far curve (paper Fig. 2a y-axis): entry `i` is the lowest
    /// valid cycle count among trials `0..=i`; `f64::INFINITY` until the
    /// first valid trial.
    pub fn best_curve(&self) -> Vec<f64> {
        let mut best = f64::INFINITY;
        self.trials
            .iter()
            .map(|t| {
                if let Some(c) = t.outcome.cycles() {
                    best = best.min(c as f64);
                }
                best
            })
            .collect()
    }

    /// Fraction of profiling attempts that were invalid (Fig. 2b left).
    pub fn invalidity_ratio(&self) -> f64 {
        if self.trials.is_empty() {
            return 0.0;
        }
        let invalid = self
            .trials
            .iter()
            .filter(|t| !t.outcome.is_valid())
            .count();
        invalid as f64 / self.trials.len() as f64
    }

    /// Number of invalid attempts by class `(crash, wrong_output)`.
    pub fn invalid_counts(&self) -> (usize, usize) {
        let crash = self
            .trials
            .iter()
            .filter(|t| t.outcome == Outcome::Crash)
            .count();
        let wrong = self
            .trials
            .iter()
            .filter(|t| t.outcome == Outcome::WrongOutput)
            .count();
        (crash, wrong)
    }

    /// Valid execution times (cycles) — Fig. 2b right histogram input.
    pub fn valid_cycles(&self) -> Vec<f64> {
        self.trials
            .iter()
            .filter_map(|t| t.outcome.cycles().map(|c| c as f64))
            .collect()
    }

    /// First trial count at which best-so-far ≤ `target` (None if never).
    pub fn trials_to_reach(&self, target: f64) -> Option<usize> {
        self.best_curve().iter().position(|&b| b <= target).map(|i| i + 1)
    }

    /// 1-based trial count at which the run's overall best was first
    /// reached ("samples to best-so-far"; the telemetry round events
    /// carry this per round). `None` until the first valid trial.
    pub fn trials_to_best(&self) -> Option<usize> {
        let best = self.best_cycles()?;
        self.trials
            .iter()
            .position(|t| t.outcome.cycles() == Some(best))
            .map(|i| i + 1)
    }

    /// Paper's convergence criterion ("the same value repeated more than
    /// 10 times", i.e. no improvement for `window` trailing trials):
    /// returns `(trials_to_converge, converged_value)` where
    /// `trials_to_converge` is the trial count at the *last* improvement.
    /// If the curve is still improving within `window` of the end, the run
    /// did not converge — the budget end is reported instead.
    pub fn convergence(&self, window: usize) -> Option<(usize, f64)> {
        let curve = self.best_curve();
        let best = *curve.last()?;
        if !best.is_finite() {
            return None;
        }
        // last index where the best-so-far improved
        let last_improve = curve
            .iter()
            .position(|&v| v == best)
            .unwrap_or(curve.len() - 1);
        if curve.len() - last_improve >= window {
            Some((last_improve + 1, best))
        } else {
            Some((curve.len(), best)) // not yet stable: report budget end
        }
    }

    /// Estimated wall-clock profiling cost on the real board (seconds) —
    /// the quantity the paper's invalid-filtering actually saves.
    pub fn estimated_wall_clock(&self, cost: &ProfilingCostModel) -> f64 {
        self.trials
            .iter()
            .map(|t| match t.outcome {
                Outcome::Valid { cycles } => {
                    cost.per_attempt_overhead_s
                        + cost.repeats as f64
                            * (cycles as f64 / (cost.clock_mhz * 1e6))
                }
                Outcome::WrongOutput => {
                    cost.per_attempt_overhead_s + cost.wrong_output_cost_s
                }
                Outcome::Crash => cost.crash_reboot_s,
            })
            .sum()
    }
}

/// Board-profiling cost constants (paper §A.2: a crash "requires a manual
/// reboot" — dominant cost; defaults model a ZCU102 flow).
#[derive(Clone, Debug)]
pub struct ProfilingCostModel {
    /// Board clock used to convert cycles to seconds.
    pub clock_mhz: f64,
    /// Measurement repeats per valid config.
    pub repeats: usize,
    /// Fixed per-attempt overhead (compile upload, RPC, …).
    pub per_attempt_overhead_s: f64,
    /// Extra cost of a wrong-output run (executes + compare).
    pub wrong_output_cost_s: f64,
    /// Board reboot after a register error.
    pub crash_reboot_s: f64,
}

impl Default for ProfilingCostModel {
    fn default() -> Self {
        ProfilingCostModel {
            clock_mhz: 100.0,
            repeats: 10,
            per_attempt_overhead_s: 1.0,
            wrong_output_cost_s: 0.5,
            crash_reboot_s: 60.0,
        }
    }
}

/// Average several best-so-far curves (same length assumed; shorter curves
/// are padded with their final value). Infinite prefixes are skipped.
pub fn average_curves(curves: &[Vec<f64>]) -> Vec<f64> {
    if curves.is_empty() {
        return Vec::new();
    }
    let len = curves.iter().map(Vec::len).max().unwrap();
    let mut out = Vec::with_capacity(len);
    for i in 0..len {
        let mut sum = 0.0;
        let mut n = 0usize;
        for c in curves {
            let v = if i < c.len() {
                c[i]
            } else {
                *c.last().unwrap_or(&f64::INFINITY)
            };
            if v.is_finite() {
                sum += v;
                n += 1;
            }
        }
        out.push(if n == 0 { f64::INFINITY } else { sum / n as f64 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::Schedule;
    use crate::tuner::database::Fidelity;

    fn trace_with(outcomes: &[Outcome]) -> TuningTrace {
        let mut t = TuningTrace::new("conv1", "test");
        for (i, &o) in outcomes.iter().enumerate() {
            let s = Schedule { tile_h: 1 + i, tile_w: 1, tile_oc: 16,
                               tile_ic: 16, n_vthreads: 1,
                               ..Default::default() };
            t.trials.push(Arc::new(TrialRecord {
                space_index: i,
                schedule: s,
                visible: crate::compiler::schedule::SpaceKind::Paper
                    .visible_features(&s),
                hidden: vec![],
                outcome: o,
                fidelity: Fidelity::Full,
            }));
        }
        t
    }

    #[test]
    fn best_curve_monotone() {
        let t = trace_with(&[
            Outcome::Crash,
            Outcome::Valid { cycles: 100 },
            Outcome::Valid { cycles: 200 },
            Outcome::Valid { cycles: 50 },
        ]);
        let c = t.best_curve();
        assert!(c[0].is_infinite());
        assert_eq!(&c[1..], &[100.0, 100.0, 50.0]);
        assert_eq!(t.best_cycles(), Some(50));
    }

    #[test]
    fn invalidity_and_counts() {
        let t = trace_with(&[
            Outcome::Crash,
            Outcome::WrongOutput,
            Outcome::Valid { cycles: 10 },
            Outcome::Crash,
        ]);
        assert_eq!(t.invalidity_ratio(), 0.75);
        assert_eq!(t.invalid_counts(), (2, 1));
    }

    #[test]
    fn convergence_detects_plateau() {
        let mut outs = vec![Outcome::Valid { cycles: 100 }];
        outs.extend(std::iter::repeat(Outcome::Valid { cycles: 150 })
            .take(12));
        let t = trace_with(&outs);
        let (at, val) = t.convergence(10).unwrap();
        assert_eq!(val, 100.0);
        assert_eq!(at, 1);
    }

    #[test]
    fn trials_to_reach() {
        let t = trace_with(&[
            Outcome::Valid { cycles: 300 },
            Outcome::Valid { cycles: 100 },
        ]);
        assert_eq!(t.trials_to_reach(300.0), Some(1));
        assert_eq!(t.trials_to_reach(100.0), Some(2));
        assert_eq!(t.trials_to_reach(50.0), None);
    }

    #[test]
    fn wall_clock_dominated_by_crashes() {
        let cost = ProfilingCostModel::default();
        let crashy = trace_with(&[Outcome::Crash; 5]);
        let clean =
            trace_with(&[Outcome::Valid { cycles: 100_000 }; 5]);
        assert!(crashy.estimated_wall_clock(&cost)
            > 10.0 * clean.estimated_wall_clock(&cost));
    }

    #[test]
    fn average_curves_skips_infinite() {
        let a = vec![f64::INFINITY, 10.0, 10.0];
        let b = vec![20.0, 20.0, 8.0];
        let avg = average_curves(&[a, b]);
        assert_eq!(avg, vec![20.0, 15.0, 9.0]);
    }
}
