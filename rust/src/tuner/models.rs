//! The three cost models of paper Fig. 1, over the GBDT substrate.
//!
//! * **Model P** — performance regressor on visible features, trained on
//!   valid records only (Table 3 column P).
//! * **Model V** — validity classifier on the *same* visible features
//!   (binary:hinge, Table 3 column V).
//! * **Model A** — performance regressor on visible ⊕ hidden features
//!   (Table 3 column A).
//!
//! All three predict from raw feature vectors; P and A predict
//! `log2(cycles)` (lower is better).
//!
//! Each model trains through exactly one entry point, `fit(&TrainSet,
//! &FitOpts)`: the caller assembles rows with
//! [`crate::tuner::train::TrainSet`] (cold records, warm-transferred
//! records, tiered coarse weights, TVM penalty labels — all row-assembly
//! concerns), and [`FitOpts`] composes the booster-level options: round
//! count, subsampling seed, warm continuation from a previous round's
//! ensemble, and meta-artifact adaptation (continuation + level
//! recalibration).

use crate::gbdt::{
    Booster, Dataset, FeatureMatrix, FlatEnsemble, GbdtParams, TrainOpts,
};
use crate::tuner::train::TrainSet;

/// Booster-level options for one model `fit` call.
#[derive(Clone, Copy, Default)]
pub struct FitOpts<'a> {
    /// Boosting rounds — appended rounds when `base` is set, total
    /// rounds otherwise.
    pub rounds: usize,
    /// Subsampling seed (ignored under continuation: the base's seed
    /// stream is replayed so appended trees are bit-exact).
    pub seed: u64,
    /// Continuation base: a previous round's ensemble (incremental
    /// per-round training) or a corpus-trained meta ensemble. `fit`
    /// keeps its trees and appends `rounds` more; with fewer than 2
    /// training rows the base alone is returned, which is what makes a
    /// meta-adapted run model-guided from round 1.
    pub base: Option<&'a Booster>,
    /// Shift the base's intercept by the mean residual over the training
    /// set before appending trees — the meta-adaptation level correction
    /// (a corpus model knows the landscape's shape; the run's records
    /// know its level).
    pub recalibrate: bool,
}

impl<'a> FitOpts<'a> {
    /// Cold fit: `rounds` boosting rounds under `seed`.
    pub fn new(rounds: usize, seed: u64) -> Self {
        FitOpts { rounds, seed, base: None, recalibrate: false }
    }

    /// Continue from `base`, appending `self.rounds` trees.
    pub fn with_base(mut self, base: &'a Booster) -> Self {
        self.base = Some(base);
        self
    }

    /// Enable the mean-residual intercept correction (meta adaptation).
    pub fn recalibrated(mut self) -> Self {
        self.recalibrate = true;
        self
    }
}

/// Shared training tail: readiness guard (≥ 2 rows) + boosting, with
/// optional continuation/recalibration. A `base` whose feature width
/// does not match the set's rows (e.g. a meta artifact from a different
/// feature layout) falls back to a cold fit rather than poisoning
/// predictions.
fn fit_impl(
    params: GbdtParams,
    set: &TrainSet,
    opts: &FitOpts,
) -> Option<Booster> {
    if set.len() < 2 {
        // too few rows to fit anything fresh — but a continuation base
        // is already a usable ensemble; hand it back unchanged
        return opts.base.cloned();
    }
    let data = Dataset::from_rows(set.xs(), set.ys());
    let train_opts = TrainOpts::weighted(set.weights());
    let base = match opts.base {
        Some(b) if b.n_features == data.n_features => b,
        _ => {
            return Some(Booster::fit(
                &params.with_seed(opts.seed).with_rounds(opts.rounds),
                &data,
                &train_opts,
            ))
        }
    };
    let recal;
    let base = if opts.recalibrate {
        let mut shifted = base.clone();
        let resid: f64 = set
            .xs()
            .iter()
            .zip(set.ys())
            .map(|(x, y)| y - base.predict_row(x))
            .sum::<f64>()
            / set.len() as f64;
        shifted.base_score += resid;
        recal = shifted;
        &recal
    } else {
        base
    };
    Some(Booster::fit(
        &params.with_rounds(opts.rounds),
        &data,
        &TrainOpts { init: Some(base), ..train_opts },
    ))
}

/// A trained P model.
pub struct ModelP {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical predictions).
    flat: FlatEnsemble,
}

impl ModelP {
    /// Wrap a trained/deserialized ensemble (e.g. a meta artifact).
    pub fn from_booster(booster: Booster) -> ModelP {
        ModelP { flat: booster.flatten(), booster }
    }

    /// Train on an assembled [`TrainSet`] (see
    /// [`crate::tuner::train::TrainSet::extend_p`] /
    /// [`crate::tuner::train::TrainSet::extend_p_penalty`]); `None` if
    /// the set has < 2 rows and no continuation base.
    pub fn fit(set: &TrainSet, opts: &FitOpts) -> Option<ModelP> {
        fit_impl(GbdtParams::model_p(), set, opts)
            .map(ModelP::from_booster)
    }

    /// Predicted `log2(cycles)` — lower is better.
    pub fn predict(&self, visible: &[f64]) -> f64 {
        self.booster.predict_row(visible)
    }

    /// Batched predictions over a visible-feature matrix (flattened
    /// ensemble; per row bit-identical to [`ModelP::predict`]). `out`
    /// is cleared and resized.
    pub fn predict_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }
}

/// A trained V model.
pub struct ModelV {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical margins).
    flat: FlatEnsemble,
}

impl ModelV {
    /// Wrap a trained/deserialized ensemble (e.g. a meta artifact).
    pub fn from_booster(booster: Booster) -> ModelV {
        ModelV { flat: booster.flatten(), booster }
    }

    /// Train on an assembled [`TrainSet`] (see
    /// [`crate::tuner::train::TrainSet::extend_v`]); `None` if the set
    /// has < 2 rows and no continuation base. Degenerate labels (all
    /// same class) still train but predict a constant; that is fine —
    /// the explorer falls back gracefully.
    pub fn fit(set: &TrainSet, opts: &FitOpts) -> Option<ModelV> {
        fit_impl(GbdtParams::model_v(), set, opts)
            .map(ModelV::from_booster)
    }

    /// True if the model's hinge score clears `margin` — the V veto.
    ///
    /// A positive margin (default
    /// [`crate::tuner::DEFAULT_V_MARGIN`] = 0.25 on the hinge score in
    /// [-1, 1], configurable via `TunerConfig::v_margin` / `--v-margin`)
    /// gates stricter than the raw sign: the explorer walks a P-front
    /// that hugs the validity boundary, exactly where marginal false
    /// accepts concentrate — a stricter gate trades a few vetoed good
    /// configs for far fewer wasted profiling slots (calibrated on
    /// conv4's hazard-corruption boundary, see EXPERIMENTS.md §V-margin).
    pub fn predict_valid(&self, visible: &[f64], margin: f64) -> bool {
        self.margin(visible) > margin
    }

    /// Raw margin (diagnostics / threshold sweeps).
    pub fn margin(&self, visible: &[f64]) -> f64 {
        self.booster.predict_row(visible)
    }

    /// Batched raw margins over a visible-feature matrix (per row
    /// bit-identical to [`ModelV::margin`]). `out` is cleared and
    /// resized.
    pub fn margin_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }
}

/// A trained A model.
pub struct ModelA {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical predictions).
    flat: FlatEnsemble,
}

impl ModelA {
    /// Wrap a trained/deserialized ensemble (e.g. a meta artifact).
    pub fn from_booster(booster: Booster) -> ModelA {
        ModelA { flat: booster.flatten(), booster }
    }

    /// Train on an assembled [`TrainSet`] (see
    /// [`crate::tuner::train::TrainSet::extend_a`]); `None` if the set
    /// has < 2 rows and no continuation base.
    pub fn fit(set: &TrainSet, opts: &FitOpts) -> Option<ModelA> {
        fit_impl(GbdtParams::model_a(), set, opts)
            .map(ModelA::from_booster)
    }

    /// Predicted `log2(cycles)` from visible ⊕ hidden features.
    pub fn predict(&self, combined: &[f64]) -> f64 {
        self.booster.predict_row(combined)
    }

    /// Batched predictions over a combined (visible ⊕ hidden) feature
    /// matrix (per row bit-identical to [`ModelA::predict`]). `out` is
    /// cleared and resized.
    pub fn predict_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }

    /// Feature importance over the combined feature space (Table 5).
    pub fn importance(&self) -> Vec<f64> {
        self.booster.feature_importance()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::{Schedule, SpaceKind};
    use crate::tuner::database::{Database, Fidelity, Outcome,
                                 TrialRecord};
    use crate::tuner::train::Provenance;
    use crate::tuner::DEFAULT_V_MARGIN;

    fn fit_p(db: &Database, rounds: usize, seed: u64) -> Option<ModelP> {
        let mut set = TrainSet::new();
        set.extend_p(db, Provenance::Cold);
        ModelP::fit(&set, &FitOpts::new(rounds, seed))
    }

    fn fit_v(db: &Database, rounds: usize, seed: u64) -> Option<ModelV> {
        let mut set = TrainSet::new();
        set.extend_v(db, Provenance::Cold);
        ModelV::fit(&set, &FitOpts::new(rounds, seed))
    }

    fn fit_a(db: &Database, rounds: usize, seed: u64) -> Option<ModelA> {
        let mut set = TrainSet::new();
        set.extend_a(db, Provenance::Cold);
        ModelA::fit(&set, &FitOpts::new(rounds, seed))
    }

    fn vis(s: &Schedule) -> Vec<f64> {
        SpaceKind::Paper.visible_features(s)
    }

    fn sched(th: usize, vt: usize) -> Schedule {
        Schedule { tile_h: th, tile_w: 4, tile_oc: 32, tile_ic: 32,
                   n_vthreads: vt, ..Default::default() }
    }

    fn synth_db(n: usize) -> Database {
        let mut db = Database::new("test");
        for i in 0..n {
            let th = 1 + (i % 16);
            let vt = 1 + (i % 4);
            let schedule = sched(th, vt);
            // validity: big tiles with many threads fail
            let valid = th * vt <= 24;
            let cycles = (200_000 / th + 10_000 * vt) as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule,
                visible: vis(&schedule),
                hidden: vec![th as f64 * 4.0, (th * vt) as f64],
                outcome: if valid {
                    Outcome::Valid { cycles }
                } else {
                    Outcome::Crash
                },
                fidelity: Fidelity::Full,
            });
        }
        db
    }

    #[test]
    fn p_learns_cycle_ordering() {
        let db = synth_db(128);
        let p = fit_p(&db, 80, 1).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12), "small tiles must predict slower");
    }

    #[test]
    fn v_learns_validity_boundary() {
        let db = synth_db(256);
        let v = fit_v(&db, 80, 1).unwrap();
        let f = |th: usize, vt: usize| {
            v.predict_valid(&vis(&sched(th, vt)), DEFAULT_V_MARGIN)
        };
        assert!(f(4, 1), "small config should be predicted valid");
        assert!(!f(16, 4), "oversized config should be predicted invalid");
    }

    #[test]
    fn veto_margin_is_configurable() {
        let db = synth_db(256);
        let v = fit_v(&db, 80, 1).unwrap();
        let feats = vis(&sched(4, 1));
        let m = v.margin(&feats);
        assert!(v.predict_valid(&feats, DEFAULT_V_MARGIN));
        // a margin above the score vetoes; one below accepts
        assert!(!v.predict_valid(&feats, m + 0.01));
        assert!(v.predict_valid(&feats, m - 0.01));
    }

    #[test]
    fn a_uses_hidden_features() {
        let db = synth_db(128);
        let a = fit_a(&db, 80, 1).unwrap();
        let imp = a.importance();
        assert_eq!(imp.len(), SpaceKind::Paper.n_visible() + 2);
        // the hidden features are informative (th*4 mirrors th)
        assert!(imp.iter().sum::<f64>() > 99.0);
    }

    #[test]
    fn batch_apis_match_single_row_bitwise() {
        use crate::gbdt::FeatureMatrix;
        let db = synth_db(256);
        let p = fit_p(&db, 60, 3).unwrap();
        let v = fit_v(&db, 60, 3).unwrap();
        let a = fit_a(&db, 60, 3).unwrap();
        let rows: Vec<Vec<f64>> =
            (1..=16).map(|th| vis(&sched(th, 1 + th % 4))).collect();
        let m = FeatureMatrix::from_rows(&rows);
        let mut out = Vec::new();
        p.predict_batch_into(&m, &mut out);
        assert_eq!(out.len(), rows.len());
        for (r, &s) in rows.iter().zip(&out) {
            assert_eq!(p.predict(r).to_bits(), s.to_bits());
        }
        v.margin_batch_into(&m, &mut out);
        for (r, &s) in rows.iter().zip(&out) {
            assert_eq!(v.margin(r).to_bits(), s.to_bits());
        }
        // A consumes visible ⊕ hidden rows
        let arows: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| {
                let mut x = r.clone();
                x.extend_from_slice(&[3.0, 7.0]);
                x
            })
            .collect();
        let am = FeatureMatrix::from_rows(&arows);
        a.predict_batch_into(&am, &mut out);
        for (r, &s) in arows.iter().zip(&out) {
            assert_eq!(a.predict(r).to_bits(), s.to_bits());
        }
    }

    #[test]
    fn too_few_records_returns_none() {
        let db = synth_db(1);
        assert!(fit_p(&db, 10, 0).is_none());
        assert!(fit_a(&db, 10, 0).is_none());
    }

    #[test]
    fn warm_start_trains_before_any_fresh_record() {
        let warm = synth_db(256);
        let fresh = Database::new("target");
        assert!(fit_p(&fresh, 40, 1).is_none(),
                "cold model needs fresh records");
        let mut ps = TrainSet::new();
        ps.extend_p(&warm, Provenance::Warm)
            .extend_p(&fresh, Provenance::Cold);
        let p = ModelP::fit(&ps, &FitOpts::new(80, 1)).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12),
                "transferred records alone must order the landscape");
        let mut vs = TrainSet::new();
        vs.extend_v(&warm, Provenance::Warm)
            .extend_v(&fresh, Provenance::Cold);
        let v = ModelV::fit(&vs, &FitOpts::new(80, 1)).unwrap();
        assert!(v.predict_valid(&vis(&sched(4, 1)), DEFAULT_V_MARGIN));
        assert!(!v.predict_valid(&vis(&sched(16, 4)), DEFAULT_V_MARGIN));
        let mut as_ = TrainSet::new();
        as_.extend_a(&warm, Provenance::Warm)
            .extend_a(&fresh, Provenance::Cold);
        assert!(ModelA::fit(&as_, &FitOpts::new(40, 1)).is_some());
    }

    #[test]
    fn coarse_labels_steer_but_do_not_outvote_measured_ones() {
        // a cold database of coarse estimates alone can train P (the
        // prescreen bootstrap), and mixing coarse rows into a measured
        // database keeps predictions bit-close to measured-only when
        // the coarse labels agree in ordering
        let mut coarse_only = Database::new("t");
        for i in 0..64usize {
            let th = 1 + (i % 16);
            let s = sched(th, 1);
            coarse_only.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: vis(&s),
                hidden: vec![],
                outcome: Outcome::Valid {
                    cycles: (300_000 / th) as u64,
                },
                fidelity: Fidelity::Coarse,
            });
        }
        let p = fit_p(&coarse_only, 80, 1).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12),
                "coarse-only training must order the landscape");
        // mixed db: the measured rows dominate where they disagree
        let mut mixed = synth_db(128);
        for i in 0..128usize {
            let th = 1 + (i % 16);
            let s = sched(th, 1);
            mixed.push(TrialRecord {
                space_index: 1000 + i,
                schedule: s,
                visible: vis(&s),
                hidden: vec![],
                // adversarial coarse labels: inverted ordering
                outcome: Outcome::Valid {
                    cycles: (10_000 * th) as u64,
                },
                fidelity: Fidelity::Coarse,
            });
        }
        let pm = fit_p(&mixed, 80, 1).unwrap();
        let fm = |th: usize| pm.predict(&vis(&sched(th, 1)));
        assert!(fm(2) > fm(12),
                "measured labels must outvote down-weighted coarse ones");
    }

    #[test]
    fn warm_start_combines_fresh_and_transferred_rows() {
        // 1 fresh valid record alone cannot train P; with a warm source
        // it can, and the fresh row participates (xs = warm ⊕ fresh).
        let warm = synth_db(16);
        let mut fresh = Database::new("target");
        let s = sched(3, 1);
        fresh.push(TrialRecord {
            space_index: 0,
            schedule: s,
            visible: vis(&s),
            hidden: vec![12.0, 3.0],
            outcome: Outcome::Valid { cycles: 70_000 },
            fidelity: Fidelity::Full,
        });
        assert!(fit_p(&fresh, 10, 0).is_none());
        let mut set = TrainSet::new();
        set.extend_p(&warm, Provenance::Warm)
            .extend_p(&fresh, Provenance::Cold);
        assert!(ModelP::fit(&set, &FitOpts::new(10, 0)).is_some());
    }

    #[test]
    fn continuation_base_carries_a_model_with_too_few_rows() {
        // the meta path: an empty run still gets a usable model when a
        // base ensemble is supplied, so tuning is model-guided from
        // round 1
        let corpus = synth_db(128);
        let base = fit_p(&corpus, 60, 1).unwrap().booster;
        let empty = TrainSet::new();
        assert!(ModelP::fit(&empty, &FitOpts::new(10, 0)).is_none());
        let p = ModelP::fit(&empty,
                            &FitOpts::new(10, 0).with_base(&base))
            .unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12), "base alone must order the landscape");
        assert_eq!(p.booster.trees.len(), base.trees.len(),
                   "nothing to adapt on -> base returned unchanged");
    }

    #[test]
    fn recalibration_shifts_the_level_not_the_shape() {
        // corpus labels live 3 log2 units below the run's: after
        // recalibrated adaptation on a handful of run rows, predictions
        // land near the run's level
        let corpus = synth_db(128);
        let base = fit_p(&corpus, 120, 1).unwrap().booster;
        let mut run = Database::new("run");
        for i in 0..8usize {
            let th = 1 + 2 * (i % 8);
            let s = sched(th, 1);
            run.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: vis(&s),
                hidden: vec![],
                outcome: Outcome::Valid {
                    cycles: 8 * (200_000 / th + 10_000) as u64,
                },
                fidelity: Fidelity::Full,
            });
        }
        let mut set = TrainSet::new();
        set.extend_p(&run, Provenance::Cold);
        let adapted = ModelP::fit(
            &set,
            &FitOpts::new(12, 0).with_base(&base).recalibrated(),
        )
        .unwrap();
        let before: f64 = set
            .xs()
            .iter()
            .zip(set.ys())
            .map(|(x, y)| (y - base.predict_row(x)).abs())
            .sum::<f64>()
            / set.len() as f64;
        let after: f64 = set
            .xs()
            .iter()
            .zip(set.ys())
            .map(|(x, y)| (y - adapted.predict(x)).abs())
            .sum::<f64>()
            / set.len() as f64;
        assert!(after < 0.5 * before,
                "recalibrated adaptation must close the level gap: \
                 {after} vs {before}");
        // and the landscape shape survives
        let f = |th: usize| adapted.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12));
    }

    #[test]
    fn base_with_wrong_width_falls_back_to_cold_fit() {
        let db = synth_db(128);
        let base = fit_a(&db, 40, 1).unwrap().booster; // wider features
        let mut set = TrainSet::new();
        set.extend_p(&db, Provenance::Cold);
        let p = ModelP::fit(&set,
                            &FitOpts::new(30, 1).with_base(&base))
            .unwrap();
        let cold = fit_p(&db, 30, 1).unwrap();
        let feats = vis(&sched(5, 1));
        assert_eq!(p.predict(&feats).to_bits(),
                   cold.predict(&feats).to_bits(),
                   "width-mismatched base must be ignored");
    }
}
