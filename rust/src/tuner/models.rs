//! The three cost models of paper Fig. 1, over the GBDT substrate.
//!
//! * **Model P** — performance regressor on visible features, trained on
//!   valid records only (Table 3 column P).
//! * **Model V** — validity classifier on the *same* visible features
//!   (binary:hinge, Table 3 column V).
//! * **Model A** — performance regressor on visible ⊕ hidden features
//!   (Table 3 column A).
//!
//! All three predict from raw feature vectors; P and A predict
//! `log2(cycles)` (lower is better).

use crate::gbdt::{
    Booster, Dataset, FeatureMatrix, FlatEnsemble, GbdtParams,
};
use crate::tuner::database::Database;

/// Shared training tail: readiness guard (≥ 2 rows) + boosting.
fn fit(params: GbdtParams, xs: Vec<Vec<f64>>, ys: Vec<f64>)
    -> Option<Booster>
{
    fit_weighted(params, xs, ys, None)
}

/// Weighted variant of [`fit`]: per-row sample weights for
/// mixed-fidelity training sets. `weights: None` is bit-identical to
/// the unweighted path, which is what keeps prescreen-off runs
/// byte-identical.
fn fit_weighted(
    params: GbdtParams,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    weights: Option<Vec<f64>>,
) -> Option<Booster> {
    if xs.len() < 2 {
        return None;
    }
    let data = Dataset::from_rows(&xs, &ys);
    Some(Booster::train_weighted(&params, &data, weights.as_deref()))
}

/// Warm-start training set: rows from `warm` (a transferred database,
/// see [`crate::tuner::database::TransferDb::warm_start_for`]) precede
/// the freshly profiled rows, so a model is trainable *before the first
/// profiled batch* of a run.
fn warm_rows(
    fresh: (Vec<Vec<f64>>, Vec<f64>),
    warm: (Vec<Vec<f64>>, Vec<f64>),
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let (mut xs, mut ys) = warm;
    xs.extend(fresh.0);
    ys.extend(fresh.1);
    (xs, ys)
}

/// A trained P model.
pub struct ModelP {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical predictions).
    flat: FlatEnsemble,
}

impl ModelP {
    fn params(rounds: usize, seed: u64) -> GbdtParams {
        GbdtParams::model_p().with_rounds(rounds).with_seed(seed)
    }

    fn from_booster(booster: Booster) -> ModelP {
        ModelP { flat: booster.flatten(), booster }
    }

    /// Train on the database's valid records (`None` if < 2 rows).
    /// Coarse tier-0 estimates participate at
    /// [`crate::tuner::database::COARSE_LABEL_WEIGHT`]; a database
    /// without them trains through the unweighted path bit-identically.
    pub fn train(db: &Database, rounds: usize, seed: u64) -> Option<ModelP> {
        let (xs, ys, ws) = db.train_p_tiered();
        fit_weighted(Self::params(rounds, seed), xs, ys, ws)
            .map(ModelP::from_booster)
    }

    /// Transfer warm-start variant: transferred rows first, fresh rows
    /// after (see [`warm_rows`]). Transferred rows are always measured
    /// (the transfer store drops coarse records) and weigh 1.0; fresh
    /// coarse rows keep their tier weight.
    pub fn train_warm(
        fresh: &Database,
        warm: &Database,
        rounds: usize,
        seed: u64,
    ) -> Option<ModelP> {
        let (fx, fy, fw) = fresh.train_p_tiered();
        let (wx, wy) = warm.train_p();
        let ws = fw.map(|fw| {
            let mut w = vec![1.0; wx.len()];
            w.extend(fw);
            w
        });
        let (xs, ys) = warm_rows((fx, fy), (wx, wy));
        fit_weighted(Self::params(rounds, seed), xs, ys, ws)
            .map(ModelP::from_booster)
    }

    /// TVM-approach variant: all records, invalids penalized.
    pub fn train_tvm(
        db: &Database,
        rounds: usize,
        seed: u64,
    ) -> Option<ModelP> {
        let (xs, ys) = db.train_p_with_penalty();
        fit(Self::params(rounds, seed), xs, ys)
            .map(ModelP::from_booster)
    }

    /// Predicted `log2(cycles)` — lower is better.
    pub fn predict(&self, visible: &[f64]) -> f64 {
        self.booster.predict_row(visible)
    }

    /// Batched predictions over a visible-feature matrix (flattened
    /// ensemble; per row bit-identical to [`ModelP::predict`]). `out`
    /// is cleared and resized.
    pub fn predict_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }
}

/// A trained V model.
pub struct ModelV {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical margins).
    flat: FlatEnsemble,
}

impl ModelV {
    fn params(rounds: usize, seed: u64) -> GbdtParams {
        GbdtParams::model_v().with_rounds(rounds).with_seed(seed)
    }

    fn from_booster(booster: Booster) -> ModelV {
        ModelV { flat: booster.flatten(), booster }
    }

    /// Train on all records, labelled by validity (`None` if < 2 rows).
    pub fn train(db: &Database, rounds: usize, seed: u64) -> Option<ModelV> {
        // degenerate labels (all same class) would still train but predict a
        // constant; that is fine — the explorer falls back gracefully.
        let (xs, ys) = db.train_v();
        fit(Self::params(rounds, seed), xs, ys)
            .map(ModelV::from_booster)
    }

    /// Transfer warm-start variant of [`ModelV::train`]: transferred
    /// rows first, fresh rows after. The validity boundary is
    /// scratchpad-pressure driven — a near-layer-independent function of
    /// the schedule — so V is the model that transfers best.
    pub fn train_warm(
        fresh: &Database,
        warm: &Database,
        rounds: usize,
        seed: u64,
    ) -> Option<ModelV> {
        let (xs, ys) = warm_rows(fresh.train_v(), warm.train_v());
        fit(Self::params(rounds, seed), xs, ys)
            .map(ModelV::from_booster)
    }

    /// True if the model's hinge score clears `margin` — the V veto.
    ///
    /// A positive margin (default
    /// [`crate::tuner::DEFAULT_V_MARGIN`] = 0.25 on the hinge score in
    /// [-1, 1], configurable via `TunerConfig::v_margin` / `--v-margin`)
    /// gates stricter than the raw sign: the explorer walks a P-front
    /// that hugs the validity boundary, exactly where marginal false
    /// accepts concentrate — a stricter gate trades a few vetoed good
    /// configs for far fewer wasted profiling slots (calibrated on
    /// conv4's hazard-corruption boundary, see EXPERIMENTS.md §V-margin).
    pub fn predict_valid(&self, visible: &[f64], margin: f64) -> bool {
        self.margin(visible) > margin
    }

    /// Raw margin (diagnostics / threshold sweeps).
    pub fn margin(&self, visible: &[f64]) -> f64 {
        self.booster.predict_row(visible)
    }

    /// Batched raw margins over a visible-feature matrix (per row
    /// bit-identical to [`ModelV::margin`]). `out` is cleared and
    /// resized.
    pub fn margin_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }
}

/// A trained A model.
pub struct ModelA {
    /// Underlying GBDT ensemble.
    pub booster: Booster,
    /// Flattened inference layout (bit-identical predictions).
    flat: FlatEnsemble,
}

impl ModelA {
    fn params(rounds: usize, seed: u64) -> GbdtParams {
        GbdtParams::model_a().with_rounds(rounds).with_seed(seed)
    }

    fn from_booster(booster: Booster) -> ModelA {
        ModelA { flat: booster.flatten(), booster }
    }

    /// Train on valid records, visible ⊕ hidden (`None` if < 2 rows).
    pub fn train(db: &Database, rounds: usize, seed: u64) -> Option<ModelA> {
        let (xs, ys) = db.train_a();
        fit(Self::params(rounds, seed), xs, ys)
            .map(ModelA::from_booster)
    }

    /// Transfer warm-start variant of [`ModelA::train`]: transferred
    /// rows (visible ⊕ stored hidden features) first, fresh rows after.
    pub fn train_warm(
        fresh: &Database,
        warm: &Database,
        rounds: usize,
        seed: u64,
    ) -> Option<ModelA> {
        let (xs, ys) = warm_rows(fresh.train_a(), warm.train_a());
        fit(Self::params(rounds, seed), xs, ys)
            .map(ModelA::from_booster)
    }

    /// Predicted `log2(cycles)` from visible ⊕ hidden features.
    pub fn predict(&self, combined: &[f64]) -> f64 {
        self.booster.predict_row(combined)
    }

    /// Batched predictions over a combined (visible ⊕ hidden) feature
    /// matrix (per row bit-identical to [`ModelA::predict`]). `out` is
    /// cleared and resized.
    pub fn predict_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        self.flat.predict_batch_into(m, out);
    }

    /// Feature importance over the combined feature space (Table 5).
    pub fn importance(&self) -> Vec<f64> {
        self.booster.feature_importance()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::{Schedule, SpaceKind};
    use crate::tuner::database::{Fidelity, Outcome, TrialRecord};
    use crate::tuner::DEFAULT_V_MARGIN;

    fn vis(s: &Schedule) -> Vec<f64> {
        SpaceKind::Paper.visible_features(s)
    }

    fn sched(th: usize, vt: usize) -> Schedule {
        Schedule { tile_h: th, tile_w: 4, tile_oc: 32, tile_ic: 32,
                   n_vthreads: vt, ..Default::default() }
    }

    fn synth_db(n: usize) -> Database {
        let mut db = Database::new("test");
        for i in 0..n {
            let th = 1 + (i % 16);
            let vt = 1 + (i % 4);
            let schedule = sched(th, vt);
            // validity: big tiles with many threads fail
            let valid = th * vt <= 24;
            let cycles = (200_000 / th + 10_000 * vt) as u64;
            db.push(TrialRecord {
                space_index: i,
                schedule,
                visible: vis(&schedule),
                hidden: vec![th as f64 * 4.0, (th * vt) as f64],
                outcome: if valid {
                    Outcome::Valid { cycles }
                } else {
                    Outcome::Crash
                },
                fidelity: Fidelity::Full,
            });
        }
        db
    }

    #[test]
    fn p_learns_cycle_ordering() {
        let db = synth_db(128);
        let p = ModelP::train(&db, 80, 1).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12), "small tiles must predict slower");
    }

    #[test]
    fn v_learns_validity_boundary() {
        let db = synth_db(256);
        let v = ModelV::train(&db, 80, 1).unwrap();
        let f = |th: usize, vt: usize| {
            v.predict_valid(&vis(&sched(th, vt)), DEFAULT_V_MARGIN)
        };
        assert!(f(4, 1), "small config should be predicted valid");
        assert!(!f(16, 4), "oversized config should be predicted invalid");
    }

    #[test]
    fn veto_margin_is_configurable() {
        let db = synth_db(256);
        let v = ModelV::train(&db, 80, 1).unwrap();
        let feats = vis(&sched(4, 1));
        let m = v.margin(&feats);
        assert!(v.predict_valid(&feats, DEFAULT_V_MARGIN));
        // a margin above the score vetoes; one below accepts
        assert!(!v.predict_valid(&feats, m + 0.01));
        assert!(v.predict_valid(&feats, m - 0.01));
    }

    #[test]
    fn a_uses_hidden_features() {
        let db = synth_db(128);
        let a = ModelA::train(&db, 80, 1).unwrap();
        let imp = a.importance();
        assert_eq!(imp.len(), SpaceKind::Paper.n_visible() + 2);
        // the hidden features are informative (th*4 mirrors th)
        assert!(imp.iter().sum::<f64>() > 99.0);
    }

    #[test]
    fn batch_apis_match_single_row_bitwise() {
        use crate::gbdt::FeatureMatrix;
        let db = synth_db(256);
        let p = ModelP::train(&db, 60, 3).unwrap();
        let v = ModelV::train(&db, 60, 3).unwrap();
        let a = ModelA::train(&db, 60, 3).unwrap();
        let rows: Vec<Vec<f64>> =
            (1..=16).map(|th| vis(&sched(th, 1 + th % 4))).collect();
        let m = FeatureMatrix::from_rows(&rows);
        let mut out = Vec::new();
        p.predict_batch_into(&m, &mut out);
        assert_eq!(out.len(), rows.len());
        for (r, &s) in rows.iter().zip(&out) {
            assert_eq!(p.predict(r).to_bits(), s.to_bits());
        }
        v.margin_batch_into(&m, &mut out);
        for (r, &s) in rows.iter().zip(&out) {
            assert_eq!(v.margin(r).to_bits(), s.to_bits());
        }
        // A consumes visible ⊕ hidden rows
        let arows: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| {
                let mut x = r.clone();
                x.extend_from_slice(&[3.0, 7.0]);
                x
            })
            .collect();
        let am = FeatureMatrix::from_rows(&arows);
        a.predict_batch_into(&am, &mut out);
        for (r, &s) in arows.iter().zip(&out) {
            assert_eq!(a.predict(r).to_bits(), s.to_bits());
        }
    }

    #[test]
    fn too_few_records_returns_none() {
        let db = synth_db(1);
        assert!(ModelP::train(&db, 10, 0).is_none());
        assert!(ModelA::train(&db, 10, 0).is_none());
    }

    #[test]
    fn warm_start_trains_before_any_fresh_record() {
        let warm = synth_db(256);
        let fresh = Database::new("target");
        assert!(ModelP::train(&fresh, 40, 1).is_none(),
                "cold model needs fresh records");
        let p = ModelP::train_warm(&fresh, &warm, 80, 1).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12),
                "transferred records alone must order the landscape");
        let v = ModelV::train_warm(&fresh, &warm, 80, 1).unwrap();
        assert!(v.predict_valid(&vis(&sched(4, 1)), DEFAULT_V_MARGIN));
        assert!(!v.predict_valid(&vis(&sched(16, 4)), DEFAULT_V_MARGIN));
        assert!(ModelA::train_warm(&fresh, &warm, 40, 1).is_some());
    }

    #[test]
    fn coarse_labels_steer_but_do_not_outvote_measured_ones() {
        // a cold database of coarse estimates alone can train P (the
        // prescreen bootstrap), and mixing coarse rows into a measured
        // database keeps predictions bit-close to measured-only when
        // the coarse labels agree in ordering
        let mut coarse_only = Database::new("t");
        for i in 0..64usize {
            let th = 1 + (i % 16);
            let s = sched(th, 1);
            coarse_only.push(TrialRecord {
                space_index: i,
                schedule: s,
                visible: vis(&s),
                hidden: vec![],
                outcome: Outcome::Valid {
                    cycles: (300_000 / th) as u64,
                },
                fidelity: Fidelity::Coarse,
            });
        }
        let p = ModelP::train(&coarse_only, 80, 1).unwrap();
        let f = |th: usize| p.predict(&vis(&sched(th, 1)));
        assert!(f(2) > f(12),
                "coarse-only training must order the landscape");
        // mixed db: the measured rows dominate where they disagree
        let mut mixed = synth_db(128);
        for i in 0..128usize {
            let th = 1 + (i % 16);
            let s = sched(th, 1);
            mixed.push(TrialRecord {
                space_index: 1000 + i,
                schedule: s,
                visible: vis(&s),
                hidden: vec![],
                // adversarial coarse labels: inverted ordering
                outcome: Outcome::Valid {
                    cycles: (10_000 * th) as u64,
                },
                fidelity: Fidelity::Coarse,
            });
        }
        let pm = ModelP::train(&mixed, 80, 1).unwrap();
        let fm = |th: usize| pm.predict(&vis(&sched(th, 1)));
        assert!(fm(2) > fm(12),
                "measured labels must outvote down-weighted coarse ones");
    }

    #[test]
    fn warm_start_combines_fresh_and_transferred_rows() {
        // 1 fresh valid record alone cannot train P; with a warm source
        // it can, and the fresh row participates (xs = warm ⊕ fresh).
        let warm = synth_db(16);
        let mut fresh = Database::new("target");
        let s = sched(3, 1);
        fresh.push(TrialRecord {
            space_index: 0,
            schedule: s,
            visible: vis(&s),
            hidden: vec![12.0, 3.0],
            outcome: Outcome::Valid { cycles: 70_000 },
            fidelity: Fidelity::Full,
        });
        assert!(ModelP::train(&fresh, 10, 0).is_none());
        assert!(ModelP::train_warm(&fresh, &warm, 10, 0).is_some());
    }
}
