//! # ML²Tuner — multi-level machine-learning autotuning for DL accelerators
//!
//! Reproduction of *ML²Tuner: Efficient Code Tuning via Multi-Level Machine
//! Learning Models* (Cha et al., 2024) on a simulated extended-VTA
//! accelerator. See `ARCHITECTURE.md` for the system inventory and the
//! paper-to-module mapping.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — deterministic RNG, minimal JSON, statistics, table printing,
//!   and the in-tree property-test / micro-bench harnesses (the offline
//!   vendor set has no `proptest`/`criterion`).
//! * [`vta`] — the hardware substrate: a functional **and** cycle-approximate
//!   simulator of the extended VTA of paper Appendix A.1 (Table 1), including
//!   the runtime fault model that makes configurations *invalid*.
//! * [`compiler`] — the backend compiler substrate: a knob-based, lazily
//!   indexed search space ([`compiler::schedule::ConfigSpace`]; the
//!   paper-exact knob set plus an extended one with load double-buffering
//!   and kernel unroll), schedule-driven code generation (conv → tiled
//!   loop nest → VTA instruction stream) whose analysis passes emit the
//!   paper's *hidden features* (Table 5), and a derived-feature registry
//!   that generates the P/V feature vectors from the knob declarations.
//! * [`gbdt`] — from-scratch XGBoost-style gradient-boosted trees (the
//!   paper's cost-model family), with the Table 3 hyper-parameter surface.
//! * [`workloads`] — the network registry: ResNet18 (paper Table 2a),
//!   VGG-16, a MobileNet-style pointwise net, a synthetic GEMM/dense
//!   suite, plus synthetic workload generators. `tune-net`, the
//!   experiments, and the transfer store all operate over any registered
//!   [`workloads::Network`].
//! * [`runtime`] — PJRT wrapper executing the AOT-compiled JAX/Pallas golden
//!   models from `artifacts/*.hlo.txt` (Python never runs at tuning time).
//! * [`tuner`] — the paper's contribution: configuration explorer, cost
//!   models P/V/A, profiling database, the ML²Tuner loop and the
//!   TVM-approach / random baselines. Tuning logs are shape-stamped and
//!   a [`tuner::database::TransferDb`] (any directory of prior logs)
//!   warm-starts the models on shape-similar layers before the first
//!   profiled batch (`--transfer-from`).
//! * [`engine`] — the parallel tuning engine: a batched profiling
//!   executor (worker pool, `--jobs` configurable, deterministic traces
//!   for any worker count), a `(layer, schedule)` compile cache that
//!   kills the A-stage double compilation, and a network-level scheduler
//!   (`tune-net`) that splits one global budget across all layers with a
//!   UCB allocator.
//! * [`obs`] — observability: the always-on telemetry [`obs::Recorder`]
//!   (atomic counters, span timers, duration histograms shared across
//!   the worker pool), the versioned JSONL event sink behind
//!   `--metrics-out`, the leveled console sink (`--quiet`/`-v`), and
//!   the `ml2tuner report` aggregator. Telemetry observes, never
//!   participates: traces are byte-identical with and without it.
//! * [`serve`] — tuning-as-a-service: the persistent best-schedule store
//!   ([`serve::ScheduleDb`], appended to by every `--schedule-db` tuning
//!   run) and the `serve` daemon that answers schedule queries instantly
//!   from it, falling back to warm-started tuning jobs on a bounded
//!   worker pool over one shared engine on a miss.
//! * [`experiments`] — one harness per paper table/figure (Fig 2–5,
//!   Table 2b/4/5, headline metrics) plus the beyond-paper `transfer`
//!   study (cold vs warm sample-efficiency) and the `storm` serving
//!   stress harness (lookup-latency percentiles under mixed hit/miss
//!   query load).

#![warn(missing_docs)]

pub mod compiler;
pub mod engine;
pub mod experiments;
pub mod gbdt;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod tuner;
pub mod util;
pub mod vta;
pub mod workloads;

/// Convenient re-exports for examples and binaries.
pub mod prelude {
    pub use crate::compiler::schedule::{ConfigSpace, Schedule, SpaceKind};
    pub use crate::compiler::Compiler;
    pub use crate::engine::Engine;
    pub use crate::gbdt::params::GbdtParams;
    pub use crate::gbdt::Booster;
    pub use crate::util::rng::Rng;
    pub use crate::vta::{config::VtaConfig, Simulator};
    pub use crate::workloads::resnet18::{self, ConvLayer};
    pub use crate::workloads::{network, Network};
}
