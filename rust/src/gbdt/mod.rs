//! From-scratch gradient-boosted decision trees — the paper's cost-model
//! family (XGBoost v2.1.1, paper §3) rebuilt on the second-order boosting
//! formulation:
//!
//! * histogram split finding over quantile-binned features
//!   ([`dataset::BinnedDataset`]);
//! * objectives `reg:squarederror`, `binary:logistic`, `binary:hinge`,
//!   `rank:pairwise` ([`objective::Objective`] — the Table 3/4 surface);
//! * regularization: `max_depth`, `min_child_weight`, `gamma`, `subsample`,
//!   `colsample_bytree`, `learning_rate`, `reg_alpha` (L1 on leaves, via
//!   soft thresholding) and `reg_lambda` ([`params::GbdtParams`]);
//! * gain-based feature importance for the Table 5 report;
//! * a flattened SoA inference layout ([`flat::FlatEnsemble`], built by
//!   [`Booster::flatten`]) with a batched `predict` over a reusable
//!   row-major [`dataset::FeatureMatrix`] — the explorer's scoring-sweep
//!   hot path; outputs are bit-identical to the per-row walk;
//! * one training entry point, [`Booster::fit`], whose [`TrainOpts`]
//!   compose per-row weights, ranking groups, and warm continuation
//!   (append rounds on top of a trained base — bit-identical to a longer
//!   fresh fit when the record set is unchanged), plus JSON
//!   serialization for the corpus-trained meta-model artifacts.

pub mod booster;
pub mod dataset;
pub mod flat;
pub mod objective;
pub mod params;
pub mod tree;

pub use booster::{Booster, TrainOpts};
pub use dataset::{Dataset, FeatureMatrix};
pub use flat::FlatEnsemble;
pub use objective::Objective;
pub use params::GbdtParams;
