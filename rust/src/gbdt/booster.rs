//! Gradient boosting driver + evaluation metrics.
//!
//! Training goes through exactly one entry point, [`Booster::fit`]: per-row
//! weights, ranking groups, and warm continuation from a previously trained
//! ensemble are composable [`TrainOpts`] rather than separate `train_*`
//! methods. Continuation replays the base booster's subsampling RNG stream
//! and rebuilds its margins tree-at-a-time, so appending `k` rounds to an
//! `r`-round base on an unchanged dataset is bit-identical to training
//! `r + k` rounds from scratch (pinned by tests here and in
//! `tests/meta_training.rs`).

use anyhow::{bail, Context, Result};

use super::dataset::{BinnedDataset, Dataset};
use super::flat::FlatEnsemble;
use super::objective::Objective;
use super::params::GbdtParams;
use super::tree::{grow, GrowCfg, Node, Tree};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Composable options for one [`Booster::fit`] call. The default is plain
/// cold training: no weights, one ranking group, no continuation base.
#[derive(Clone, Copy, Default)]
pub struct TrainOpts<'a> {
    /// Per-row sample weights: each row's gradient and hessian are scaled
    /// by its weight, so a 0.25-weighted row pulls every split and leaf
    /// value a quarter as hard as a full row (the multi-fidelity label
    /// path — coarse tier-0 estimates train at
    /// [`crate::tuner::database::COARSE_LABEL_WEIGHT`]). `None` is
    /// bit-identical to all-ones.
    pub weights: Option<&'a [f64]>,
    /// Ranking query-group sizes (summing to `n_rows`); `None` ⇒ one group.
    pub groups: Option<&'a [usize]>,
    /// Warm-continuation base: keep its trees and `base_score`, append
    /// `params.boost_rounds` new trees on top of its margins. All other
    /// hyper-parameters (binning, depth, subsampling, seed) come from the
    /// base so the appended trees see exactly the stream a longer fresh
    /// run would have seen.
    pub init: Option<&'a Booster>,
}

impl<'a> TrainOpts<'a> {
    /// Cold training with per-row weights.
    pub fn weighted(weights: Option<&'a [f64]>) -> Self {
        TrainOpts { weights, ..Default::default() }
    }

    /// Continue from a previously trained ensemble.
    pub fn continuing(base: &'a Booster) -> Self {
        TrainOpts { init: Some(base), ..Default::default() }
    }
}

/// A trained ensemble.
#[derive(Clone, Debug)]
pub struct Booster {
    /// Hyper-parameters the ensemble was trained with.
    pub params: GbdtParams,
    /// Initial raw prediction every tree sum starts from.
    pub base_score: f64,
    /// The boosted trees, training order.
    pub trees: Vec<Tree>,
    /// Feature-vector width the ensemble expects.
    pub n_features: usize,
}

impl Booster {
    /// Train on `data`. With `opts.init` set this is warm continuation:
    /// the base's trees are kept, `params.boost_rounds` more are appended
    /// (every other field of `params` is ignored in favor of the base's),
    /// and on an unchanged dataset the result is bit-identical to a
    /// from-scratch fit of the combined round count.
    pub fn fit(params: &GbdtParams, data: &Dataset, opts: &TrainOpts) -> Booster {
        assert!(data.n_rows > 0, "empty training set");
        if let Some(w) = opts.weights {
            assert_eq!(w.len(), data.n_rows, "one weight per row");
        }
        static NO_TREES: &[Tree] = &[];
        let (eff, base_trees, init_score) = match opts.init {
            Some(b) => {
                assert_eq!(
                    b.n_features, data.n_features,
                    "continuation base expects {} features, data has {}",
                    b.n_features, data.n_features
                );
                let eff = GbdtParams {
                    boost_rounds: params.boost_rounds,
                    ..b.params.clone()
                };
                (eff, b.trees.as_slice(), Some(b.base_score))
            }
            None => (params.clone(), NO_TREES, None),
        };
        let binned = BinnedDataset::bin(data, eff.max_bins);
        let mut rng = Rng::new(eff.seed ^ 0x9bd1_77c3);
        let base = init_score
            .unwrap_or_else(|| eff.objective.base_score(&data.labels));
        let mut preds = vec![base; data.n_rows];
        // Continuation: replay the base's per-round subsampling draws so
        // the appended rounds consume the stream from where a fresh
        // `base + appended`-round run would, then rebuild the base's
        // margins one tree at a time — the exact per-row adds training
        // performed, so `preds` is bitwise what round `base_trees.len()`
        // saw when the record set is unchanged.
        for _ in 0..base_trees.len() {
            if eff.subsample < 1.0 {
                let k = ((data.n_rows as f64 * eff.subsample).ceil()
                    as usize)
                    .clamp(1, data.n_rows);
                rng.sample_indices(data.n_rows, k);
            }
            if eff.colsample_bytree < 1.0 {
                let k = ((data.n_features as f64 * eff.colsample_bytree)
                    .ceil() as usize)
                    .clamp(1, data.n_features);
                rng.sample_indices(data.n_features, k);
            }
        }
        for tree in base_trees {
            FlatEnsemble::from_trees(data.n_features, 0.0,
                                     std::slice::from_ref(tree))
                .accumulate_dataset(data, &mut preds);
        }
        let mut grad: Vec<f64> = Vec::new();
        let mut hess: Vec<f64> = Vec::new();
        let grow_cfg = GrowCfg {
            max_depth: eff.max_depth,
            min_child_weight: eff.min_child_weight,
            gamma: eff.gamma,
            reg_alpha: eff.reg_alpha,
            reg_lambda: eff.reg_lambda,
            learning_rate: eff.learning_rate,
        };
        let all_rows: Vec<u32> = (0..data.n_rows as u32).collect();
        let all_feats: Vec<u32> = (0..data.n_features as u32).collect();
        let mut trees = Vec::with_capacity(base_trees.len()
            + eff.boost_rounds);
        trees.extend_from_slice(base_trees);
        for _round in 0..eff.boost_rounds {
            eff.objective.grad_hess(
                &preds, &data.labels, opts.groups, &mut grad, &mut hess,
            );
            if let Some(w) = opts.weights {
                for i in 0..data.n_rows {
                    grad[i] *= w[i];
                    hess[i] *= w[i];
                }
            }
            // row subsampling
            let rows: Vec<u32> = if eff.subsample < 1.0 {
                let k = ((data.n_rows as f64 * eff.subsample).ceil()
                    as usize)
                    .clamp(1, data.n_rows);
                rng.sample_indices(data.n_rows, k)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect()
            } else {
                all_rows.clone()
            };
            // feature subsampling
            let feats: Vec<u32> = if eff.colsample_bytree < 1.0 {
                let k = ((data.n_features as f64
                    * eff.colsample_bytree)
                    .ceil() as usize)
                    .clamp(1, data.n_features);
                rng.sample_indices(data.n_features, k)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect()
            } else {
                all_feats.clone()
            };
            let tree = grow(&binned, &grad, &hess, &rows, &feats,
                            &grow_cfg);
            // margin update through the flattened single-tree layout
            // (same per-row adds, SoA traversal)
            FlatEnsemble::from_trees(data.n_features, 0.0,
                                     std::slice::from_ref(&tree))
                .accumulate_dataset(data, &mut preds);
            trees.push(tree);
        }
        Booster {
            params: GbdtParams { boost_rounds: trees.len(), ..eff },
            base_score: base,
            trees,
            n_features: data.n_features,
        }
    }

    /// Raw score for one feature row.
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        let rowf: Vec<f32> = row.iter().map(|&v| v as f32).collect();
        self.predict_row_f32(&rowf)
    }

    /// Raw score for one `f32` feature row (the hot-path layout).
    #[inline]
    pub fn predict_row_f32(&self, row: &[f32]) -> f64 {
        let mut s = self.base_score;
        for t in &self.trees {
            s += t.predict_row(row);
        }
        s
    }

    /// Flatten into the SoA inference layout. Batched predictions over
    /// a [`crate::gbdt::FeatureMatrix`] are bit-identical to
    /// [`Booster::predict_row`]; this replaced the old
    /// `predict(&[Vec<f64>])` row-of-Vecs path.
    pub fn flatten(&self) -> FlatEnsemble {
        FlatEnsemble::from_trees(self.n_features, self.base_score,
                                 &self.trees)
    }

    /// Binary decision using the objective's raw-score threshold.
    pub fn predict_binary(&self, row: &[f64]) -> bool {
        self.predict_row(row) > self.params.objective.decision_threshold()
    }

    /// Gain-based feature importance, normalized to percentages
    /// (paper Table 5's "Normalized Feature Importance Score (%)").
    pub fn feature_importance(&self) -> Vec<f64> {
        let mut gains = vec![0.0; self.n_features];
        for t in &self.trees {
            t.add_gains(&mut gains);
        }
        let total: f64 = gains.iter().sum();
        if total > 0.0 {
            for g in gains.iter_mut() {
                *g *= 100.0 / total;
            }
        }
        gains
    }

    // -------------------------------------------------- serialization ---

    /// Serialize the full ensemble (hyper-parameters, base score, trees)
    /// for the meta-model artifact. Node fields round-trip exactly: the
    /// JSON writer prints integral `f64`s as integers and everything else
    /// with enough digits to re-parse bit-identically, and thresholds are
    /// `f32` (exact in `f64`).
    pub fn to_json(&self) -> Json {
        let p = &self.params;
        let mut pj = Json::obj();
        pj.set("objective", p.objective.name())
            .set("boost_rounds", p.boost_rounds as i64)
            .set("max_depth", p.max_depth as i64)
            .set("min_child_weight", p.min_child_weight)
            .set("gamma", p.gamma)
            .set("subsample", p.subsample)
            .set("colsample_bytree", p.colsample_bytree)
            .set("learning_rate", p.learning_rate)
            .set("reg_alpha", p.reg_alpha)
            .set("reg_lambda", p.reg_lambda)
            .set("max_bins", p.max_bins as i64)
            // decimal string: u64 seeds above 2^53 don't fit an f64
            .set("seed", p.seed.to_string());
        let trees: Vec<Json> = self
            .trees
            .iter()
            .map(|t| {
                Json::Arr(
                    t.nodes
                        .iter()
                        .map(|n| {
                            Json::Arr(vec![
                                Json::Num(n.feature as f64),
                                Json::Num(n.threshold as f64),
                                Json::Num(n.left as f64),
                                Json::Num(n.right as f64),
                                Json::Num(n.value),
                                Json::Num(n.gain),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        let mut j = Json::obj();
        j.set("params", pj)
            .set("base_score", self.base_score)
            .set("n_features", self.n_features as i64)
            .set("trees", Json::Arr(trees));
        j
    }

    /// Inverse of [`Booster::to_json`]. Strict: every hyper-parameter and
    /// node field must be present and well-typed.
    pub fn from_json(j: &Json) -> Result<Booster> {
        let pj = j.get("params").context("booster missing 'params'")?;
        let num = |k: &str| -> Result<f64> {
            pj.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("booster params missing '{k}'"))
        };
        let objective = pj
            .get("objective")
            .and_then(Json::as_str)
            .and_then(Objective::parse_name)
            .context("booster params missing a known 'objective'")?;
        let seed: u64 = pj
            .get("seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse().ok())
            .context("booster params missing decimal-string 'seed'")?;
        let params = GbdtParams {
            objective,
            boost_rounds: num("boost_rounds")? as usize,
            max_depth: num("max_depth")? as usize,
            min_child_weight: num("min_child_weight")?,
            gamma: num("gamma")?,
            subsample: num("subsample")?,
            colsample_bytree: num("colsample_bytree")?,
            learning_rate: num("learning_rate")?,
            reg_alpha: num("reg_alpha")?,
            reg_lambda: num("reg_lambda")?,
            max_bins: num("max_bins")? as usize,
            seed,
        };
        let base_score = j
            .get("base_score")
            .and_then(Json::as_f64)
            .context("booster missing 'base_score'")?;
        let n_features = j
            .get("n_features")
            .and_then(Json::as_usize)
            .context("booster missing 'n_features'")?;
        let mut trees = Vec::new();
        for tj in j
            .get("trees")
            .and_then(Json::as_arr)
            .context("booster missing 'trees'")?
        {
            let njs = tj.as_arr().context("tree must be a node array")?;
            let mut nodes = Vec::with_capacity(njs.len());
            for nj in njs {
                let a = nj.as_arr().context("node must be an array")?;
                if a.len() != 6 {
                    bail!("node must have 6 fields, got {}", a.len());
                }
                let f = |i: usize| -> Result<f64> {
                    a[i].as_f64().context("non-numeric node field")
                };
                nodes.push(Node {
                    feature: f(0)? as u32,
                    threshold: f(1)? as f32,
                    left: f(2)? as u32,
                    right: f(3)? as u32,
                    value: f(4)?,
                    gain: f(5)?,
                });
            }
            trees.push(Tree { nodes });
        }
        Ok(Booster { params, base_score, trees, n_features })
    }
}

// ------------------------------------------------------------- metrics ---

/// Fraction of test pairs ordered consistently with the labels — the
/// "accuracy" we report for regression/ranking models in Table 4.
pub fn pairwise_accuracy(preds: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    let n = preds.len();
    let mut ok = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in i + 1..n {
            if labels[i] == labels[j] {
                continue;
            }
            total += 1;
            if (labels[i] > labels[j]) == (preds[i] > preds[j]) {
                ok += 1;
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        ok as f64 / total as f64
    }
}

/// Binary classification accuracy at the objective's raw threshold.
pub fn binary_accuracy(
    obj: Objective,
    preds_raw: &[f64],
    labels: &[f64],
) -> f64 {
    assert_eq!(preds_raw.len(), labels.len());
    if preds_raw.is_empty() {
        return 1.0;
    }
    let thr = obj.decision_threshold();
    let ok = preds_raw
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| (p > thr) == (y > 0.5))
        .count();
    ok as f64 / preds_raw.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::dataset::FeatureMatrix;
    use crate::util::stats;

    fn cold(params: &GbdtParams, data: &Dataset) -> Booster {
        Booster::fit(params, data, &TrainOpts::default())
    }

    /// Batched predictions via the flattened layout (the replacement
    /// for the removed `Booster::predict(&[Vec<f64>])`).
    fn predict_all(b: &Booster, rows: &[Vec<f64>]) -> Vec<f64> {
        b.flatten().predict_batch(&FeatureMatrix::from_rows(rows))
    }

    fn synth_regression(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut r = Rng::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![r.range_f64(0.0, 4.0), r.range_f64(0.0, 4.0),
                          r.range_f64(0.0, 1.0)])
            .collect();
        let labels: Vec<f64> = rows
            .iter()
            .map(|x| x[0] * x[0] + 3.0 * x[1] + 0.05 * x[2])
            .collect();
        (rows, labels)
    }

    #[test]
    fn regression_fits_smooth_function() {
        let (rows, labels) = synth_regression(400, 1);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 120,
            max_depth: 5,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let (test_rows, test_labels) = synth_regression(200, 2);
        let preds = predict_all(&b, &test_rows);
        let rmse = stats::rmse(&preds, &test_labels);
        let spread = stats::std_dev(&test_labels);
        assert!(rmse < 0.25 * spread, "rmse={rmse}, spread={spread}");
    }

    #[test]
    fn logistic_classifies() {
        let mut r = Rng::new(3);
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![r.range_f64(-2.0, 2.0), r.range_f64(-2.0, 2.0)])
            .collect();
        let labels: Vec<f64> = rows
            .iter()
            .map(|x| (x[0] + x[1] > 0.0) as u8 as f64)
            .collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::Logistic,
            boost_rounds: 60,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = binary_accuracy(Objective::Logistic, &preds, &labels);
        assert!(acc > 0.95, "acc={acc}");
        // transformed raw scores are probabilities
        let probs: Vec<f64> = preds
            .iter()
            .map(|&p| b.params.objective.transform(p))
            .collect();
        assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn hinge_classifies() {
        let mut r = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![r.range_f64(0.0, 10.0)])
            .collect();
        let labels: Vec<f64> =
            rows.iter().map(|x| (x[0] > 6.0) as u8 as f64).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::Hinge,
            boost_rounds: 40,
            max_depth: 3,
            learning_rate: 0.3,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = binary_accuracy(Objective::Hinge, &preds, &labels);
        assert!(acc > 0.97, "acc={acc}");
    }

    #[test]
    fn rank_orders_items() {
        let mut r = Rng::new(7);
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|_| vec![r.range_f64(0.0, 1.0), r.range_f64(0.0, 1.0)])
            .collect();
        let labels: Vec<f64> =
            rows.iter().map(|x| 5.0 * x[0] + x[1]).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::RankPairwise,
            boost_rounds: 40,
            max_depth: 4,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = pairwise_accuracy(&preds, &labels);
        assert!(acc > 0.9, "pairwise acc={acc}");
    }

    #[test]
    fn subsampling_still_learns() {
        let (rows, labels) = synth_regression(500, 11);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 150,
            max_depth: 5,
            learning_rate: 0.2,
            subsample: 0.6,
            colsample_bytree: 0.6,
            seed: 4,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = pairwise_accuracy(&preds, &labels);
        assert!(acc > 0.93, "acc={acc}");
    }

    #[test]
    fn importance_finds_the_signal_feature() {
        let (rows, labels) = synth_regression(400, 13);
        let d = Dataset::from_rows(&rows, &labels);
        let b = cold(
            &GbdtParams { boost_rounds: 50, max_depth: 4,
                          learning_rate: 0.2, ..Default::default() },
            &d,
        );
        let imp = b.feature_importance();
        assert_eq!(imp.len(), 3);
        assert!((imp.iter().sum::<f64>() - 100.0).abs() < 1e-6);
        // feature 2 has coefficient 0.05 — near-noise
        assert!(imp[0] > imp[2] && imp[1] > imp[2], "{imp:?}");
    }

    #[test]
    fn training_is_deterministic_per_seed() {
        let (rows, labels) = synth_regression(100, 17);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams { boost_rounds: 10, subsample: 0.7, seed: 9,
                             ..Default::default() };
        let a = cold(&p, &d);
        let b = cold(&p, &d);
        assert_eq!(predict_all(&a, &rows), predict_all(&b, &rows));
    }

    #[test]
    fn flattened_batch_matches_per_row_bitwise() {
        let (rows, labels) = synth_regression(300, 21);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 60,
            max_depth: 5,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = cold(&p, &d);
        let batch = predict_all(&b, &rows);
        assert_eq!(batch.len(), rows.len());
        for (r, &s) in rows.iter().zip(&batch) {
            assert_eq!(b.predict_row(r).to_bits(), s.to_bits());
        }
    }

    #[test]
    fn weighted_training_none_is_bit_identical_and_weights_pull() {
        let (rows, labels) = synth_regression(200, 23);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams { boost_rounds: 40, max_depth: 4,
                             learning_rate: 0.2, ..Default::default() };
        let plain = cold(&p, &d);
        let none = Booster::fit(&p, &d, &TrainOpts::weighted(None));
        let a = predict_all(&plain, &rows);
        let b = predict_all(&none, &rows);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "weights: None must not perturb training");
        }
        // duplicate the data with the copy's labels shifted +10; with
        // the corrupted half near-zero-weighted, predictions track the
        // clean labels far more closely than under uniform weights
        let mut rows2 = rows.clone();
        rows2.extend(rows.iter().cloned());
        let mut labels2 = labels.clone();
        labels2.extend(labels.iter().map(|y| y + 10.0));
        let d2 = Dataset::from_rows(&rows2, &labels2);
        let mut w = vec![1.0; labels.len()];
        w.extend(std::iter::repeat(0.01).take(labels.len()));
        let down = Booster::fit(&p, &d2, &TrainOpts::weighted(Some(&w)));
        let uniform = cold(&p, &d2);
        let err = |b: &Booster| {
            stats::rmse(&predict_all(b, &rows), &labels)
        };
        assert!(err(&down) < 0.5 * err(&uniform),
                "down-weighting must mute the corrupted labels: {} vs {}",
                err(&down), err(&uniform));
    }

    /// base(r) + continue(k) on the same dataset ≡ fresh train(r+k),
    /// bitwise — both for the deterministic P/A-style parameters and for
    /// V-style row/column subsampling (which needs the RNG-draw replay).
    #[test]
    fn continuation_matches_fresh_training_bitwise() {
        let (rows, labels) = synth_regression(250, 29);
        let d = Dataset::from_rows(&rows, &labels);
        let shapes = [
            GbdtParams { max_depth: 5, learning_rate: 0.2, seed: 6,
                         ..Default::default() },
            GbdtParams { max_depth: 4, learning_rate: 0.2,
                         subsample: 0.6, colsample_bytree: 0.7, seed: 6,
                         ..Default::default() },
        ];
        for p in shapes {
            let base = cold(&p.clone().with_rounds(20), &d);
            let cont = Booster::fit(&p.clone().with_rounds(15), &d,
                                    &TrainOpts::continuing(&base));
            let fresh = cold(&p.clone().with_rounds(35), &d);
            assert_eq!(cont.trees.len(), 35);
            assert_eq!(cont.base_score.to_bits(),
                       fresh.base_score.to_bits());
            for (a, b) in predict_all(&cont, &rows)
                .iter()
                .zip(&predict_all(&fresh, &rows))
            {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "continuation must be bit-identical \
                            (subsample={})", p.subsample);
            }
            // and continuation composes: two 5-round extensions on top
            // of the 35-tree chain still match a fresh 45-round fit
            let cont2 = Booster::fit(&p.clone().with_rounds(5), &d,
                                     &TrainOpts::continuing(&cont));
            let cont3 = Booster::fit(&p.clone().with_rounds(5), &d,
                                     &TrainOpts::continuing(&cont2));
            let fresh45 = cold(&p.clone().with_rounds(45), &d);
            for (a, b) in predict_all(&cont3, &rows)
                .iter()
                .zip(&predict_all(&fresh45, &rows))
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// Continuation keeps the base's `base_score` even when the labels
    /// grew (the incremental per-round path: margins shift via appended
    /// trees, not via a recomputed intercept).
    #[test]
    fn continuation_on_grown_data_appends_and_keeps_base_score() {
        let (rows, labels) = synth_regression(120, 31);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams { boost_rounds: 12, max_depth: 4,
                             learning_rate: 0.2, ..Default::default() };
        let base = cold(&p, &d);
        let (more_rows, more_labels) = synth_regression(40, 37);
        let mut rows2 = rows.clone();
        rows2.extend(more_rows);
        let mut labels2 = labels.clone();
        labels2.extend(more_labels);
        let d2 = Dataset::from_rows(&rows2, &labels2);
        let cont = Booster::fit(&p.clone().with_rounds(6), &d2,
                                &TrainOpts::continuing(&base));
        assert_eq!(cont.trees.len(), base.trees.len() + 6);
        assert_eq!(cont.base_score.to_bits(), base.base_score.to_bits());
        // the appended trees still reduce error on the grown set
        let err = |b: &Booster| {
            stats::rmse(&predict_all(b, &rows2), &labels2)
        };
        assert!(err(&cont) < err(&base),
                "appended trees must fit the new rows: {} vs {}",
                err(&cont), err(&base));
    }

    #[test]
    fn pairwise_accuracy_bounds() {
        assert_eq!(pairwise_accuracy(&[1.0, 2.0], &[1.0, 2.0]), 1.0);
        assert_eq!(pairwise_accuracy(&[2.0, 1.0], &[1.0, 2.0]), 0.0);
        assert_eq!(pairwise_accuracy(&[], &[]), 1.0);
    }

    #[test]
    fn json_round_trip_is_bit_identical() {
        let (rows, labels) = synth_regression(150, 41);
        let p = GbdtParams {
            objective: Objective::Hinge,
            boost_rounds: 25,
            subsample: 0.6,
            colsample_bytree: 0.6,
            seed: u64::MAX - 7, // above 2^53: exercises the string seed
            ..Default::default()
        };
        let labels01: Vec<f64> =
            labels.iter().map(|&y| (y > 8.0) as u8 as f64).collect();
        let b = cold(&p, &Dataset::from_rows(&rows, &labels01));
        let text = b.to_json().to_string_pretty();
        let back = Booster::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.params, b.params);
        assert_eq!(back.n_features, b.n_features);
        assert_eq!(back.trees, b.trees);
        assert_eq!(back.base_score.to_bits(), b.base_score.to_bits());
        for r in &rows {
            assert_eq!(back.predict_row(r).to_bits(),
                       b.predict_row(r).to_bits());
        }
        // and a deserialized base continues bit-identically
        let cont_a = Booster::fit(&p.clone().with_rounds(5),
                                  &Dataset::from_rows(&rows, &labels01),
                                  &TrainOpts::continuing(&b));
        let cont_b = Booster::fit(&p.clone().with_rounds(5),
                                  &Dataset::from_rows(&rows, &labels01),
                                  &TrainOpts::continuing(&back));
        for r in &rows {
            assert_eq!(cont_a.predict_row(r).to_bits(),
                       cont_b.predict_row(r).to_bits());
        }
    }
}
