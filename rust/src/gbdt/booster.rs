//! Gradient boosting driver + evaluation metrics.

use super::dataset::{BinnedDataset, Dataset};
use super::flat::FlatEnsemble;
use super::objective::Objective;
use super::params::GbdtParams;
use super::tree::{grow, GrowCfg, Tree};
use crate::util::rng::Rng;

/// A trained ensemble.
#[derive(Clone, Debug)]
pub struct Booster {
    /// Hyper-parameters the ensemble was trained with.
    pub params: GbdtParams,
    /// Initial raw prediction every tree sum starts from.
    pub base_score: f64,
    /// The boosted trees, training order.
    pub trees: Vec<Tree>,
    /// Feature-vector width the ensemble expects.
    pub n_features: usize,
}

impl Booster {
    /// Train on `data` (optionally with ranking groups).
    pub fn train(params: &GbdtParams, data: &Dataset) -> Booster {
        Self::train_impl(params, data, None, None)
    }

    /// Train with per-row sample weights: each row's gradient and
    /// hessian are scaled by its weight, so a 0.25-weighted row pulls
    /// every split and leaf value a quarter as hard as a full row (the
    /// multi-fidelity label path — coarse tier-0 estimates train at
    /// [`crate::tuner::database::COARSE_LABEL_WEIGHT`]). `weights:
    /// None` is bit-identical to [`Booster::train`].
    pub fn train_weighted(
        params: &GbdtParams,
        data: &Dataset,
        weights: Option<&[f64]>,
    ) -> Booster {
        Self::train_impl(params, data, None, weights)
    }

    /// Train with explicit ranking query groups (sizes summing to n_rows).
    pub fn train_grouped(
        params: &GbdtParams,
        data: &Dataset,
        groups: Option<&[usize]>,
    ) -> Booster {
        Self::train_impl(params, data, groups, None)
    }

    fn train_impl(
        params: &GbdtParams,
        data: &Dataset,
        groups: Option<&[usize]>,
        weights: Option<&[f64]>,
    ) -> Booster {
        assert!(data.n_rows > 0, "empty training set");
        if let Some(w) = weights {
            assert_eq!(w.len(), data.n_rows, "one weight per row");
        }
        let binned = BinnedDataset::bin(data, params.max_bins);
        let mut rng = Rng::new(params.seed ^ 0x9bd1_77c3);
        let base = params.objective.base_score(&data.labels);
        let mut preds = vec![base; data.n_rows];
        let mut grad: Vec<f64> = Vec::new();
        let mut hess: Vec<f64> = Vec::new();
        let grow_cfg = GrowCfg {
            max_depth: params.max_depth,
            min_child_weight: params.min_child_weight,
            gamma: params.gamma,
            reg_alpha: params.reg_alpha,
            reg_lambda: params.reg_lambda,
            learning_rate: params.learning_rate,
        };
        let all_rows: Vec<u32> = (0..data.n_rows as u32).collect();
        let all_feats: Vec<u32> = (0..data.n_features as u32).collect();
        let mut trees = Vec::with_capacity(params.boost_rounds);
        for _round in 0..params.boost_rounds {
            params.objective.grad_hess(
                &preds, &data.labels, groups, &mut grad, &mut hess,
            );
            if let Some(w) = weights {
                for i in 0..data.n_rows {
                    grad[i] *= w[i];
                    hess[i] *= w[i];
                }
            }
            // row subsampling
            let rows: Vec<u32> = if params.subsample < 1.0 {
                let k = ((data.n_rows as f64 * params.subsample).ceil()
                    as usize)
                    .clamp(1, data.n_rows);
                rng.sample_indices(data.n_rows, k)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect()
            } else {
                all_rows.clone()
            };
            // feature subsampling
            let feats: Vec<u32> = if params.colsample_bytree < 1.0 {
                let k = ((data.n_features as f64
                    * params.colsample_bytree)
                    .ceil() as usize)
                    .clamp(1, data.n_features);
                rng.sample_indices(data.n_features, k)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect()
            } else {
                all_feats.clone()
            };
            let tree = grow(&binned, &grad, &hess, &rows, &feats,
                            &grow_cfg);
            // margin update through the flattened single-tree layout
            // (same per-row adds, SoA traversal)
            FlatEnsemble::from_trees(data.n_features, 0.0,
                                     std::slice::from_ref(&tree))
                .accumulate_dataset(data, &mut preds);
            trees.push(tree);
        }
        Booster {
            params: params.clone(),
            base_score: base,
            trees,
            n_features: data.n_features,
        }
    }

    /// Raw score for one feature row.
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        let rowf: Vec<f32> = row.iter().map(|&v| v as f32).collect();
        self.predict_row_f32(&rowf)
    }

    /// Raw score for one `f32` feature row (the hot-path layout).
    #[inline]
    pub fn predict_row_f32(&self, row: &[f32]) -> f64 {
        let mut s = self.base_score;
        for t in &self.trees {
            s += t.predict_row(row);
        }
        s
    }

    /// Flatten into the SoA inference layout. Batched predictions over
    /// a [`crate::gbdt::FeatureMatrix`] are bit-identical to
    /// [`Booster::predict_row`]; this replaced the old
    /// `predict(&[Vec<f64>])` row-of-Vecs path.
    pub fn flatten(&self) -> FlatEnsemble {
        FlatEnsemble::from_trees(self.n_features, self.base_score,
                                 &self.trees)
    }

    /// Binary decision using the objective's raw-score threshold.
    pub fn predict_binary(&self, row: &[f64]) -> bool {
        self.predict_row(row) > self.params.objective.decision_threshold()
    }

    /// Gain-based feature importance, normalized to percentages
    /// (paper Table 5's "Normalized Feature Importance Score (%)").
    pub fn feature_importance(&self) -> Vec<f64> {
        let mut gains = vec![0.0; self.n_features];
        for t in &self.trees {
            t.add_gains(&mut gains);
        }
        let total: f64 = gains.iter().sum();
        if total > 0.0 {
            for g in gains.iter_mut() {
                *g *= 100.0 / total;
            }
        }
        gains
    }
}

// ------------------------------------------------------------- metrics ---

/// Fraction of test pairs ordered consistently with the labels — the
/// "accuracy" we report for regression/ranking models in Table 4.
pub fn pairwise_accuracy(preds: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    let n = preds.len();
    let mut ok = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in i + 1..n {
            if labels[i] == labels[j] {
                continue;
            }
            total += 1;
            if (labels[i] > labels[j]) == (preds[i] > preds[j]) {
                ok += 1;
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        ok as f64 / total as f64
    }
}

/// Binary classification accuracy at the objective's raw threshold.
pub fn binary_accuracy(
    obj: Objective,
    preds_raw: &[f64],
    labels: &[f64],
) -> f64 {
    assert_eq!(preds_raw.len(), labels.len());
    if preds_raw.is_empty() {
        return 1.0;
    }
    let thr = obj.decision_threshold();
    let ok = preds_raw
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| (p > thr) == (y > 0.5))
        .count();
    ok as f64 / preds_raw.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::dataset::FeatureMatrix;
    use crate::util::stats;

    /// Batched predictions via the flattened layout (the replacement
    /// for the removed `Booster::predict(&[Vec<f64>])`).
    fn predict_all(b: &Booster, rows: &[Vec<f64>]) -> Vec<f64> {
        b.flatten().predict_batch(&FeatureMatrix::from_rows(rows))
    }

    fn synth_regression(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut r = Rng::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![r.range_f64(0.0, 4.0), r.range_f64(0.0, 4.0),
                          r.range_f64(0.0, 1.0)])
            .collect();
        let labels: Vec<f64> = rows
            .iter()
            .map(|x| x[0] * x[0] + 3.0 * x[1] + 0.05 * x[2])
            .collect();
        (rows, labels)
    }

    #[test]
    fn regression_fits_smooth_function() {
        let (rows, labels) = synth_regression(400, 1);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 120,
            max_depth: 5,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let (test_rows, test_labels) = synth_regression(200, 2);
        let preds = predict_all(&b, &test_rows);
        let rmse = stats::rmse(&preds, &test_labels);
        let spread = stats::std_dev(&test_labels);
        assert!(rmse < 0.25 * spread, "rmse={rmse}, spread={spread}");
    }

    #[test]
    fn logistic_classifies() {
        let mut r = Rng::new(3);
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![r.range_f64(-2.0, 2.0), r.range_f64(-2.0, 2.0)])
            .collect();
        let labels: Vec<f64> = rows
            .iter()
            .map(|x| (x[0] + x[1] > 0.0) as u8 as f64)
            .collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::Logistic,
            boost_rounds: 60,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = binary_accuracy(Objective::Logistic, &preds, &labels);
        assert!(acc > 0.95, "acc={acc}");
        // transformed raw scores are probabilities
        let probs: Vec<f64> = preds
            .iter()
            .map(|&p| b.params.objective.transform(p))
            .collect();
        assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn hinge_classifies() {
        let mut r = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![r.range_f64(0.0, 10.0)])
            .collect();
        let labels: Vec<f64> =
            rows.iter().map(|x| (x[0] > 6.0) as u8 as f64).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::Hinge,
            boost_rounds: 40,
            max_depth: 3,
            learning_rate: 0.3,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = binary_accuracy(Objective::Hinge, &preds, &labels);
        assert!(acc > 0.97, "acc={acc}");
    }

    #[test]
    fn rank_orders_items() {
        let mut r = Rng::new(7);
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|_| vec![r.range_f64(0.0, 1.0), r.range_f64(0.0, 1.0)])
            .collect();
        let labels: Vec<f64> =
            rows.iter().map(|x| 5.0 * x[0] + x[1]).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            objective: Objective::RankPairwise,
            boost_rounds: 40,
            max_depth: 4,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = pairwise_accuracy(&preds, &labels);
        assert!(acc > 0.9, "pairwise acc={acc}");
    }

    #[test]
    fn subsampling_still_learns() {
        let (rows, labels) = synth_regression(500, 11);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 150,
            max_depth: 5,
            learning_rate: 0.2,
            subsample: 0.6,
            colsample_bytree: 0.6,
            seed: 4,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let preds = predict_all(&b, &rows);
        let acc = pairwise_accuracy(&preds, &labels);
        assert!(acc > 0.93, "acc={acc}");
    }

    #[test]
    fn importance_finds_the_signal_feature() {
        let (rows, labels) = synth_regression(400, 13);
        let d = Dataset::from_rows(&rows, &labels);
        let b = Booster::train(
            &GbdtParams { boost_rounds: 50, max_depth: 4,
                          learning_rate: 0.2, ..Default::default() },
            &d,
        );
        let imp = b.feature_importance();
        assert_eq!(imp.len(), 3);
        assert!((imp.iter().sum::<f64>() - 100.0).abs() < 1e-6);
        // feature 2 has coefficient 0.05 — near-noise
        assert!(imp[0] > imp[2] && imp[1] > imp[2], "{imp:?}");
    }

    #[test]
    fn training_is_deterministic_per_seed() {
        let (rows, labels) = synth_regression(100, 17);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams { boost_rounds: 10, subsample: 0.7, seed: 9,
                             ..Default::default() };
        let a = Booster::train(&p, &d);
        let b = Booster::train(&p, &d);
        assert_eq!(predict_all(&a, &rows), predict_all(&b, &rows));
    }

    #[test]
    fn flattened_batch_matches_per_row_bitwise() {
        let (rows, labels) = synth_regression(300, 21);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams {
            boost_rounds: 60,
            max_depth: 5,
            learning_rate: 0.2,
            ..Default::default()
        };
        let b = Booster::train(&p, &d);
        let batch = predict_all(&b, &rows);
        assert_eq!(batch.len(), rows.len());
        for (r, &s) in rows.iter().zip(&batch) {
            assert_eq!(b.predict_row(r).to_bits(), s.to_bits());
        }
    }

    #[test]
    fn weighted_training_none_is_bit_identical_and_weights_pull() {
        let (rows, labels) = synth_regression(200, 23);
        let d = Dataset::from_rows(&rows, &labels);
        let p = GbdtParams { boost_rounds: 40, max_depth: 4,
                             learning_rate: 0.2, ..Default::default() };
        let plain = Booster::train(&p, &d);
        let none = Booster::train_weighted(&p, &d, None);
        let a = predict_all(&plain, &rows);
        let b = predict_all(&none, &rows);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "weights: None must not perturb training");
        }
        // duplicate the data with the copy's labels shifted +10; with
        // the corrupted half near-zero-weighted, predictions track the
        // clean labels far more closely than under uniform weights
        let mut rows2 = rows.clone();
        rows2.extend(rows.iter().cloned());
        let mut labels2 = labels.clone();
        labels2.extend(labels.iter().map(|y| y + 10.0));
        let d2 = Dataset::from_rows(&rows2, &labels2);
        let mut w = vec![1.0; labels.len()];
        w.extend(std::iter::repeat(0.01).take(labels.len()));
        let down = Booster::train_weighted(&p, &d2, Some(&w));
        let uniform = Booster::train(&p, &d2);
        let err = |b: &Booster| {
            stats::rmse(&predict_all(b, &rows), &labels)
        };
        assert!(err(&down) < 0.5 * err(&uniform),
                "down-weighting must mute the corrupted labels: {} vs {}",
                err(&down), err(&uniform));
    }

    #[test]
    fn pairwise_accuracy_bounds() {
        assert_eq!(pairwise_accuracy(&[1.0, 2.0], &[1.0, 2.0]), 1.0);
        assert_eq!(pairwise_accuracy(&[2.0, 1.0], &[1.0, 2.0]), 0.0);
        assert_eq!(pairwise_accuracy(&[], &[]), 1.0);
    }
}
