//! Tabular dataset + quantile binning for histogram split finding, and
//! the reusable [`FeatureMatrix`] input buffer of the batched inference
//! path.

/// Row-major f32 feature matrix *without* labels — the input buffer of
/// [`crate::gbdt::FlatEnsemble`]'s batched prediction kernel.
///
/// Rows are appended (f64 rows are narrowed per element exactly like
/// [`crate::gbdt::Booster::predict_row`] always did) and the backing
/// storage survives [`FeatureMatrix::clear`], so a scoring sweep fills
/// one allocation per chunk instead of one `Vec<f64>` per candidate.
#[derive(Clone, Debug)]
pub struct FeatureMatrix {
    n_features: usize,
    values: Vec<f32>,
}

impl FeatureMatrix {
    /// Empty matrix of the given row width.
    pub fn new(n_features: usize) -> FeatureMatrix {
        FeatureMatrix { n_features, values: Vec::new() }
    }

    /// Preallocate room for `rows` rows.
    pub fn with_capacity(n_features: usize, rows: usize) -> FeatureMatrix {
        FeatureMatrix {
            n_features,
            values: Vec::with_capacity(n_features * rows),
        }
    }

    /// Build from f64 rows (test/experiment convenience; the hot paths
    /// fill a reused matrix incrementally instead).
    pub fn from_rows(rows: &[Vec<f64>]) -> FeatureMatrix {
        let nf = rows.first().map_or(0, |r| r.len());
        let mut m = FeatureMatrix::with_capacity(nf, rows.len());
        for r in rows {
            m.push_row_f64(r);
        }
        m
    }

    /// Row width.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Rows currently held.
    pub fn n_rows(&self) -> usize {
        if self.n_features == 0 {
            0
        } else {
            self.values.len() / self.n_features
        }
    }

    /// Whether the matrix holds no rows.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Drop all rows, keeping the backing allocation.
    pub fn clear(&mut self) {
        self.values.clear();
    }

    /// Append one row, narrowing each value to f32.
    pub fn push_row_f64(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.n_features, "row width");
        self.values.extend(row.iter().map(|&v| v as f32));
    }

    /// Append one f32 row.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.n_features, "row width");
        self.values.extend_from_slice(row);
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.n_features..(i + 1) * self.n_features]
    }

    /// The raw row-major storage (the batch kernel iterates this).
    pub fn values(&self) -> &[f32] {
        &self.values
    }
}

/// Row-major float feature matrix with labels.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    /// Rows currently held.
    pub n_rows: usize,
    /// Row width.
    pub n_features: usize,
    /// `values[row * n_features + f]`.
    pub values: Vec<f32>,
    /// One training label per row.
    pub labels: Vec<f64>,
}

impl Dataset {
    /// Empty dataset of the given row width.
    pub fn new(n_features: usize) -> Self {
        Dataset { n_rows: 0, n_features, values: Vec::new(),
                  labels: Vec::new() }
    }

    /// Append one labelled row, narrowing each value to f32.
    pub fn push(&mut self, row: &[f64], label: f64) {
        assert_eq!(row.len(), self.n_features);
        self.values.extend(row.iter().map(|&v| v as f32));
        self.labels.push(label);
        self.n_rows += 1;
    }

    /// Build from parallel row/label slices.
    pub fn from_rows(rows: &[Vec<f64>], labels: &[f64]) -> Self {
        assert_eq!(rows.len(), labels.len());
        let nf = rows.first().map_or(0, |r| r.len());
        let mut d = Dataset::new(nf);
        for (r, &l) in rows.iter().zip(labels) {
            d.push(r, l);
        }
        d
    }

    /// Borrow row `i` (labels excluded).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.n_features..(i + 1) * self.n_features]
    }
}

/// Quantile-binned view of a dataset (feature-major u8 bin matrix).
#[derive(Clone, Debug)]
pub struct BinnedDataset {
    /// Rows binned.
    pub n_rows: usize,
    /// Features binned.
    pub n_features: usize,
    /// `bins[f * n_rows + row]` — feature-major for histogram locality.
    pub bins: Vec<u8>,
    /// Per-feature ascending cut points; bin `b` ⇔ `x > cuts[b-1] && x <=
    /// cuts[b]`-ish: `bin(x) = #{c in cuts : x > c}`.
    pub cuts: Vec<Vec<f32>>,
}

impl BinnedDataset {
    /// Bin with at most `max_bins` bins per feature (≤ 256).
    pub fn bin(data: &Dataset, max_bins: usize) -> Self {
        assert!((2..=256).contains(&max_bins));
        let (n, nf) = (data.n_rows, data.n_features);
        let mut cuts = Vec::with_capacity(nf);
        let mut bins = vec![0u8; nf * n];
        let mut col: Vec<f32> = Vec::with_capacity(n);
        for f in 0..nf {
            col.clear();
            col.extend((0..n).map(|r| data.values[r * nf + f]));
            let c = quantile_cuts(&mut col.clone(), max_bins - 1);
            for r in 0..n {
                bins[f * n + r] = bin_of(&c, data.values[r * nf + f]);
            }
            cuts.push(c);
        }
        BinnedDataset { n_rows: n, n_features: nf, bins, cuts }
    }

    /// Borrow the bin column of feature `f` (one u8 per row).
    #[inline]
    pub fn feature_bins(&self, f: usize) -> &[u8] {
        &self.bins[f * self.n_rows..(f + 1) * self.n_rows]
    }

    /// Number of bins actually used for feature `f`.
    pub fn n_bins(&self, f: usize) -> usize {
        self.cuts[f].len() + 1
    }
}

/// `#{c in cuts : x > c}` — the bin index of a raw value.
#[inline]
pub fn bin_of(cuts: &[f32], x: f32) -> u8 {
    // cuts are short (≤255); linear scan beats binary search at this size
    let mut b = 0u8;
    for &c in cuts {
        if x > c {
            b += 1;
        } else {
            break;
        }
    }
    b
}

/// Up to `k` cut points between distinct quantiles of `col`.
fn quantile_cuts(col: &mut [f32], k: usize) -> Vec<f32> {
    if col.is_empty() {
        return Vec::new();
    }
    col.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut distinct: Vec<f32> = Vec::new();
    for &v in col.iter() {
        if distinct.last() != Some(&v) {
            distinct.push(v);
        }
    }
    if distinct.len() <= 1 {
        return Vec::new();
    }
    let n_cuts = k.min(distinct.len() - 1);
    let mut cuts = Vec::with_capacity(n_cuts);
    if distinct.len() - 1 <= k {
        // one cut between every pair of adjacent distinct values
        for w in distinct.windows(2) {
            cuts.push((w[0] + w[1]) * 0.5);
        }
    } else {
        for i in 1..=n_cuts {
            let pos = i * (distinct.len() - 1) / (n_cuts + 1);
            cuts.push((distinct[pos] + distinct[pos + 1]) * 0.5);
        }
        cuts.dedup();
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_row() {
        let mut d = Dataset::new(2);
        d.push(&[1.0, 2.0], 0.5);
        d.push(&[3.0, 4.0], 1.5);
        assert_eq!(d.n_rows, 2);
        assert_eq!(d.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn binning_separates_distinct_values() {
        let rows: Vec<Vec<f64>> =
            (0..10).map(|i| vec![i as f64]).collect();
        let labels = vec![0.0; 10];
        let d = Dataset::from_rows(&rows, &labels);
        let b = BinnedDataset::bin(&d, 256);
        // 10 distinct values → 10 bins, each row its own bin
        let bins = b.feature_bins(0);
        let mut sorted = bins.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn binning_respects_max_bins() {
        let rows: Vec<Vec<f64>> =
            (0..1000).map(|i| vec![i as f64]).collect();
        let d = Dataset::from_rows(&rows, &vec![0.0; 1000]);
        let b = BinnedDataset::bin(&d, 16);
        assert!(b.n_bins(0) <= 16);
        // bins are monotone in the raw value
        let bins = b.feature_bins(0);
        for w in bins.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn constant_feature_single_bin() {
        let d = Dataset::from_rows(
            &(0..5).map(|_| vec![7.0]).collect::<Vec<_>>(),
            &vec![0.0; 5],
        );
        let b = BinnedDataset::bin(&d, 256);
        assert_eq!(b.n_bins(0), 1);
        assert!(b.feature_bins(0).iter().all(|&x| x == 0));
    }

    #[test]
    fn feature_matrix_push_row_and_clear() {
        let mut m = FeatureMatrix::new(3);
        assert!(m.is_empty());
        assert_eq!(m.n_rows(), 0);
        m.push_row_f64(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.row(1), &[4.0f32, 5.0, 6.0]);
        assert_eq!(m.values().len(), 6);
        m.clear();
        assert!(m.is_empty());
        m.push_row_f64(&[7.0, 8.0, 9.0]);
        assert_eq!(m.n_rows(), 1);
        assert_eq!(m.row(0), &[7.0f32, 8.0, 9.0]);
    }

    #[test]
    fn feature_matrix_narrows_exactly_like_predict_row() {
        // the f64 → f32 narrowing must match `row as f32` per element
        let rows = vec![vec![0.1f64, 1e9 + 1.0]];
        let m = FeatureMatrix::from_rows(&rows);
        assert_eq!(m.row(0)[0].to_bits(), (0.1f64 as f32).to_bits());
        assert_eq!(m.row(0)[1].to_bits(), ((1e9f64 + 1.0) as f32).to_bits());
    }

    #[test]
    fn bin_of_matches_threshold_semantics() {
        let cuts = vec![1.0f32, 3.0, 5.0];
        assert_eq!(bin_of(&cuts, 0.5), 0);
        assert_eq!(bin_of(&cuts, 1.0), 0); // x <= cut → left
        assert_eq!(bin_of(&cuts, 2.0), 1);
        assert_eq!(bin_of(&cuts, 9.0), 3);
    }
}
