//! Regression tree: histogram-grown, stored flat for fast traversal.

use super::dataset::{BinnedDataset, Dataset};

/// Flat node. Leaves have `feature == u32::MAX`.
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    /// Split feature, or `u32::MAX` for a leaf.
    pub feature: u32,
    /// Raw-value threshold: `x <= threshold` goes left.
    pub threshold: f32,
    /// Left child index (leaf: unused).
    pub left: u32,
    /// Right child index (leaf: unused).
    pub right: u32,
    /// Leaf output (already scaled by the learning rate).
    pub value: f64,
    /// Split gain (importance accounting).
    pub gain: f64,
}

impl Node {
    /// Whether this node is a leaf.
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.feature == u32::MAX
    }

    /// A leaf node with the given output value.
    pub fn leaf(value: f64) -> Node {
        Node { feature: u32::MAX, threshold: 0.0, left: 0, right: 0,
               value, gain: 0.0 }
    }
}

/// One boosted tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    /// Flat node storage; index 0 is the root.
    pub nodes: Vec<Node>,
}

impl Tree {
    /// Predict a single raw feature row.
    #[inline]
    pub fn predict_row(&self, row: &[f32]) -> f64 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.is_leaf() {
                return n.value;
            }
            i = if row[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    /// Accumulate split gains per feature into `out`.
    pub fn add_gains(&self, out: &mut [f64]) {
        for n in &self.nodes {
            if !n.is_leaf() {
                out[n.feature as usize] += n.gain;
            }
        }
    }

    /// Number of leaf nodes.
    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Depth of the deepest leaf (0 for a stump).
    pub fn depth(&self) -> usize {
        fn rec(t: &Tree, i: usize) -> usize {
            let n = &t.nodes[i];
            if n.is_leaf() {
                0
            } else {
                1 + rec(t, n.left as usize).max(rec(t, n.right as usize))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            rec(self, 0)
        }
    }
}

/// Split-finding configuration (subset of `GbdtParams` the grower needs).
pub struct GrowCfg {
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Minimum hessian sum per child.
    pub min_child_weight: f64,
    /// Minimum split gain.
    pub gamma: f64,
    /// L1 penalty on leaf weights.
    pub reg_alpha: f64,
    /// L2 penalty on leaf weights.
    pub reg_lambda: f64,
    /// Shrinkage applied to leaf outputs.
    pub learning_rate: f64,
}

/// L1 soft threshold on the gradient sum.
#[inline]
fn soft_threshold(g: f64, alpha: f64) -> f64 {
    if g > alpha {
        g - alpha
    } else if g < -alpha {
        g + alpha
    } else {
        0.0
    }
}

#[inline]
fn leaf_objective(g: f64, h: f64, cfg: &GrowCfg) -> f64 {
    let t = soft_threshold(g, cfg.reg_alpha);
    t * t / (h + cfg.reg_lambda)
}

#[inline]
fn leaf_weight(g: f64, h: f64, cfg: &GrowCfg) -> f64 {
    -soft_threshold(g, cfg.reg_alpha) / (h + cfg.reg_lambda)
}

/// Grow one tree on `rows` (indices into the binned data) with per-row
/// gradient/hessian, considering only `features`. Depth-wise expansion.
pub fn grow(
    binned: &BinnedDataset,
    grad: &[f64],
    hess: &[f64],
    rows: &[u32],
    features: &[u32],
    cfg: &GrowCfg,
) -> Tree {
    let mut tree = Tree::default();
    let mut row_buf: Vec<u32> = rows.to_vec();
    // node → (segment in row_buf, depth)
    struct Work {
        node: usize,
        lo: usize,
        hi: usize,
        depth: usize,
        g: f64,
        h: f64,
    }
    let (g0, h0) = sum_gh(grad, hess, &row_buf);
    tree.nodes.push(Node::leaf(0.0));
    let mut stack = vec![Work { node: 0, lo: 0, hi: row_buf.len(),
                                depth: 0, g: g0, h: h0 }];
    // scratch histograms: (sum_g, sum_h) per bin
    let mut hist_g = vec![0.0f64; 256];
    let mut hist_h = vec![0.0f64; 256];
    while let Some(w) = stack.pop() {
        let seg = &row_buf[w.lo..w.hi];
        let parent_obj = leaf_objective(w.g, w.h, cfg);
        let mut best: Option<(f64, u32, u8, f64, f64)> = None;
        // (gain, feature, bin, gl, hl)
        if w.depth < cfg.max_depth && seg.len() >= 2 {
            for &f in features {
                let bins = binned.feature_bins(f as usize);
                let nb = binned.n_bins(f as usize);
                if nb < 2 {
                    continue;
                }
                hist_g[..nb].fill(0.0);
                hist_h[..nb].fill(0.0);
                for &r in seg {
                    let b = bins[r as usize] as usize;
                    hist_g[b] += grad[r as usize];
                    hist_h[b] += hess[r as usize];
                }
                let mut gl = 0.0;
                let mut hl = 0.0;
                for b in 0..nb - 1 {
                    gl += hist_g[b];
                    hl += hist_h[b];
                    let gr = w.g - gl;
                    let hr = w.h - hl;
                    if hl < cfg.min_child_weight
                        || hr < cfg.min_child_weight
                    {
                        continue;
                    }
                    let gain = 0.5
                        * (leaf_objective(gl, hl, cfg)
                            + leaf_objective(gr, hr, cfg)
                            - parent_obj)
                        - cfg.gamma;
                    let improves = match best {
                        None => true,
                        Some((bg, ..)) => gain > bg,
                    };
                    if gain > 0.0 && improves {
                        best = Some((gain, f, b as u8, gl, hl));
                    }
                }
            }
        }
        match best {
            None => {
                tree.nodes[w.node] =
                    Node::leaf(cfg.learning_rate * leaf_weight(w.g, w.h, cfg));
            }
            Some((gain, f, bin, gl, hl)) => {
                // partition the segment in place
                let bins = binned.feature_bins(f as usize);
                let seg = &mut row_buf[w.lo..w.hi];
                let mut i = 0usize;
                let mut j = seg.len();
                while i < j {
                    if bins[seg[i] as usize] <= bin {
                        i += 1;
                    } else {
                        j -= 1;
                        seg.swap(i, j);
                    }
                }
                let mid = w.lo + i;
                let left = tree.nodes.len();
                tree.nodes.push(Node::leaf(0.0));
                let right = tree.nodes.len();
                tree.nodes.push(Node::leaf(0.0));
                tree.nodes[w.node] = Node {
                    feature: f,
                    threshold: binned.cuts[f as usize][bin as usize],
                    left: left as u32,
                    right: right as u32,
                    value: 0.0,
                    gain,
                };
                stack.push(Work { node: left, lo: w.lo, hi: mid,
                                  depth: w.depth + 1, g: gl, h: hl });
                stack.push(Work { node: right, lo: mid, hi: w.hi,
                                  depth: w.depth + 1, g: w.g - gl,
                                  h: w.h - hl });
            }
        }
    }
    tree
}

fn sum_gh(grad: &[f64], hess: &[f64], rows: &[u32]) -> (f64, f64) {
    let mut g = 0.0;
    let mut h = 0.0;
    for &r in rows {
        g += grad[r as usize];
        h += hess[r as usize];
    }
    (g, h)
}

/// Predict a whole dataset with one tree (adds into `out`).
pub fn predict_into(tree: &Tree, data: &Dataset, out: &mut [f64]) {
    for i in 0..data.n_rows {
        out[i] += tree.predict_row(data.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::dataset::{BinnedDataset, Dataset};

    fn cfg() -> GrowCfg {
        GrowCfg { max_depth: 6, min_child_weight: 1e-9, gamma: 0.0,
                  reg_alpha: 0.0, reg_lambda: 1.0, learning_rate: 1.0 }
    }

    #[test]
    fn splits_a_step_function() {
        // y = 1 if x > 5 else 0; squared error grads at pred=0: g = -y
        let rows: Vec<Vec<f64>> = (0..12).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> =
            (0..12).map(|i| if i > 5 { 1.0 } else { 0.0 }).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let b = BinnedDataset::bin(&d, 256);
        let grad: Vec<f64> = labels.iter().map(|&y| -y).collect();
        let hess = vec![1.0; 12];
        let rows_idx: Vec<u32> = (0..12).collect();
        let feats = [0u32];
        let t = grow(&b, &grad, &hess, &rows_idx, &feats, &cfg());
        // root split near 5.5; left→~0, right→~1 (shrunk by lambda)
        assert!(!t.nodes[0].is_leaf());
        let lo = t.predict_row(&[0.0]);
        let hi = t.predict_row(&[11.0]);
        assert!(lo < 0.2, "{lo}");
        assert!(hi > 0.5, "{hi}");
    }

    #[test]
    fn max_depth_zero_is_single_leaf() {
        let d = Dataset::from_rows(
            &(0..4).map(|i| vec![i as f64]).collect::<Vec<_>>(),
            &[0.0, 0.0, 1.0, 1.0],
        );
        let b = BinnedDataset::bin(&d, 256);
        let mut c = cfg();
        c.max_depth = 0;
        let t = grow(&b, &[-0.0, -0.0, -1.0, -1.0], &[1.0; 4],
                     &[0, 1, 2, 3], &[0], &c);
        assert_eq!(t.nodes.len(), 1);
        assert!(t.nodes[0].is_leaf());
        // leaf = -G/(H+λ) = 2/(4+1)
        assert!((t.nodes[0].value - 0.4).abs() < 1e-12);
    }

    #[test]
    fn min_child_weight_blocks_tiny_splits() {
        let d = Dataset::from_rows(
            &(0..4).map(|i| vec![i as f64]).collect::<Vec<_>>(),
            &[0.0, 0.0, 0.0, 1.0],
        );
        let b = BinnedDataset::bin(&d, 256);
        let mut c = cfg();
        c.min_child_weight = 3.0; // each side needs ≥3 rows (hess=1)
        let t = grow(&b, &[0.0, 0.0, 0.0, -1.0], &[1.0; 4],
                     &[0, 1, 2, 3], &[0], &c);
        assert!(t.nodes[0].is_leaf(), "no split can satisfy min_child");
    }

    #[test]
    fn l1_shrinks_leaves_to_zero() {
        let d = Dataset::from_rows(&[vec![0.0], vec![1.0]], &[0.1, 0.1]);
        let b = BinnedDataset::bin(&d, 256);
        let mut c = cfg();
        c.reg_alpha = 10.0; // |G| < alpha everywhere → 0 leaves
        c.max_depth = 0;
        let t = grow(&b, &[-0.1, -0.1], &[1.0; 2], &[0, 1], &[0], &c);
        assert_eq!(t.nodes[0].value, 0.0);
    }

    #[test]
    fn gains_accumulate_per_feature() {
        let rows: Vec<Vec<f64>> =
            (0..20).map(|i| vec![i as f64, 0.0]).collect();
        let labels: Vec<f64> =
            (0..20).map(|i| if i >= 10 { 1.0 } else { 0.0 }).collect();
        let d = Dataset::from_rows(&rows, &labels);
        let b = BinnedDataset::bin(&d, 256);
        let grad: Vec<f64> = labels.iter().map(|&y| -y).collect();
        let t = grow(&b, &grad, &vec![1.0; 20],
                     &(0..20).collect::<Vec<u32>>(), &[0, 1], &cfg());
        let mut gains = vec![0.0; 2];
        t.add_gains(&mut gains);
        assert!(gains[0] > 0.0);
        assert_eq!(gains[1], 0.0, "constant feature never splits");
    }
}
