//! Hyper-parameters — paper Table 3 (search space and tuned values).

use super::objective::Objective;

/// XGBoost-style boosting hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct GbdtParams {
    /// Training objective (loss).
    pub objective: Objective,
    /// `boost round` (Table 3: 300 for all models).
    pub boost_rounds: usize,
    /// `max depth` (P/A: 14, V: 5).
    pub max_depth: usize,
    /// `min child weight` (3).
    pub min_child_weight: f64,
    /// `gamma` — minimum split gain (0.0).
    pub gamma: f64,
    /// `subsample` — row sampling per tree (P/A: 1.0, V: 0.6).
    pub subsample: f64,
    /// `colsample bytree` (P/A: 1.0, V: 0.6).
    pub colsample_bytree: f64,
    /// `learning rate` (P/A: 0.01, V: 0.1).
    pub learning_rate: f64,
    /// `reg alpha` — L1 on leaf weights (P/A: 1e-5, V: 1e-2).
    pub reg_alpha: f64,
    /// L2 on leaf weights (XGBoost default 1.0; not swept in the paper).
    pub reg_lambda: f64,
    /// Histogram bins per feature.
    pub max_bins: usize,
    /// RNG seed for row/column subsampling.
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            objective: Objective::SquaredError,
            boost_rounds: 100,
            max_depth: 6,
            min_child_weight: 1.0,
            gamma: 0.0,
            subsample: 1.0,
            colsample_bytree: 1.0,
            learning_rate: 0.1,
            reg_alpha: 0.0,
            reg_lambda: 1.0,
            max_bins: 256,
            seed: 0,
        }
    }
}

impl GbdtParams {
    /// Paper Table 3, "Model P" column.
    pub fn model_p() -> Self {
        GbdtParams {
            objective: Objective::SquaredError,
            boost_rounds: 300,
            max_depth: 14,
            min_child_weight: 3.0,
            gamma: 0.0,
            subsample: 1.0,
            colsample_bytree: 1.0,
            learning_rate: 0.01,
            reg_alpha: 1e-5,
            ..Default::default()
        }
    }

    /// Paper Table 3, "Model V" column (binary:hinge).
    pub fn model_v() -> Self {
        GbdtParams {
            objective: Objective::Hinge,
            boost_rounds: 300,
            max_depth: 5,
            min_child_weight: 3.0,
            gamma: 0.0,
            subsample: 0.6,
            colsample_bytree: 0.6,
            learning_rate: 0.1,
            reg_alpha: 1e-2,
            ..Default::default()
        }
    }

    /// Paper Table 3, "Model A" column (same as P; wider feature input).
    pub fn model_a() -> Self {
        Self::model_p()
    }

    /// Tuning-loop variant: fewer rounds so each iteration retrain stays
    /// cheap (the paper retrains per iteration; round count is an
    /// experiment axis in Fig. 4).
    pub fn with_rounds(mut self, rounds: usize) -> Self {
        self.boost_rounds = rounds;
        self
    }

    /// Same parameters, different subsampling seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Same parameters, different objective.
    pub fn with_objective(mut self, obj: Objective) -> Self {
        self.objective = obj;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_presets() {
        let p = GbdtParams::model_p();
        assert_eq!(p.boost_rounds, 300);
        assert_eq!(p.max_depth, 14);
        assert_eq!(p.learning_rate, 0.01);
        assert_eq!(p.reg_alpha, 1e-5);
        let v = GbdtParams::model_v();
        assert_eq!(v.max_depth, 5);
        assert_eq!(v.subsample, 0.6);
        assert_eq!(v.objective, Objective::Hinge);
        assert_eq!(GbdtParams::model_a(), GbdtParams::model_p());
    }
}
