//! Training objectives — paper Tables 3/4: regression (squared error),
//! binary classification (hinge / logistic), pairwise ranking.

/// Supported objective functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// `reg:squarederror` — models P and A.
    SquaredError,
    /// `binary:logistic` — model V variant (Table 4).
    Logistic,
    /// `binary:hinge` — model V (Table 3).
    Hinge,
    /// `rank:pairwise` — P/A variant compared in Table 4 ([41] LambdaMART
    /// style, single query group unless groups are given).
    RankPairwise,
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl Objective {
    /// Stable identifier used by serialized model artifacts.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::SquaredError => "squared_error",
            Objective::Logistic => "logistic",
            Objective::Hinge => "hinge",
            Objective::RankPairwise => "rank_pairwise",
        }
    }

    /// Inverse of [`Objective::name`].
    pub fn parse_name(name: &str) -> Option<Objective> {
        match name {
            "squared_error" => Some(Objective::SquaredError),
            "logistic" => Some(Objective::Logistic),
            "hinge" => Some(Objective::Hinge),
            "rank_pairwise" => Some(Objective::RankPairwise),
            _ => None,
        }
    }

    /// Initial raw prediction.
    pub fn base_score(&self, labels: &[f64]) -> f64 {
        match self {
            Objective::SquaredError => {
                if labels.is_empty() {
                    0.0
                } else {
                    labels.iter().sum::<f64>() / labels.len() as f64
                }
            }
            Objective::Logistic => {
                let p = (labels.iter().sum::<f64>()
                    / labels.len().max(1) as f64)
                    .clamp(1e-6, 1.0 - 1e-6);
                (p / (1.0 - p)).ln()
            }
            Objective::Hinge | Objective::RankPairwise => 0.0,
        }
    }

    /// Gradient/hessian of the loss at current raw predictions.
    /// `groups`: query-group sizes for ranking (None ⇒ one group).
    pub fn grad_hess(
        &self,
        preds: &[f64],
        labels: &[f64],
        groups: Option<&[usize]>,
        grad: &mut Vec<f64>,
        hess: &mut Vec<f64>,
    ) {
        let n = preds.len();
        grad.clear();
        hess.clear();
        grad.resize(n, 0.0);
        hess.resize(n, 0.0);
        match self {
            Objective::SquaredError => {
                for i in 0..n {
                    grad[i] = preds[i] - labels[i];
                    hess[i] = 1.0;
                }
            }
            Objective::Logistic => {
                for i in 0..n {
                    let p = sigmoid(preds[i]);
                    grad[i] = p - labels[i];
                    hess[i] = (p * (1.0 - p)).max(1e-16);
                }
            }
            Objective::Hinge => {
                for i in 0..n {
                    let y = 2.0 * labels[i] - 1.0; // {0,1} → {-1,+1}
                    if y * preds[i] < 1.0 {
                        grad[i] = -y;
                    } else {
                        grad[i] = 0.0;
                    }
                    hess[i] = 1.0;
                }
            }
            Objective::RankPairwise => {
                let one_group = [n];
                let groups = groups.unwrap_or(&one_group);
                let mut start = 0usize;
                for &len in groups {
                    let end = start + len;
                    for i in start..end {
                        for j in start..end {
                            if labels[i] <= labels[j] {
                                continue; // want pairs where i beats j
                            }
                            // P(i beats j) should → 1
                            let s = sigmoid(preds[i] - preds[j]);
                            let g = s - 1.0;
                            let h = (s * (1.0 - s)).max(1e-16);
                            grad[i] += g;
                            grad[j] -= g;
                            hess[i] += h;
                            hess[j] += h;
                        }
                    }
                    start = end;
                }
                for h in hess.iter_mut() {
                    if *h == 0.0 {
                        *h = 1e-16;
                    }
                }
            }
        }
    }

    /// Transform a raw prediction into the reporting domain
    /// (probability for logistic; identity otherwise).
    pub fn transform(&self, raw: f64) -> f64 {
        match self {
            Objective::Logistic => sigmoid(raw),
            _ => raw,
        }
    }

    /// Decision threshold on the *raw* score for binary objectives.
    pub fn decision_threshold(&self) -> f64 {
        match self {
            Objective::Logistic => 0.0, // sigmoid(0) = 0.5
            Objective::Hinge => 0.0,
            Objective::SquaredError => 0.5, // regression-on-{0,1} trick
            Objective::RankPairwise => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_error_grad() {
        let mut g = vec![];
        let mut h = vec![];
        Objective::SquaredError.grad_hess(
            &[2.0, -1.0],
            &[1.0, 1.0],
            None,
            &mut g,
            &mut h,
        );
        assert_eq!(g, vec![1.0, -2.0]);
        assert_eq!(h, vec![1.0, 1.0]);
    }

    #[test]
    fn logistic_grad_signs() {
        let mut g = vec![];
        let mut h = vec![];
        Objective::Logistic.grad_hess(
            &[0.0, 0.0],
            &[1.0, 0.0],
            None,
            &mut g,
            &mut h,
        );
        assert!(g[0] < 0.0 && g[1] > 0.0);
        assert!(h.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn hinge_zero_grad_outside_margin() {
        let mut g = vec![];
        let mut h = vec![];
        Objective::Hinge.grad_hess(
            &[2.0, 0.5, -2.0],
            &[1.0, 1.0, 0.0],
            None,
            &mut g,
            &mut h,
        );
        assert_eq!(g[0], 0.0); // margin satisfied
        assert_eq!(g[1], -1.0); // inside margin, pushes up
        assert_eq!(g[2], 0.0); // y=-1, pred=-2 → margin satisfied
    }

    #[test]
    fn rank_pushes_winner_up() {
        let mut g = vec![];
        let mut h = vec![];
        Objective::RankPairwise.grad_hess(
            &[0.0, 0.0],
            &[2.0, 1.0],
            None,
            &mut g,
            &mut h,
        );
        assert!(g[0] < 0.0, "winner gradient must push score up");
        assert!(g[1] > 0.0);
        assert_eq!(g[0], -g[1]);
    }

    #[test]
    fn rank_respects_groups() {
        let mut g = vec![];
        let mut h = vec![];
        // two groups; cross-group pairs must not contribute
        Objective::RankPairwise.grad_hess(
            &[0.0, 0.0],
            &[2.0, 1.0],
            Some(&[1, 1]),
            &mut g,
            &mut h,
        );
        assert_eq!(g, vec![0.0, 0.0]);
    }

    #[test]
    fn base_scores() {
        assert_eq!(
            Objective::SquaredError.base_score(&[1.0, 3.0]),
            2.0
        );
        let b = Objective::Logistic.base_score(&[1.0, 1.0, 0.0, 0.0]);
        assert!(b.abs() < 1e-9); // logit(0.5) = 0
        assert_eq!(Objective::Hinge.base_score(&[1.0]), 0.0);
    }
}
