//! Flattened ensemble — the cache-friendly inference layout of a trained
//! booster.
//!
//! [`crate::gbdt::tree::Tree`] stores an array-of-structs `Vec<Node>` per
//! tree; walking it row-at-a-time loads a 40-byte node to read ~10 bytes
//! and re-streams every tree's nodes once *per row*. [`FlatEnsemble`]
//! concatenates all trees into structure-of-arrays node storage
//! (`feature[]` / `threshold[]` / `left[]` / `right[]` / leaf `value[]`,
//! children addressed by global index) and predicts trees-outer /
//! rows-inner over a row-major [`FeatureMatrix`], so each tree's small,
//! hot node arrays stream once over the whole batch.
//!
//! Scores accumulate in f64 in the exact order of
//! [`crate::gbdt::Booster::predict_row`] (base score, then trees in
//! boosting order), so batched outputs are **bit-identical** to the
//! per-row path — the explorer's golden traces cannot move
//! (`tests/flat_inference.rs` pins this across spaces, targets and
//! objectives).

use super::dataset::{Dataset, FeatureMatrix};
use super::tree::Tree;

/// An immutable SoA copy of a trained ensemble, built once per trained
/// model ([`crate::gbdt::Booster::flatten`]).
#[derive(Clone, Debug, Default)]
pub struct FlatEnsemble {
    n_features: usize,
    base_score: f64,
    /// Split feature per node; `u32::MAX` marks a leaf.
    feature: Vec<u32>,
    /// Split threshold per node (`x <= threshold` goes left).
    threshold: Vec<f32>,
    /// Global child indices (leaves: 0, unused).
    left: Vec<u32>,
    right: Vec<u32>,
    /// Leaf value per node (internal nodes: 0.0).
    value: Vec<f64>,
    /// Root node index of each tree, boosting order.
    roots: Vec<u32>,
}

impl FlatEnsemble {
    /// Flatten `trees` (each non-empty) over `n_features`-wide rows.
    pub fn from_trees(
        n_features: usize,
        base_score: f64,
        trees: &[Tree],
    ) -> FlatEnsemble {
        let total: usize = trees.iter().map(|t| t.nodes.len()).sum();
        let mut f = FlatEnsemble {
            n_features,
            base_score,
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            value: Vec::with_capacity(total),
            roots: Vec::with_capacity(trees.len()),
        };
        for t in trees {
            assert!(!t.nodes.is_empty(), "cannot flatten an empty tree");
            let off = f.feature.len() as u32;
            f.roots.push(off);
            for n in &t.nodes {
                f.feature.push(n.feature);
                f.threshold.push(n.threshold);
                // leaves keep 0 children; internal nodes rebase to
                // global indices
                f.left.push(if n.is_leaf() { 0 } else { n.left + off });
                f.right.push(if n.is_leaf() { 0 } else { n.right + off });
                f.value.push(n.value);
            }
        }
        f
    }

    /// Number of flattened trees.
    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    /// Feature-vector width the ensemble expects.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Initial raw prediction every tree sum starts from.
    pub fn base_score(&self) -> f64 {
        self.base_score
    }

    /// Walk one tree for one row.
    #[inline]
    fn leaf_value(&self, root: usize, row: &[f32]) -> f64 {
        let mut i = root;
        loop {
            let f = self.feature[i];
            if f == u32::MAX {
                return self.value[i];
            }
            i = if row[f as usize] <= self.threshold[i] {
                self.left[i] as usize
            } else {
                self.right[i] as usize
            };
        }
    }

    /// Raw score of one f32 row — bit-identical to
    /// [`crate::gbdt::Booster::predict_row_f32`] (same f64 accumulation
    /// order).
    pub fn predict_row(&self, row: &[f32]) -> f64 {
        debug_assert_eq!(row.len(), self.n_features);
        let mut s = self.base_score;
        for &root in &self.roots {
            s += self.leaf_value(root as usize, row);
        }
        s
    }

    /// Core batched kernel: trees outer, rows inner, adding each tree's
    /// leaf into `out` on top of whatever is there (`values` is
    /// `out.len()` rows of `n_features` f32s, row-major).
    fn accumulate(&self, values: &[f32], out: &mut [f64]) {
        if out.is_empty() {
            return;
        }
        let nf = self.n_features;
        debug_assert_eq!(values.len(), out.len() * nf);
        if nf == 0 {
            // degenerate zero-feature data: every tree is a stump
            for &root in &self.roots {
                let v = self.value[root as usize];
                for s in out.iter_mut() {
                    *s += v;
                }
            }
            return;
        }
        for &root in &self.roots {
            let root = root as usize;
            for (row, s) in values.chunks_exact(nf).zip(out.iter_mut()) {
                *s += self.leaf_value(root, row);
            }
        }
    }

    /// Batched raw scores over a feature matrix, written into `out`
    /// (cleared and resized to the row count). Per row this is
    /// bit-identical to [`FlatEnsemble::predict_row`] — only the loop
    /// nest is transposed.
    pub fn predict_batch_into(
        &self,
        m: &FeatureMatrix,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(m.n_features(), self.n_features, "feature width");
        out.clear();
        out.resize(m.n_rows(), self.base_score);
        self.accumulate(m.values(), out);
    }

    /// Allocating convenience wrapper over
    /// [`FlatEnsemble::predict_batch_into`].
    pub fn predict_batch(&self, m: &FeatureMatrix) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_batch_into(m, &mut out);
        out
    }

    /// Add this ensemble's tree contributions (no base score) to `out`
    /// over a dataset — the training-time margin-update path of
    /// [`crate::gbdt::Booster::fit`].
    pub fn accumulate_dataset(&self, data: &Dataset, out: &mut [f64]) {
        assert_eq!(data.n_rows, out.len(), "row count");
        assert_eq!(data.n_features, self.n_features, "feature width");
        self.accumulate(&data.values, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::tree::Node;

    /// Hand-built two-level tree: x0 <= 1.0 ? (x1 <= 5.0 ? 1.0 : 2.0) : 3.0
    fn small_tree() -> Tree {
        Tree {
            nodes: vec![
                Node { feature: 0, threshold: 1.0, left: 1, right: 2,
                       value: 0.0, gain: 1.0 },
                Node { feature: 1, threshold: 5.0, left: 3, right: 4,
                       value: 0.0, gain: 1.0 },
                Node::leaf(3.0),
                Node::leaf(1.0),
                Node::leaf(2.0),
            ],
        }
    }

    #[test]
    fn flatten_rebases_children_across_trees() {
        let t = small_tree();
        let flat = FlatEnsemble::from_trees(2, 0.5, &[t.clone(), t]);
        assert_eq!(flat.n_trees(), 2);
        // both trees agree with the AoS walk; the ensemble sums them
        for row in [[0.0f32, 0.0], [0.0, 9.0], [2.0, 0.0]] {
            let one = small_tree().predict_row(&row);
            assert_eq!(flat.predict_row(&row).to_bits(),
                       (0.5 + one + one).to_bits());
        }
    }

    #[test]
    fn batch_matches_row_bitwise() {
        let flat = FlatEnsemble::from_trees(2, -1.25, &[small_tree()]);
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![(i % 3) as f64, (i % 7) as f64])
            .collect();
        let m = FeatureMatrix::from_rows(&rows);
        let batch = flat.predict_batch(&m);
        assert_eq!(batch.len(), rows.len());
        for (i, &s) in batch.iter().enumerate() {
            assert_eq!(s.to_bits(), flat.predict_row(m.row(i)).to_bits());
        }
    }

    #[test]
    fn empty_ensemble_predicts_base_score() {
        let flat = FlatEnsemble::from_trees(3, 2.5, &[]);
        assert_eq!(flat.n_trees(), 0);
        assert_eq!(flat.predict_row(&[0.0, 0.0, 0.0]), 2.5);
        let m = FeatureMatrix::from_rows(&[vec![1.0, 2.0, 3.0]]);
        assert_eq!(flat.predict_batch(&m), vec![2.5]);
    }

    #[test]
    fn accumulate_dataset_adds_without_base() {
        let flat = FlatEnsemble::from_trees(2, 100.0, &[small_tree()]);
        let rows = vec![vec![0.0, 0.0], vec![2.0, 0.0]];
        let d = Dataset::from_rows(&rows, &[0.0, 0.0]);
        let mut out = vec![10.0f64; 2];
        flat.accumulate_dataset(&d, &mut out);
        assert_eq!(out, vec![11.0, 13.0], "base score must not leak in");
    }
}
