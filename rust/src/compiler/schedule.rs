//! Schedule knobs — the tuner's *visible features* (paper §B.2: "the
//! optimizable features in our VTA implementation and backend compiler are
//! based on tiling and the number of virtual threads").

use crate::workloads::ConvLayer;

/// One point in the per-layer search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Schedule {
    /// Output-tile height (`TH` in paper Table 5).
    pub tile_h: usize,
    /// Output-tile width (`TW`).
    pub tile_w: usize,
    /// Output channels per tile (multiple of the GEMM block).
    pub tile_oc: usize,
    /// Input channels per chunk (multiple of the GEMM block).
    pub tile_ic: usize,
    /// Virtual threads (`nVirtualThread`): software pipelining depth; the
    /// scratchpads are partitioned `1/n` per thread.
    pub n_vthreads: usize,
}

impl Schedule {
    /// Visible feature names, aligned with [`Schedule::visible_features`].
    pub const VISIBLE_NAMES: [&'static str; 11] = [
        "TW",
        "TH",
        "tileIC",
        "tileOC",
        "nVirtualThread",
        "TW*TH",
        "TW*TH*tileOC",
        "TW*TH*tileOC*nVT",
        "tileIC*nVT",
        "TW*TH*tileIC*nVT",
        "tileOC*tileIC*nVT",
    ];

    /// The visible feature vector models P and V consume (paper: layer and
    /// kernel information is *not* included — models are per-layer).
    ///
    /// Alongside the raw knobs, AutoTVM-style derived products are included:
    /// they are computable from the schedule alone (no compilation — still
    /// "visible"), and they turn the multiplicative scratchpad-pressure
    /// boundaries into near-axis-aligned thresholds that tree models can
    /// actually represent (the paper's model V reaches 99.4% accuracy,
    /// Table 4; raw knobs alone cap far below that).
    pub fn visible_features(&self) -> Vec<f64> {
        let (tw, th) = (self.tile_w as f64, self.tile_h as f64);
        let (ic, oc) = (self.tile_ic as f64, self.tile_oc as f64);
        let vt = self.n_vthreads as f64;
        vec![
            tw,
            th,
            ic,
            oc,
            vt,
            tw * th,
            tw * th * oc,
            tw * th * oc * vt,
            ic * vt,
            tw * th * ic * vt,
            oc * ic * vt,
        ]
    }

    /// Stable identity key for databases / dedup.
    pub fn key(&self) -> u64 {
        // fields are small; pack into a u64
        (self.tile_h as u64) << 48
            | (self.tile_w as u64) << 32
            | (self.tile_oc as u64) << 20
            | (self.tile_ic as u64) << 8
            | self.n_vthreads as u64
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "th{}_tw{}_oc{}_ic{}_vt{}",
            self.tile_h, self.tile_w, self.tile_oc, self.tile_ic,
            self.n_vthreads
        )
    }
}

/// Per-layer candidate lists (DESIGN.md §Search space): divisors of the
/// output extent plus multiples of 8, channel-block multiples, 1/2/4
/// virtual threads. The full space is their cross product.
pub fn candidates(layer: &ConvLayer) -> ScheduleSpace {
    ScheduleSpace {
        tile_h: spatial_candidates(layer.oh),
        tile_w: spatial_candidates(layer.ow),
        tile_oc: oc_candidates(layer.kc),
        tile_ic: ic_candidates(layer.c),
        // the extended VTA exposes deeper virtual threading; each level
        // halves the per-thread scratchpad slice (capacity pressure is the
        // main source of the paper's 0.50–0.93 random invalidity)
        n_vthreads: vec![1, 2, 4, 8, 16],
    }
}

/// The cross-product search space for one layer.
#[derive(Clone, Debug)]
pub struct ScheduleSpace {
    pub tile_h: Vec<usize>,
    pub tile_w: Vec<usize>,
    pub tile_oc: Vec<usize>,
    pub tile_ic: Vec<usize>,
    pub n_vthreads: Vec<usize>,
}

impl ScheduleSpace {
    pub fn len(&self) -> usize {
        self.tile_h.len()
            * self.tile_w.len()
            * self.tile_oc.len()
            * self.tile_ic.len()
            * self.n_vthreads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate the `i`-th schedule (row-major over the candidate lists).
    pub fn nth(&self, i: usize) -> Schedule {
        let mut r = i;
        let pick = |r: &mut usize, xs: &[usize]| {
            let v = xs[*r % xs.len()];
            *r /= xs.len();
            v
        };
        let n_vthreads = pick(&mut r, &self.n_vthreads);
        let tile_ic = pick(&mut r, &self.tile_ic);
        let tile_oc = pick(&mut r, &self.tile_oc);
        let tile_w = pick(&mut r, &self.tile_w);
        let tile_h = pick(&mut r, &self.tile_h);
        assert!(r == 0 || i < self.len(), "index out of range");
        Schedule { tile_h, tile_w, tile_oc, tile_ic, n_vthreads }
    }

    /// All schedules, enumeration order.
    pub fn all(&self) -> Vec<Schedule> {
        (0..self.len()).map(|i| self.nth(i)).collect()
    }
}

/// Divisors of `n` union multiples of 4 up to `n` (boundary-exercising;
/// the multiples keep the large-tile — mostly invalid — region densely
/// represented, mirroring the paper's 0.50–0.93 random invalidity band).
fn spatial_candidates(n: usize) -> Vec<usize> {
    let mut v: Vec<usize> =
        (1..=n).filter(|d| n % d == 0 || d % 4 == 0).collect();
    v.dedup();
    v
}

/// Multiples of 16 up to `kc`, thinned above 64 to keep spaces tractable.
fn oc_candidates(kc: usize) -> Vec<usize> {
    (1..=kc / 16)
        .map(|b| b * 16)
        .filter(|&v| v <= 64 || v % 32 == 0)
        .collect()
}

/// Divisors of `c` that are multiples of 16 (channel chunks must tile C
/// exactly; see `compiler::passes`).
fn ic_candidates(c: usize) -> Vec<usize> {
    (1..=c / 16)
        .map(|b| b * 16)
        .filter(|v| c % v == 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn space_sizes_are_sane() {
        for l in resnet18::LAYERS {
            let s = candidates(&l);
            assert!(
                (500..20_000).contains(&s.len()),
                "{}: {}",
                l.name,
                s.len()
            );
        }
    }

    #[test]
    fn nth_enumerates_all_distinct() {
        let l = resnet18::layer("conv5").unwrap();
        let s = candidates(&l);
        let all = s.all();
        assert_eq!(all.len(), s.len());
        let mut keys: Vec<u64> = all.iter().map(|s| s.key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), all.len(), "schedules must be distinct");
    }

    #[test]
    fn ic_candidates_divide_c() {
        for l in resnet18::LAYERS {
            for ic in candidates(&l).tile_ic {
                assert_eq!(l.c % ic, 0);
            }
        }
    }

    #[test]
    fn visible_features_order() {
        let s = Schedule { tile_h: 4, tile_w: 8, tile_oc: 32, tile_ic: 16,
                           n_vthreads: 2 };
        let f = s.visible_features();
        assert_eq!(&f[..5], &[8.0, 4.0, 16.0, 32.0, 2.0]);
        assert_eq!(f.len(), Schedule::VISIBLE_NAMES.len());
        assert_eq!(f[5], 32.0); // TW*TH
        assert_eq!(f[7], 8.0 * 4.0 * 32.0 * 2.0);
    }
}
