//! Schedule knobs — the tuner's *visible features* (paper §B.2: "the
//! optimizable features in our VTA implementation and backend compiler are
//! based on tiling and the number of virtual threads").
//!
//! The schedule layer is knob-based: a [`ConfigSpace`] is an ordered list
//! of [`Knob`]s (name + candidate values) with mixed-radix *lazy* indexing
//! — [`ConfigSpace::nth`] / [`ConfigSpace::index_of`] enumerate points on
//! demand, nothing is materialized up front, and the space index is the
//! canonical identity of a configuration (replacing the old fixed-width
//! bit-packed `Schedule::key`, which silently collided once knob values
//! outgrew their fields).
//!
//! Two knob sets are defined:
//!
//! * [`SpaceKind::Paper`] — exactly the paper's five knobs
//!   (TH/TW/tileOC/tileIC/nVirtualThread). Enumeration order, candidate
//!   lists, and the visible feature vector are byte-identical to the
//!   original hard-coded implementation, so cold `--space paper` runs
//!   reproduce pre-refactor tuning traces exactly (pinned by
//!   `tests/space_golden.rs`).
//! * [`SpaceKind::Extended`] — adds two primitives that genuinely flow
//!   through codegen, the timing model, and the validity structure:
//!   `nLoadSlots` (load double-buffering toggle: 2 = paper behaviour,
//!   1 = single-buffered, halving the effective INP/WGT footprint and
//!   shifting the validity boundary model V must learn) and
//!   `kernelUnroll` (kernel-position unroll for the GEMM inner loop:
//!   fewer, larger GEMM instructions programmed by an expanded micro-op
//!   table — less issue overhead, more uop-buffer pressure). The cross
//!   product is 6× the paper space per layer.
//!
//! Visible features (model P/V inputs) are *generated* from the knob list
//! by a declarative registry: every knob contributes its raw value, and
//! [`SpaceKind::feature_terms`] lists the AutoTVM-style derived products
//! (each a list of knob names whose values are multiplied). Names are
//! derived from the knob declarations too, so adding a knob cannot desync
//! names from values.

use crate::workloads::ConvLayer;

// ------------------------------------------------------------ knob defs --

// Knob names, in declaration order. `Schedule` field accessors are keyed
// by these names; serialization writes them next to their values so
// tuning logs stay readable across space versions (unknown names in old
// or future logs are simply skipped on load).

/// Output-tile height knob.
pub const KNOB_TH: &str = "TH";
/// Output-tile width knob.
pub const KNOB_TW: &str = "TW";
/// Output-channels-per-tile knob.
pub const KNOB_OC: &str = "tileOC";
/// Input-channels-per-chunk knob.
pub const KNOB_IC: &str = "tileIC";
/// Virtual-thread-count knob.
pub const KNOB_VT: &str = "nVirtualThread";
/// Load-buffer-slots knob (extended space).
pub const KNOB_SLOTS: &str = "nLoadSlots";
/// Kernel-unroll knob (extended space).
pub const KNOB_UNROLL: &str = "kernelUnroll";

/// The knob universe this build understands (paper five + extensions).
/// (A `static`, not a `const`: [`SpaceKind::knob_names`] hands out
/// `&'static` sub-slices of it.)
pub static ALL_KNOB_NAMES: [&str; 7] = [
    KNOB_TH, KNOB_TW, KNOB_OC, KNOB_IC, KNOB_VT, KNOB_SLOTS, KNOB_UNROLL,
];

/// Abbreviation used when composing derived-feature names (kept short so
/// Table-5 style reports stay readable; matches the paper's `nVT`).
fn short_name(name: &str) -> &str {
    match name {
        KNOB_VT => "nVT",
        KNOB_SLOTS => "nBuf",
        KNOB_UNROLL => "kUnroll",
        other => other,
    }
}

// ------------------------------------------------------------- schedule --

/// One point in the per-layer search space, fully resolved (every knob the
/// build knows has a value; knobs outside the originating space hold their
/// paper-fixed defaults).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Schedule {
    /// Output-tile height (`TH` in paper Table 5).
    pub tile_h: usize,
    /// Output-tile width (`TW`).
    pub tile_w: usize,
    /// Output channels per tile (multiple of the GEMM block).
    pub tile_oc: usize,
    /// Input channels per chunk (multiple of the GEMM block).
    pub tile_ic: usize,
    /// Virtual threads (`nVirtualThread`): software pipelining depth; the
    /// scratchpads are partitioned `1/n` per thread.
    pub n_vthreads: usize,
    /// Load-buffer slots per virtual thread: 2 = double buffering (the
    /// paper-fixed behaviour), 1 = single-buffered (half the INP/WGT
    /// footprint, loads serialized against compute).
    pub n_load_slots: usize,
    /// Kernel-position unroll factor for the GEMM inner loop: 1 = one
    /// GEMM instruction per (kh, kw) position (paper behaviour); u > 1
    /// packs u positions into each instruction via an expanded uop table.
    pub k_unroll: usize,
}

impl Default for Schedule {
    /// Paper-fixed defaults for the extension knobs; minimal legal values
    /// for the paper five (callers always overwrite those).
    fn default() -> Self {
        Schedule {
            tile_h: 1,
            tile_w: 1,
            tile_oc: 16,
            tile_ic: 16,
            n_vthreads: 1,
            n_load_slots: 2,
            k_unroll: 1,
        }
    }
}

impl Schedule {
    /// All knob values in [`ALL_KNOB_NAMES`] order. [`FeatureGen`]
    /// resolves knob names to indices of this array once, so the
    /// scoring sweep never does per-candidate string lookups.
    #[inline]
    pub fn knob_values(&self) -> [f64; 7] {
        [
            self.tile_h as f64,
            self.tile_w as f64,
            self.tile_oc as f64,
            self.tile_ic as f64,
            self.n_vthreads as f64,
            self.n_load_slots as f64,
            self.k_unroll as f64,
        ]
    }

    /// Read a knob value by name (`None` for names outside the universe).
    pub fn knob(&self, name: &str) -> Option<usize> {
        match name {
            KNOB_TH => Some(self.tile_h),
            KNOB_TW => Some(self.tile_w),
            KNOB_OC => Some(self.tile_oc),
            KNOB_IC => Some(self.tile_ic),
            KNOB_VT => Some(self.n_vthreads),
            KNOB_SLOTS => Some(self.n_load_slots),
            KNOB_UNROLL => Some(self.k_unroll),
            _ => None,
        }
    }

    /// Set a knob value by name; returns false (and leaves the schedule
    /// unchanged) for unknown names — the "skip unknown knobs" contract
    /// cross-version tuning-log loads rely on.
    pub fn set_knob(&mut self, name: &str, v: usize) -> bool {
        match name {
            KNOB_TH => self.tile_h = v,
            KNOB_TW => self.tile_w = v,
            KNOB_OC => self.tile_oc = v,
            KNOB_IC => self.tile_ic = v,
            KNOB_VT => self.n_vthreads = v,
            KNOB_SLOTS => self.n_load_slots = v,
            KNOB_UNROLL => self.k_unroll = v,
            _ => return false,
        }
        true
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "th{}_tw{}_oc{}_ic{}_vt{}",
            self.tile_h, self.tile_w, self.tile_oc, self.tile_ic,
            self.n_vthreads
        )?;
        // extension knobs only when off their paper defaults, so paper
        // runs render exactly as before
        if self.n_load_slots != 2 {
            write!(f, "_buf{}", self.n_load_slots)?;
        }
        if self.k_unroll != 1 {
            write!(f, "_u{}", self.k_unroll)?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------ space kind --

/// Which knob set a search space is built from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpaceKind {
    /// The paper-exact five-knob space (reproducibility baseline).
    Paper,
    /// Paper knobs + load double-buffering toggle + kernel unroll.
    Extended,
}

/// Derived-feature products for the paper space: the raw knobs (in the
/// paper's Table-5 order) followed by the AutoTVM-style products that turn
/// multiplicative scratchpad-pressure boundaries into near-axis-aligned
/// thresholds tree models can represent.
const PAPER_FEATURES: &[&[&str]] = &[
    &[KNOB_TW],
    &[KNOB_TH],
    &[KNOB_IC],
    &[KNOB_OC],
    &[KNOB_VT],
    &[KNOB_TW, KNOB_TH],
    &[KNOB_TW, KNOB_TH, KNOB_OC],
    &[KNOB_TW, KNOB_TH, KNOB_OC, KNOB_VT],
    &[KNOB_IC, KNOB_VT],
    &[KNOB_TW, KNOB_TH, KNOB_IC, KNOB_VT],
    &[KNOB_OC, KNOB_IC, KNOB_VT],
];

/// Extra features of the extended space: the two new raw knobs plus the
/// products that expose their capacity interactions (INP pressure scales
/// with `tileIC · nVT · nLoadSlots`; uop-table pressure with
/// `tileOC · tileIC · kernelUnroll`).
const EXTENDED_EXTRA_FEATURES: &[&[&str]] = &[
    &[KNOB_SLOTS],
    &[KNOB_UNROLL],
    &[KNOB_IC, KNOB_VT, KNOB_SLOTS],
    &[KNOB_TW, KNOB_TH, KNOB_IC, KNOB_VT, KNOB_SLOTS],
    &[KNOB_OC, KNOB_IC, KNOB_UNROLL],
];

impl SpaceKind {
    /// Parse a CLI space name (`paper`, `extended`/`ext`).
    pub fn parse(name: &str) -> Option<SpaceKind> {
        match name {
            "paper" => Some(SpaceKind::Paper),
            "extended" | "ext" => Some(SpaceKind::Extended),
            _ => None,
        }
    }

    /// Canonical space name, as stamped into logs.
    pub fn name(&self) -> &'static str {
        match self {
            SpaceKind::Paper => "paper",
            SpaceKind::Extended => "extended",
        }
    }

    /// Knob names this space kind enumerates, declaration order.
    pub fn knob_names(&self) -> &'static [&'static str] {
        match self {
            SpaceKind::Paper => &ALL_KNOB_NAMES[..5],
            SpaceKind::Extended => &ALL_KNOB_NAMES,
        }
    }

    /// The declarative feature registry: each entry is the list of knob
    /// names whose values are multiplied (singletons are the raw knobs).
    pub fn feature_terms(&self) -> Vec<&'static [&'static str]> {
        let mut terms: Vec<&'static [&'static str]> =
            PAPER_FEATURES.to_vec();
        if *self == SpaceKind::Extended {
            terms.extend_from_slice(EXTENDED_EXTRA_FEATURES);
        }
        terms
    }

    /// Visible feature names, generated from the registry (aligned with
    /// [`SpaceKind::visible_features`]).
    pub fn visible_names(&self) -> Vec<String> {
        self.feature_terms()
            .iter()
            .map(|terms| {
                if terms.len() == 1 {
                    terms[0].to_string()
                } else {
                    terms
                        .iter()
                        .map(|t| short_name(t))
                        .collect::<Vec<_>>()
                        .join("*")
                }
            })
            .collect()
    }

    /// Width of the visible feature vector.
    pub fn n_visible(&self) -> usize {
        self.feature_terms().len()
    }

    /// The visible feature vector models P and V consume (paper: layer
    /// and kernel information is *not* included — models are per-layer).
    /// Every value is a product of small integers, exactly representable
    /// in f64, so the result is independent of evaluation order.
    pub fn visible_features(&self, s: &Schedule) -> Vec<f64> {
        self.feature_terms()
            .iter()
            .map(|terms| {
                terms
                    .iter()
                    .map(|t| s.knob(t).expect("registry knob") as f64)
                    .product()
            })
            .collect()
    }
}

// ------------------------------------------------------------ featuregen --

/// Precompiled visible-feature generator: the declarative registry of
/// [`SpaceKind::feature_terms`] resolved once into indices of
/// [`Schedule::knob_values`], so the explorer's scoring sweep fills
/// feature rows with no per-candidate name lookups or allocations.
/// [`FeatureGen::fill`] is bit-identical to
/// [`SpaceKind::visible_features`] (same term order, same f64 product
/// order).
#[derive(Clone, Debug)]
pub struct FeatureGen {
    /// Per feature: knob indices whose values are multiplied.
    terms: Vec<Vec<usize>>,
}

impl FeatureGen {
    /// Resolve the kind's feature registry into knob indices.
    pub fn new(kind: SpaceKind) -> FeatureGen {
        let terms = kind
            .feature_terms()
            .iter()
            .map(|term| {
                term.iter()
                    .map(|name| {
                        ALL_KNOB_NAMES
                            .iter()
                            .position(|n| n == name)
                            .expect("registry knob")
                    })
                    .collect()
            })
            .collect();
        FeatureGen { terms }
    }

    /// Width of the generated feature rows.
    pub fn n_features(&self) -> usize {
        self.terms.len()
    }

    /// Fill `out` (cleared first) with the visible features of `s`.
    pub fn fill(&self, s: &Schedule, out: &mut Vec<f64>) {
        let vals = s.knob_values();
        out.clear();
        out.reserve(self.terms.len());
        for term in &self.terms {
            out.push(term.iter().map(|&k| vals[k]).product());
        }
    }
}

// ----------------------------------------------------------- config space --

/// One named tuning knob: an ordered candidate-value list.
#[derive(Clone, Debug)]
pub struct Knob {
    /// Knob name (one of the `KNOB_*` constants).
    pub name: &'static str,
    /// Candidate values, enumeration order.
    pub values: Vec<usize>,
}

/// A configuration drawn from a [`ConfigSpace`]: knob values aligned with
/// the space's knob order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Config {
    /// One value per knob, space knob order.
    pub values: Vec<usize>,
}

/// The lazily indexed cross product of a knob list.
///
/// Indexing is mixed-radix row-major with the *last* knob varying fastest
/// — for the paper knob order (TH, TW, tileOC, tileIC, nVirtualThread)
/// this reproduces the legacy enumeration order exactly. Memory is
/// O(sum of candidate-list lengths) regardless of `len()`; nothing is
/// materialized.
#[derive(Clone, Debug)]
pub struct ConfigSpace {
    kind: SpaceKind,
    knobs: Vec<Knob>,
    len: usize,
    /// Precompiled visible-feature generator for this kind.
    features: FeatureGen,
}

impl ConfigSpace {
    /// Space over the cross product of the given knobs.
    pub fn new(kind: SpaceKind, knobs: Vec<Knob>) -> Self {
        let len = knobs
            .iter()
            .map(|k| k.values.len())
            .try_fold(1usize, usize::checked_mul)
            .expect("config space size overflows usize");
        ConfigSpace { kind, knobs, len, features: FeatureGen::new(kind) }
    }

    /// The knob-set kind this space was built from.
    pub fn kind(&self) -> SpaceKind {
        self.kind
    }

    /// The knobs, declaration order.
    pub fn knobs(&self) -> &[Knob] {
        &self.knobs
    }

    /// Number of points in the space (product of candidate-list lengths).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the space has no points.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total stored candidate values — the space's actual memory
    /// footprint driver, independent of `len()`.
    pub fn stored_values(&self) -> usize {
        self.knobs.iter().map(|k| k.values.len()).sum()
    }

    /// Decode the `i`-th configuration (mixed-radix, last knob fastest).
    pub fn nth(&self, i: usize) -> Config {
        assert!(i < self.len, "index {i} out of range ({})", self.len);
        let mut r = i;
        let mut values = vec![0usize; self.knobs.len()];
        for (k, knob) in self.knobs.iter().enumerate().rev() {
            values[k] = knob.values[r % knob.values.len()];
            r /= knob.values.len();
        }
        Config { values }
    }

    /// Canonical identity: the unique index of a configuration, `None`
    /// if any value is not in its knob's candidate list. Inverse of
    /// [`ConfigSpace::nth`].
    pub fn index_of(&self, c: &Config) -> Option<usize> {
        if c.values.len() != self.knobs.len() {
            return None;
        }
        let mut idx = 0usize;
        for (knob, &v) in self.knobs.iter().zip(&c.values) {
            let pos = knob.values.iter().position(|&x| x == v)?;
            idx = idx * knob.values.len() + pos;
        }
        Some(idx)
    }

    /// Materialize the `i`-th configuration as a resolved [`Schedule`]
    /// (knobs outside this space keep their paper defaults).
    /// Allocation-free: decodes the mixed-radix digits straight into
    /// the schedule instead of materializing a [`Config`] first —
    /// same digits, same values as [`ConfigSpace::nth`].
    pub fn schedule(&self, i: usize) -> Schedule {
        assert!(i < self.len, "index {i} out of range ({})", self.len);
        let mut r = i;
        let mut s = Schedule::default();
        for knob in self.knobs.iter().rev() {
            s.set_knob(knob.name, knob.values[r % knob.values.len()]);
            r /= knob.values.len();
        }
        s
    }

    /// The configuration corresponding to a schedule (projection onto
    /// this space's knobs).
    pub fn config_of(&self, s: &Schedule) -> Config {
        Config {
            values: self
                .knobs
                .iter()
                .map(|k| s.knob(k.name).expect("universe knob"))
                .collect(),
        }
    }

    /// Canonical identity of a schedule in this space (`None` when some
    /// knob value is off the candidate grid — e.g. a legalized/clamped
    /// schedule or one imported from a different space version).
    pub fn index_of_schedule(&self, s: &Schedule) -> Option<usize> {
        self.index_of(&self.config_of(s))
    }

    /// Visible feature vector of the `i`-th configuration.
    pub fn visible(&self, i: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.visible_into(i, &mut out);
        out
    }

    /// Fill `out` (cleared first) with the visible features of the
    /// `i`-th configuration — the allocation-free variant of
    /// [`ConfigSpace::visible`] the scoring sweep uses (bit-identical
    /// values).
    pub fn visible_into(&self, i: usize, out: &mut Vec<f64>) {
        self.features.fill(&self.schedule(i), out);
    }

    /// Visible-feature count (the row width of a scoring sweep's
    /// feature matrix).
    pub fn n_visible(&self) -> usize {
        self.features.n_features()
    }
}

// ------------------------------------------------------------ candidates --

/// Per-layer candidate knobs (ARCHITECTURE.md §Search space): divisors of the
/// output extent plus multiples of 4, channel-block multiples, 1/2/4/8/16
/// virtual threads; the extended kind adds the load-slot toggle and the
/// kernel-unroll factor. The space is the lazy cross product.
pub fn space_for(layer: &ConvLayer, kind: SpaceKind) -> ConfigSpace {
    let mut knobs = vec![
        Knob { name: KNOB_TH, values: spatial_candidates(layer.oh) },
        Knob { name: KNOB_TW, values: spatial_candidates(layer.ow) },
        Knob { name: KNOB_OC, values: oc_candidates(layer.kc) },
        Knob { name: KNOB_IC, values: ic_candidates(layer.c) },
        // the extended VTA exposes deeper virtual threading; each level
        // halves the per-thread scratchpad slice (capacity pressure is
        // the main source of the paper's 0.50–0.93 random invalidity)
        Knob { name: KNOB_VT, values: vec![1, 2, 4, 8, 16] },
    ];
    if kind == SpaceKind::Extended {
        knobs.push(Knob { name: KNOB_SLOTS, values: vec![1, 2] });
        // unroll values are deliberately layer-independent: on
        // 1x1-kernel layers legalization clamps them to 1, so those
        // points alias (exactly like clamped oversized tiles in the
        // paper space). Keeping the radix uniform keeps every layer's
        // extended space 6x — the invalid/redundant-region growth the
        // paper's model V exists to absorb — and keeps cross-layer
        // transfer working over one knob signature.
        knobs.push(Knob { name: KNOB_UNROLL, values: vec![1, 2, 4] });
    }
    ConfigSpace::new(kind, knobs)
}

/// Paper-exact space (shorthand for info/validation paths).
pub fn candidates(layer: &ConvLayer) -> ConfigSpace {
    space_for(layer, SpaceKind::Paper)
}

/// Divisors of `n` union multiples of 4 up to `n` (boundary-exercising;
/// the multiples keep the large-tile — mostly invalid — region densely
/// represented, mirroring the paper's 0.50–0.93 random invalidity band).
fn spatial_candidates(n: usize) -> Vec<usize> {
    let mut v: Vec<usize> =
        (1..=n).filter(|d| n % d == 0 || d % 4 == 0).collect();
    v.dedup();
    v
}

/// Multiples of 16 up to `kc`, thinned above 64 to keep spaces tractable.
fn oc_candidates(kc: usize) -> Vec<usize> {
    (1..=kc / 16)
        .map(|b| b * 16)
        .filter(|&v| v <= 64 || v % 32 == 0)
        .collect()
}

/// Divisors of `c` that are multiples of 16 (channel chunks must tile C
/// exactly; see `compiler::passes`).
fn ic_candidates(c: usize) -> Vec<usize> {
    (1..=c / 16)
        .map(|b| b * 16)
        .filter(|v| c % v == 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn space_sizes_are_sane() {
        for l in resnet18::LAYERS {
            let s = space_for(&l, SpaceKind::Paper);
            assert!(
                (500..20_000).contains(&s.len()),
                "{}: {}",
                l.name,
                s.len()
            );
            let e = space_for(&l, SpaceKind::Extended);
            assert_eq!(e.len(), s.len() * 6, "{}", l.name);
        }
    }

    #[test]
    fn nth_round_trips_through_index_of() {
        let l = resnet18::layer("conv5").unwrap();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let s = space_for(&l, kind);
            for i in (0..s.len()).step_by(7) {
                let c = s.nth(i);
                assert_eq!(s.index_of(&c), Some(i), "{kind:?}");
                assert_eq!(s.index_of_schedule(&s.schedule(i)), Some(i));
            }
        }
    }

    #[test]
    fn index_is_collision_free_identity() {
        // satellite regression: the old 64-bit bit-packed key collided
        // whenever two schedules differed only in knobs outside its
        // fixed fields (exactly what the new primitives are). The space
        // index distinguishes every enumerable point.
        let l = resnet18::layer("conv5").unwrap();
        let e = space_for(&l, SpaceKind::Extended);
        let a = e.schedule(0);
        let mut b = a;
        b.set_knob(KNOB_UNROLL, 4);
        let legacy_key = |s: &Schedule| -> u64 {
            // the removed Schedule::key() packing, frozen here
            (s.tile_h as u64) << 48
                | (s.tile_w as u64) << 32
                | (s.tile_oc as u64) << 20
                | (s.tile_ic as u64) << 8
                | s.n_vthreads as u64
        };
        assert_ne!(a, b);
        assert_eq!(legacy_key(&a), legacy_key(&b), "old packing collides");
        assert_ne!(e.index_of_schedule(&a), e.index_of_schedule(&b));
    }

    #[test]
    fn ic_candidates_divide_c() {
        for l in resnet18::LAYERS {
            let s = candidates(&l);
            let ic = &s
                .knobs()
                .iter()
                .find(|k| k.name == KNOB_IC)
                .unwrap()
                .values;
            for &v in ic {
                assert_eq!(l.c % v, 0);
            }
        }
    }

    #[test]
    fn visible_features_order() {
        let s = Schedule {
            tile_h: 4,
            tile_w: 8,
            tile_oc: 32,
            tile_ic: 16,
            n_vthreads: 2,
            ..Default::default()
        };
        let f = SpaceKind::Paper.visible_features(&s);
        assert_eq!(&f[..5], &[8.0, 4.0, 16.0, 32.0, 2.0]);
        assert_eq!(f.len(), SpaceKind::Paper.n_visible());
        assert_eq!(f[5], 32.0); // TW*TH
        assert_eq!(f[7], 8.0 * 4.0 * 32.0 * 2.0);
    }

    #[test]
    fn generated_names_match_the_legacy_hand_written_list() {
        assert_eq!(
            SpaceKind::Paper.visible_names(),
            vec![
                "TW",
                "TH",
                "tileIC",
                "tileOC",
                "nVirtualThread",
                "TW*TH",
                "TW*TH*tileOC",
                "TW*TH*tileOC*nVT",
                "tileIC*nVT",
                "TW*TH*tileIC*nVT",
                "tileOC*tileIC*nVT",
            ]
        );
        let ext = SpaceKind::Extended.visible_names();
        assert!(ext.contains(&"nLoadSlots".to_string()));
        assert!(ext.contains(&"kernelUnroll".to_string()));
        assert!(ext.contains(&"tileIC*nVT*nBuf".to_string()));
        assert_eq!(&ext[..11], &SpaceKind::Paper.visible_names()[..]);
    }

    #[test]
    fn extended_features_cover_new_knobs() {
        let l = resnet18::layer("conv5").unwrap();
        let e = space_for(&l, SpaceKind::Extended);
        // two extended configs equal on paper knobs but different in
        // slots/unroll must get different feature vectors
        let a = e.schedule(0); // slots=1, unroll=1 (ascending values)
        let mut b = a;
        b.set_knob(KNOB_SLOTS, 2);
        b.set_knob(KNOB_UNROLL, 4);
        let fa = SpaceKind::Extended.visible_features(&a);
        let fb = SpaceKind::Extended.visible_features(&b);
        assert_eq!(fa.len(), SpaceKind::Extended.n_visible());
        assert_ne!(fa, fb);
        // ...while the paper projection cannot tell them apart
        assert_eq!(
            SpaceKind::Paper.visible_features(&a),
            SpaceKind::Paper.visible_features(&b)
        );
    }

    #[test]
    fn featuregen_and_direct_decode_match_the_registry_paths() {
        // the hot-path decode (`schedule`, `visible_into`) must be
        // bit-identical to the declarative paths (`nth` + set_knob,
        // `SpaceKind::visible_features`) on both kinds
        let l = resnet18::layer("conv3").unwrap();
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let space = space_for(&l, kind);
            let fgen = FeatureGen::new(kind);
            assert_eq!(fgen.n_features(), kind.n_visible());
            let mut buf = Vec::new();
            for i in (0..space.len()).step_by(97) {
                // nth-based reference decode
                let c = space.nth(i);
                let mut want = Schedule::default();
                for (knob, &v) in space.knobs().iter().zip(&c.values) {
                    want.set_knob(knob.name, v);
                }
                let got = space.schedule(i);
                assert_eq!(got, want, "{kind:?} index {i}");
                let feats = kind.visible_features(&got);
                fgen.fill(&got, &mut buf);
                assert_eq!(buf, feats, "{kind:?} index {i}");
                space.visible_into(i, &mut buf);
                assert_eq!(buf, feats, "{kind:?} index {i}");
                assert_eq!(space.visible(i), feats, "{kind:?} index {i}");
            }
            assert_eq!(space.n_visible(), kind.n_visible());
        }
    }

    #[test]
    fn schedule_knob_accessors_round_trip() {
        let mut s = Schedule::default();
        for (i, name) in ALL_KNOB_NAMES.iter().enumerate() {
            assert!(s.set_knob(name, 16 + i));
            assert_eq!(s.knob(name), Some(16 + i));
        }
        assert!(!s.set_knob("notAKnob", 3));
        assert_eq!(s.knob("notAKnob"), None);
    }

    #[test]
    fn display_hides_paper_default_extension_knobs() {
        let s = Schedule {
            tile_h: 8,
            tile_w: 4,
            tile_oc: 32,
            tile_ic: 16,
            n_vthreads: 2,
            ..Default::default()
        };
        assert_eq!(s.to_string(), "th8_tw4_oc32_ic16_vt2");
        let e = Schedule { n_load_slots: 1, k_unroll: 4, ..s };
        assert_eq!(e.to_string(), "th8_tw4_oc32_ic16_vt2_buf1_u4");
    }
}
