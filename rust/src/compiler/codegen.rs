//! Code generation: conv2d + schedule → VTA instruction stream.
//!
//! Lowering structure (one output tile at a time, tiles round-robin across
//! virtual threads, `nLoadSlots` INP/WGT slots per thread — 2 is the
//! paper's double buffering, 1 serializes loads against compute):
//!
//! ```text
//! LoadUop (whole uop table, shared)
//! for tile (oh0, ow0, oc0):                  # thread t = tile_idx % nVT
//!   GEMM(reset)  over the tile's ACC region  # pops s2g after 1st tile/thread
//!   for ci in 0..C/tic:                      # load group (tile, ci)
//!     Memset/Load input halo rows → INP slot # pops g2l after `slots` groups
//!     Load weight chunk          → WGT slot  # last load pushes l2g
//!     for chunk of kernelUnroll (kh, kw)s:   # n_pos instrs when unroll=1
//!       GEMM accumulate                      # 1st pops l2g, last pushes g2l
//!   ALU shift-clip over ACC region           # pushes g2s
//!   Store tile rows                          # 1st pops g2s, last pushes s2g
//! Finish
//! ```
//!
//! The compiler *assumes* each thread owns `capacity / nVT` of every
//! scratchpad and never verifies it (the paper's premise: VTA-class backends
//! "lack the capacity for sophisticated back-end compilers"). Oversubscribed
//! schedules therefore produce real register errors or cross-thread aliasing
//! at (simulated) runtime — the invalid configurations ML²Tuner exists to
//! avoid.

use super::passes::TileAnalysis;
use crate::vta::config::VtaConfig;
use crate::vta::isa::{
    AluOp, Buffer, Dep, Dma, GemmLoop, Instr, Program, Uop,
};
use crate::workloads::ConvLayer;

/// Dynamic emission statistics — the "collected through internal branching"
/// half of the hidden features (paper §B.2), plus cost accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CompileStats {
    /// Instructions emitted.
    pub n_instrs: usize,
    /// DMA load instructions.
    pub n_loads: usize,
    /// Memset (reset) instructions.
    pub n_memsets: usize,
    /// GEMM instructions.
    pub n_gemms: usize,
    /// ALU instructions.
    pub n_alus: usize,
    /// DMA store instructions.
    pub n_stores: usize,
    /// Dummy (zero-fill) vectors emitted for interior tiles — the
    /// paper's `outDummyH(b0==0)`.
    pub dummy_vecs_interior: u64,
    /// Dummy vectors for boundary tiles — `outDummyH(b0!=0)`.
    pub dummy_vecs_boundary: u64,
    /// Dummy halo rows emitted for interior tiles.
    pub dummy_rows_interior: u64,
    /// Dummy halo rows emitted for boundary tiles.
    pub dummy_rows_boundary: u64,
    /// Full-size (interior) tiles lowered.
    pub tiles_interior: usize,
    /// Remainder (boundary) tiles lowered.
    pub tiles_boundary: usize,
    /// GEMM block operations emitted.
    pub gemm_block_ops: u64,
    /// Block-ops spent in reset (zero-fill) passes — not real MACs.
    pub reset_block_ops: u64,
    /// Total DMA traffic in bytes.
    pub dma_bytes: u64,
    /// Whether the virtual-thread lowering branch was taken.
    pub vthread_branch_taken: bool,
    /// Whether the thread split left uneven per-thread work.
    pub uneven_thread_split: bool,
}

/// Output of one compilation.
#[derive(Clone, Debug)]
pub struct Compiled {
    /// The lowered VTA program.
    pub program: Program,
    /// Dynamic emission statistics collected while lowering.
    pub stats: CompileStats,
    /// The resolved tile geometry the program was lowered under.
    pub analysis: TileAnalysis,
}

/// Lower `layer` under `sched`'s resolved analysis into a VTA program.
pub fn lower(
    cfg: &VtaConfig,
    layer: &ConvLayer,
    a: &TileAnalysis,
) -> Compiled {
    let blk = cfg.block();
    let mut prog = Program {
        dram_inp_vecs: layer.h * layer.w * a.cb_total,
        dram_wgt_blocks: a.kcb * layer.kh * layer.kw * a.cb_total,
        dram_out_vecs: layer.oh * layer.ow * a.kcb,
        ..Default::default()
    };
    let n_tiles = a.n_tiles();
    let mut st = CompileStats {
        vthread_branch_taken: a.nvt > 1,
        uneven_thread_split: a.nvt > 1 && n_tiles % a.nvt != 0,
        ..Default::default()
    };

    // ---- uop table ----------------------------------------------------
    //
    // unroll == 1 (paper lowering): one shared (nb, cb) uop block; the
    // kernel position lives in each GEMM instruction's inp/wgt base.
    //
    // unroll > 1: GEMM instructions cover `unroll` kernel positions at
    // once, so the position offsets must live in the uops themselves.
    // Layout is variant-major, then chunk, then nb, then (position, cb)
    // — per-nb blocks stay contiguous so a boundary-oc tile can address
    // the `nbc_e` prefix with one dense ubuf range. Boundary-width tiles
    // have a narrower input-halo row pitch, hence the second variant.
    let n_pos = a.n_pos;
    if a.unroll == 1 {
        for nb in 0..a.nbc {
            for cb in 0..a.cbc {
                prog.uops.push(Uop {
                    acc: nb,
                    inp: cb,
                    wgt: nb * n_pos * a.cbc + cb,
                });
            }
        }
    } else {
        let variants: &[usize] = if a.uop_variants == 2 {
            &[a.in_tile_w, a.in_tile_w_last]
        } else {
            &[a.in_tile_w]
        };
        for &in_w_v in variants {
            for chunk in 0..a.n_chunks {
                for nb in 0..a.nbc {
                    let p_end = n_pos.min((chunk + 1) * a.unroll);
                    for p in chunk * a.unroll..p_end {
                        let (kh, kw) = (p / layer.kw, p % layer.kw);
                        for cb in 0..a.cbc {
                            prog.uops.push(Uop {
                                acc: nb,
                                inp: (kh * in_w_v + kw) * a.cbc + cb,
                                wgt: nb * n_pos * a.cbc
                                    + (kh * layer.kw + kw) * a.cbc
                                    + cb,
                            });
                        }
                    }
                }
            }
        }
    }
    let reset_off = prog.uops.len();
    // stride between full chunks / between uop-table variants
    let chunk_stride = a.unroll * a.nbc * a.cbc;
    let variant_stride = n_pos * a.nbc * a.cbc;
    for nb in 0..a.nbc {
        prog.uops.push(Uop { acc: nb, inp: 0, wgt: 0 });
    }
    prog.instrs.push(Instr::LoadUop {
        sram_base: 0,
        uop_begin: 0,
        uop_end: prog.uops.len(),
        dep: Dep::push_next(), // first compute instr pops
    });

    // ---- tile enumeration, round-robin over virtual threads -----------
    // per-thread counters for dep-token priming
    let mut groups_per_thread = vec![0usize; a.nvt];
    let mut tiles_per_thread = vec![0usize; a.nvt];

    // per-thread scratch bases (the compiler's *assumed* partitioning)
    let inp_base_t = |t: usize| t * a.inp_slice;
    let wgt_base_t = |t: usize| t * a.wgt_slice;
    let acc_base_t = |t: usize| t * a.acc_slice;

    let mut first_compute = true;
    for tile_idx in 0..n_tiles {
        let t = tile_idx % a.nvt;
        // decompose: oc-major, then th, then tw (oc outermost reuses input)
        let ti_w = tile_idx % a.tiles_w;
        let ti_h = (tile_idx / a.tiles_w) % a.tiles_h;
        let ti_oc = tile_idx / (a.tiles_w * a.tiles_h);

        let oh0 = ti_h * a.th;
        let ow0 = ti_w * a.tw;
        let oc0b = ti_oc * a.nbc;

        // effective (boundary-resized) extents
        let th_e = a.th.min(layer.oh - oh0);
        let tw_e = a.tw.min(layer.ow - ow0);
        let nbc_e = a.nbc.min(a.kcb - oc0b);
        let in_h = (th_e - 1) * layer.stride + layer.kh;
        let in_w = (tw_e - 1) * layer.stride + layer.kw;
        let in_h0 = oh0 as isize * layer.stride as isize
            - layer.pad as isize;
        let in_w0 = ow0 as isize * layer.stride as isize
            - layer.pad as isize;

        let spatial_boundary = ti_h == 0
            || ti_w == 0
            || ti_h + 1 == a.tiles_h
            || ti_w + 1 == a.tiles_w;
        if spatial_boundary {
            st.tiles_boundary += 1;
        } else {
            st.tiles_interior += 1;
        }

        // ---- reset pass over the tile's ACC region --------------------
        let acc_b = acc_base_t(t);
        let tile_acc = th_e * tw_e * nbc_e;
        let mut dep = Dep::NONE;
        if first_compute {
            dep.pop_prev = true; // wait for LoadUop
            first_compute = false;
        }
        if tiles_per_thread[t] >= 1 {
            dep.pop_next = true; // wait for this thread's previous store
        }
        prog.instrs.push(Instr::Gemm {
            ubuf_begin: reset_off,
            ubuf_end: reset_off + nbc_e,
            lp0: GemmLoop {
                extent: th_e * tw_e,
                acc_off: nbc_e,
                inp_off: 0,
                wgt_off: 0,
            },
            lp1: GemmLoop { extent: 1, ..Default::default() },
            acc_base: acc_b,
            inp_base: 0,
            wgt_base: 0,
            reset: true,
            dep,
        });
        st.n_gemms += 1;
        st.reset_block_ops += (nbc_e * th_e * tw_e) as u64;

        // ---- channel chunks -------------------------------------------
        for ci in 0..a.n_ci {
            // load-slot rotation: with 2 slots (paper) a group may load
            // while the previous group computes; with 1 slot the load
            // must wait for its own buffer-free credit every group.
            let slot = groups_per_thread[t] % a.slots;
            let pop_credit = groups_per_thread[t] >= a.slots;
            groups_per_thread[t] += 1;
            let cb0 = ci * a.cbc;
            let inp_s = inp_base_t(t) + slot * a.inp_tile;
            let wgt_s = wgt_base_t(t) + slot * a.wgt_chunk;

            // load-group instructions collected, then flags applied
            let mut group: Vec<Instr> = Vec::new();

            // input halo rows (with padding memsets)
            for ih in 0..in_h {
                let src = in_h0 + ih as isize;
                let row_sram = inp_s + ih * in_w * a.cbc;
                if src < 0 || src >= layer.h as isize {
                    group.push(Instr::Memset {
                        buf: Buffer::Inp,
                        sram_base: row_sram,
                        count: in_w * a.cbc,
                        dep: Dep::NONE,
                    });
                    track_dummy(&mut st, spatial_boundary,
                                (in_w * a.cbc) as u64, 1);
                    continue;
                }
                let lead = (-in_w0).max(0) as usize;
                let lead = lead.min(in_w);
                let trail = ((in_w0 + in_w as isize)
                    - layer.w as isize)
                    .max(0) as usize;
                let trail = trail.min(in_w - lead);
                let valid = in_w - lead - trail;
                if lead > 0 {
                    group.push(Instr::Memset {
                        buf: Buffer::Inp,
                        sram_base: row_sram,
                        count: lead * a.cbc,
                        dep: Dep::NONE,
                    });
                    track_dummy(&mut st, spatial_boundary,
                                (lead * a.cbc) as u64, 0);
                }
                if valid > 0 {
                    let dram = (src as usize * layer.w
                        + (in_w0 + lead as isize) as usize)
                        * a.cb_total
                        + cb0;
                    group.push(Instr::Load {
                        buf: Buffer::Inp,
                        dma: Dma {
                            sram_base: row_sram + lead * a.cbc,
                            dram_base: dram,
                            rows: valid,
                            cols: a.cbc,
                            dram_stride: a.cb_total,
                        },
                        dep: Dep::NONE,
                    });
                }
                if trail > 0 {
                    group.push(Instr::Memset {
                        buf: Buffer::Inp,
                        sram_base: row_sram + (lead + valid) * a.cbc,
                        count: trail * a.cbc,
                        dep: Dep::NONE,
                    });
                    track_dummy(&mut st, spatial_boundary,
                                (trail * a.cbc) as u64, 0);
                }
            }

            // weight chunk: rows over (nb, kh, kw), cols over cb
            group.push(Instr::Load {
                buf: Buffer::Wgt,
                dma: Dma {
                    sram_base: wgt_s,
                    dram_base: (oc0b * layer.kh * layer.kw) * a.cb_total
                        + cb0,
                    rows: nbc_e * layer.kh * layer.kw,
                    cols: a.cbc,
                    dram_stride: a.cb_total,
                },
                dep: Dep::NONE,
            });

            // dep flags: first instr pops the slot credit, last pushes data
            if pop_credit {
                set_dep(&mut group, 0, |d| d.pop_next = true);
            }
            let last = group.len() - 1;
            set_dep(&mut group, last, |d| d.push_next = true);
            for ins in &group {
                match ins {
                    Instr::Load { .. } => st.n_loads += 1,
                    Instr::Memset { .. } => st.n_memsets += 1,
                    _ => {}
                }
            }
            prog.instrs.extend(group);

            let lp0 = GemmLoop {
                extent: th_e,
                acc_off: tw_e * nbc_e,
                inp_off: layer.stride * in_w * a.cbc,
                wgt_off: 0,
            };
            let lp1 = GemmLoop {
                extent: tw_e,
                acc_off: nbc_e,
                inp_off: layer.stride * a.cbc,
                wgt_off: 0,
            };
            if a.unroll == 1 {
                // gemm per kernel position (paper lowering)
                for kh in 0..layer.kh {
                    for kw in 0..layer.kw {
                        let first = kh == 0 && kw == 0;
                        let last =
                            kh + 1 == layer.kh && kw + 1 == layer.kw;
                        prog.instrs.push(Instr::Gemm {
                            ubuf_begin: 0,
                            ubuf_end: nbc_e * a.cbc,
                            lp0,
                            lp1,
                            acc_base: acc_b,
                            inp_base: inp_s + (kh * in_w + kw) * a.cbc,
                            wgt_base: wgt_s
                                + (kh * layer.kw + kw) * a.cbc,
                            reset: false,
                            dep: Dep {
                                pop_prev: first,
                                push_prev: last,
                                ..Dep::NONE
                            },
                        });
                        st.n_gemms += 1;
                    }
                }
            } else {
                // unrolled: one gemm per chunk of kernel positions; the
                // position offsets come from the expanded uop table
                // (variant 1 when this tile's halo rows are the narrow
                // boundary pitch)
                let variant = if a.uop_variants == 2 && tw_e != a.tw {
                    1
                } else {
                    0
                };
                for chunk in 0..a.n_chunks {
                    let u_e = (n_pos - chunk * a.unroll).min(a.unroll);
                    let base =
                        variant * variant_stride + chunk * chunk_stride;
                    let first = chunk == 0;
                    let last = chunk + 1 == a.n_chunks;
                    prog.instrs.push(Instr::Gemm {
                        // per-nb blocks inside a chunk are u_e·cbc uops,
                        // so the nbc_e prefix is one dense range
                        ubuf_begin: base,
                        ubuf_end: base + nbc_e * u_e * a.cbc,
                        lp0,
                        lp1,
                        acc_base: acc_b,
                        inp_base: inp_s,
                        wgt_base: wgt_s,
                        reset: false,
                        dep: Dep {
                            pop_prev: first,
                            push_prev: last,
                            ..Dep::NONE
                        },
                    });
                    st.n_gemms += 1;
                }
            }
        }

        // NOTE on the uop sub-ranges: uops are nb-major (within a chunk
        // for unrolled kernels), so a `[base, base + nbc_e·u_e·cbc)`
        // range covers exactly nb < nbc_e when cbc == a.cbc.

        // ---- requantize + store ---------------------------------------
        prog.instrs.push(Instr::Alu {
            op: AluOp::ShiftClip { shift: cfg.shift },
            acc_base: acc_b,
            count: tile_acc,
            dep: Dep::push_next(),
        });
        st.n_alus += 1;
        for r in 0..th_e {
            let first = r == 0;
            let last = r + 1 == th_e;
            prog.instrs.push(Instr::Store {
                dma: Dma {
                    sram_base: acc_b + r * tw_e * nbc_e,
                    dram_base: ((oh0 + r) * layer.ow + ow0) * a.kcb + oc0b,
                    rows: tw_e,
                    cols: nbc_e,
                    dram_stride: a.kcb,
                },
                dep: Dep {
                    pop_prev: first,
                    push_prev: last,
                    ..Dep::NONE
                },
            });
            st.n_stores += 1;
        }
        tiles_per_thread[t] += 1;
    }
    prog.instrs.push(Instr::Finish);

    st.n_instrs = prog.instrs.len();
    st.gemm_block_ops = prog.gemm_block_ops();
    st.dma_bytes = prog.dma_bytes(cfg);
    let _ = blk;
    Compiled { program: prog, stats: st, analysis: a.clone() }
}

fn track_dummy(
    st: &mut CompileStats,
    boundary: bool,
    vecs: u64,
    rows: u64,
) {
    if boundary {
        st.dummy_vecs_boundary += vecs;
        st.dummy_rows_boundary += rows;
    } else {
        st.dummy_vecs_interior += vecs;
        st.dummy_rows_interior += rows;
    }
}

fn set_dep(group: &mut [Instr], idx: usize, f: impl FnOnce(&mut Dep)) {
    let dep = match &mut group[idx] {
        Instr::Load { dep, .. }
        | Instr::Memset { dep, .. }
        | Instr::LoadUop { dep, .. }
        | Instr::Gemm { dep, .. }
        | Instr::Alu { dep, .. }
        | Instr::Store { dep, .. } => dep,
        Instr::Finish => return,
    };
    f(dep);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::analyze;
    use crate::compiler::schedule::Schedule;
    use crate::workloads::resnet18;

    fn compile(name: &str, s: Schedule) -> Compiled {
        let cfg = VtaConfig::zcu102();
        let layer = resnet18::layer(name).unwrap();
        let a = analyze(&cfg, &layer, &s);
        lower(&cfg, &layer, &a)
    }

    fn sched(th: usize, tw: usize, oc: usize, ic: usize, vt: usize)
        -> Schedule
    {
        Schedule { tile_h: th, tile_w: tw, tile_oc: oc, tile_ic: ic,
                   n_vthreads: vt, ..Default::default() }
    }

    #[test]
    fn gemm_block_ops_cover_all_macs() {
        // every MAC of the convolution must be issued exactly once: each
        // block-op is a 1×16 vector · 16×16 block = 256 MACs
        let c = compile("conv1", sched(8, 8, 64, 64, 1));
        let l = resnet18::layer("conv1").unwrap();
        let data_ops = c.stats.gemm_block_ops - c.stats.reset_block_ops;
        assert_eq!(data_ops * 256, l.macs());
    }

    #[test]
    fn gemm_block_ops_cover_all_macs_with_boundaries() {
        // 24 does not divide 56; boundary tiles are resized, not padded —
        // the MAC count must still be exact.
        let c = compile("conv1", sched(24, 24, 48, 32, 2));
        let l = resnet18::layer("conv1").unwrap();
        let data_ops = c.stats.gemm_block_ops - c.stats.reset_block_ops;
        assert_eq!(data_ops * 256, l.macs());
    }

    #[test]
    fn instruction_mix_counts() {
        let c = compile("conv5", sched(7, 7, 64, 64, 1));
        let st = &c.stats;
        assert_eq!(
            st.n_instrs,
            1 + st.n_loads + st.n_memsets + st.n_gemms + st.n_alus
                + st.n_stores + 1, // LoadUop + Finish
        );
        // conv5 is 1×1/pad0: no dummy halo at all
        assert_eq!(st.dummy_vecs_interior + st.dummy_vecs_boundary, 0);
    }

    #[test]
    fn padding_emits_dummy_rows_on_boundary_tiles_only() {
        let c = compile("conv1", sched(8, 8, 64, 64, 1)); // pad=1
        assert!(c.stats.dummy_vecs_boundary > 0);
        assert_eq!(c.stats.dummy_vecs_interior, 0);
    }

    #[test]
    fn one_alu_and_th_stores_per_tile() {
        let c = compile("conv4", sched(7, 7, 128, 128, 1));
        let a = &c.analysis;
        assert_eq!(c.stats.n_alus, a.n_tiles());
        assert_eq!(c.stats.n_stores, a.n_tiles() * a.th);
    }

    #[test]
    fn vthread_branch_flags() {
        assert!(!compile("conv5", sched(7, 7, 64, 64, 1))
            .stats
            .vthread_branch_taken);
        let c = compile("conv5", sched(7, 7, 64, 64, 2));
        assert!(c.stats.vthread_branch_taken);
        // 2×2×4 tiles = 16 tiles % 2 == 0 → even split
        assert!(!c.stats.uneven_thread_split);
    }

    #[test]
    fn unroll_preserves_macs_and_shrinks_instruction_count() {
        let l = resnet18::layer("conv1").unwrap(); // 3x3 kernel
        let base = sched(8, 8, 64, 64, 1);
        let c1 = compile("conv1", base);
        let c4 = compile("conv1", Schedule { k_unroll: 4, ..base });
        // every MAC still issued exactly once
        let ops = |c: &Compiled| {
            c.stats.gemm_block_ops - c.stats.reset_block_ops
        };
        assert_eq!(ops(&c1) * 256, l.macs());
        assert_eq!(ops(&c4) * 256, l.macs());
        // 9 kernel positions collapse into ceil(9/4)=3 chunks per group
        // (n_gemms also counts the one reset pass per tile)
        let data_gemms = |c: &Compiled| {
            c.stats.n_gemms - c.analysis.n_tiles()
        };
        let groups = c1.analysis.n_tiles() * c1.analysis.n_ci;
        assert_eq!(data_gemms(&c1), groups * 9);
        assert_eq!(data_gemms(&c4), groups * 3);
        // ...at the cost of a position-expanded uop table
        assert!(c4.program.uops.len() > c1.program.uops.len());
        assert_eq!(c4.program.uops.len(), c4.analysis.uop_count);
    }

    #[test]
    fn unroll_boundary_tiles_use_their_own_uop_variant() {
        // 24 does not divide 56: boundary tiles have a narrower input
        // halo, so unrolled GEMMs must address a second uop variant
        let c = compile("conv1", Schedule { k_unroll: 2,
                                            ..sched(24, 24, 48, 32, 1) });
        assert_eq!(c.analysis.uop_variants, 2);
        let variant_stride =
            c.analysis.n_pos * c.analysis.nbc * c.analysis.cbc;
        let mut saw_variant1 = false;
        for ins in &c.program.instrs {
            if let Instr::Gemm { ubuf_begin, reset: false, .. } = ins {
                if *ubuf_begin >= variant_stride
                    && *ubuf_begin < 2 * variant_stride
                {
                    saw_variant1 = true;
                }
            }
        }
        assert!(saw_variant1, "no GEMM addressed the boundary variant");
    }

    #[test]
    fn single_buffered_loads_pop_their_credit_every_group() {
        // slots=1: each load group must wait for its own buffer-free
        // token (pop after 1 group), vs slots=2 popping after 2
        let base = sched(8, 8, 32, 64, 1);
        let count_popping_loads = |c: &Compiled| {
            c.program
                .instrs
                .iter()
                .filter(|i| {
                    matches!(i,
                        Instr::Load { dep, .. } | Instr::Memset { dep, .. }
                        if dep.pop_next)
                })
                .count()
        };
        let double = compile("conv1", base);
        let single =
            compile("conv1", Schedule { n_load_slots: 1, ..base });
        assert!(count_popping_loads(&single)
                    > count_popping_loads(&double));
        // programs are otherwise the same shape: identical gemm count
        assert_eq!(single.stats.n_gemms, double.stats.n_gemms);
    }

    #[test]
    fn dram_descriptor_sizes() {
        let c = compile("conv2", sched(4, 4, 32, 64, 1));
        let l = resnet18::layer("conv2").unwrap();
        assert_eq!(c.program.dram_inp_vecs, l.h * l.w * l.c / 16);
        assert_eq!(c.program.dram_out_vecs, l.oh * l.ow * l.kc / 16);
        assert_eq!(
            c.program.dram_wgt_blocks,
            (l.kc / 16) * l.kh * l.kw * (l.c / 16)
        );
    }
}
