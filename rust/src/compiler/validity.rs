//! Static validity analysis — deliberately *incomplete*, mirroring the
//! paper's premise.
//!
//! Real VTA backends (the Glow integration the paper extends) can reject
//! only the grossest scheduling mistakes; the hard failures (per-thread
//! slice overflow under virtual threading, double-buffer spill, ACC wrap)
//! surface at runtime. This pass checks a *single-buffered, single-thread*
//! footprint against the *full* capacity — so everything it accepts can
//! still crash or corrupt on the device, and that residue is exactly what
//! cost model V has to learn.

use super::passes::TileAnalysis;
use crate::vta::config::VtaConfig;

/// Outcome of the static check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StaticCheck {
    /// Nothing obviously wrong (may still be invalid at runtime!).
    Plausible,
    /// Rejected: the footprint can never fit even ideally.
    Hopeless(String),
}

impl StaticCheck {
    pub fn is_plausible(&self) -> bool {
        matches!(self, StaticCheck::Plausible)
    }
}

/// The weak static check (see module docs).
pub fn static_check(cfg: &VtaConfig, a: &TileAnalysis) -> StaticCheck {
    if a.acc_tile > cfg.acc_capacity() {
        return StaticCheck::Hopeless(format!(
            "ACC tile {} vectors exceeds the whole buffer ({})",
            a.acc_tile,
            cfg.acc_capacity()
        ));
    }
    if a.inp_tile > cfg.inp_capacity() {
        return StaticCheck::Hopeless(format!(
            "input halo tile {} vectors exceeds the whole buffer ({})",
            a.inp_tile,
            cfg.inp_capacity()
        ));
    }
    if a.wgt_chunk > cfg.wgt_capacity() {
        return StaticCheck::Hopeless(format!(
            "weight chunk {} blocks exceeds the whole buffer ({})",
            a.wgt_chunk,
            cfg.wgt_capacity()
        ));
    }
    if a.uop_count > cfg.uop_capacity() {
        return StaticCheck::Hopeless(format!(
            "uop table {} exceeds the uop buffer ({})",
            a.uop_count,
            cfg.uop_capacity()
        ));
    }
    StaticCheck::Plausible
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::analyze;
    use crate::compiler::schedule::Schedule;
    use crate::workloads::resnet18;

    #[test]
    fn small_tiles_plausible() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        let s = Schedule { tile_h: 8, tile_w: 8, tile_oc: 32, tile_ic: 32,
                           n_vthreads: 1 };
        assert!(static_check(&cfg, &analyze(&cfg, &l, &s)).is_plausible());
    }

    #[test]
    fn whole_image_tile_is_hopeless_on_conv1() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        // 56×56 output tile, full channels: acc = 56*56*4 = 12544 > 4096
        let s = Schedule { tile_h: 56, tile_w: 56, tile_oc: 64, tile_ic: 64,
                           n_vthreads: 1 };
        let chk = static_check(&cfg, &analyze(&cfg, &l, &s));
        assert!(!chk.is_plausible(), "{chk:?}");
    }

    #[test]
    fn static_check_is_weaker_than_runtime() {
        // The whole point: a schedule whose *double-buffered, per-thread*
        // footprint overflows still passes the static check.
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        // inp_tile = 30*30*4 = 3600 ≤ 4096, but 2 slots × nvt=4 is 7× over
        let s = Schedule { tile_h: 28, tile_w: 28, tile_oc: 16, tile_ic: 64,
                           n_vthreads: 4 };
        assert!(static_check(&cfg, &analyze(&cfg, &l, &s)).is_plausible());
    }
}
