//! Static validity analysis — deliberately *incomplete*, mirroring the
//! paper's premise.
//!
//! Real VTA backends (the Glow integration the paper extends) can reject
//! only the grossest scheduling mistakes; the hard failures (per-thread
//! slice overflow under virtual threading, double-buffer spill, ACC wrap)
//! surface at runtime. This pass checks a *single-buffered, single-thread*
//! footprint against the *full* capacity — so everything it accepts can
//! still crash or corrupt on the device, and that residue is exactly what
//! cost model V has to learn.

use super::passes::TileAnalysis;
use crate::vta::config::VtaConfig;

/// Outcome of the static check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StaticCheck {
    /// Nothing obviously wrong (may still be invalid at runtime!).
    Plausible,
    /// Rejected: the footprint can never fit even ideally.
    Hopeless(String),
}

impl StaticCheck {
    /// Whether the check passed (which still guarantees nothing).
    pub fn is_plausible(&self) -> bool {
        matches!(self, StaticCheck::Plausible)
    }
}

/// The weak static check (see module docs).
pub fn static_check(cfg: &VtaConfig, a: &TileAnalysis) -> StaticCheck {
    if a.acc_tile > cfg.acc_capacity() {
        return StaticCheck::Hopeless(format!(
            "ACC tile {} vectors exceeds the whole buffer ({})",
            a.acc_tile,
            cfg.acc_capacity()
        ));
    }
    if a.inp_tile > cfg.inp_capacity() {
        return StaticCheck::Hopeless(format!(
            "input halo tile {} vectors exceeds the whole buffer ({})",
            a.inp_tile,
            cfg.inp_capacity()
        ));
    }
    if a.wgt_chunk > cfg.wgt_capacity() {
        return StaticCheck::Hopeless(format!(
            "weight chunk {} blocks exceeds the whole buffer ({})",
            a.wgt_chunk,
            cfg.wgt_capacity()
        ));
    }
    if a.uop_count > cfg.uop_capacity() {
        return StaticCheck::Hopeless(format!(
            "uop table {} exceeds the uop buffer ({})",
            a.uop_count,
            cfg.uop_capacity()
        ));
    }
    StaticCheck::Plausible
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::analyze;
    use crate::compiler::schedule::{space_for, Schedule, SpaceKind};
    use crate::compiler::Compiler;
    use crate::vta::Simulator;
    use crate::workloads::{resnet18, vgg16};

    fn sched(th: usize, tw: usize, oc: usize, ic: usize, vt: usize)
        -> Schedule
    {
        Schedule { tile_h: th, tile_w: tw, tile_oc: oc, tile_ic: ic,
                   n_vthreads: vt, ..Default::default() }
    }

    /// Which Hopeless arm fired, by message prefix.
    fn hopeless_reason(chk: &StaticCheck) -> &str {
        match chk {
            StaticCheck::Plausible => "plausible",
            StaticCheck::Hopeless(m) if m.starts_with("ACC") => "acc",
            StaticCheck::Hopeless(m) if m.starts_with("input") => "inp",
            StaticCheck::Hopeless(m) if m.starts_with("weight") => "wgt",
            StaticCheck::Hopeless(m) if m.starts_with("uop") => "uop",
            StaticCheck::Hopeless(_) => "other",
        }
    }

    fn check_of(l: &crate::workloads::ConvLayer, s: Schedule)
        -> StaticCheck
    {
        let cfg = VtaConfig::zcu102();
        static_check(&cfg, &analyze(&cfg, l, &s))
    }

    #[test]
    fn small_tiles_plausible() {
        let l = resnet18::layer("conv1").unwrap();
        assert!(check_of(&l, sched(8, 8, 32, 32, 1)).is_plausible());
    }

    #[test]
    fn acc_overflow_arm_fires() {
        let l = resnet18::layer("conv1").unwrap();
        // 56×56 output tile, full channels: acc = 56*56*4 = 12544 > 4096
        let chk = check_of(&l, sched(56, 56, 64, 64, 1));
        assert_eq!(hopeless_reason(&chk), "acc", "{chk:?}");
    }

    #[test]
    fn inp_overflow_arm_fires() {
        // conv4 (28×28, C=128, 3×3): the whole-image halo is
        // 30·30·(128/16) = 7200 input vectors > 4096, while a single
        // oc block keeps acc at 28·28·1 = 784 ≤ 4096
        let l = resnet18::layer("conv4").unwrap();
        let chk = check_of(&l, sched(28, 28, 16, 128, 1));
        assert_eq!(hopeless_reason(&chk), "inp", "{chk:?}");
    }

    #[test]
    fn wgt_overflow_arm_fires() {
        // vgg16 3×3 512→512: 512/16 · 9 · 512/16 = 9216 blocks > 2048,
        // with a small spatial tile so acc/inp stay in bounds
        let l = vgg16::LAYERS
            .iter()
            .find(|l| l.c == 512 && l.kc == 512)
            .copied()
            .expect("vgg16 has a 512->512 conv");
        let chk = check_of(&l, sched(2, 2, 512, 512, 1));
        assert_eq!(hopeless_reason(&chk), "wgt", "{chk:?}");
    }

    #[test]
    fn uop_overflow_arm_fires() {
        // the kernel-unroll primitive is what makes the uop arm
        // reachable: a position-expanded table multiplies uop_count by
        // kh·kw. On the zcu102's 16K-uop buffer the weight check always
        // trips first, so exercise the arm on a design point with a
        // small uop buffer (where it is the binding constraint).
        let cfg = VtaConfig {
            log_uop_buff_size: 12, // 1024 uops
            ..VtaConfig::zcu102()
        };
        let l = vgg16::LAYERS
            .iter()
            .find(|l| l.c == 512 && l.kc == 512)
            .copied()
            .expect("vgg16 has a 512->512 conv");
        // tw=4 divides 28 → single uop variant; nbc·cbc = 4·32 = 128 →
        // unrolled table 9·128 + 4 = 1156 > 1024, while wgt chunk
        // 9·128 = 1152 ≤ 2048 and acc/inp stay small
        let s = Schedule { k_unroll: 4, ..sched(4, 4, 64, 512, 1) };
        let a = analyze(&cfg, &l, &s);
        assert!(a.uop_count > cfg.uop_capacity(), "premise: {}",
                a.uop_count);
        let chk = static_check(&cfg, &a);
        assert_eq!(hopeless_reason(&chk), "uop", "{chk:?}");
        // the same schedule un-unrolled fits easily
        let a1 = analyze(&cfg, &l, &sched(4, 4, 64, 512, 1));
        assert!(static_check(&cfg, &a1).is_plausible());
    }

    #[test]
    fn static_check_is_weaker_than_runtime() {
        // The whole point: a schedule whose *double-buffered, per-thread*
        // footprint overflows still passes the static check.
        let l = resnet18::layer("conv1").unwrap();
        // inp_tile = 30*30*4 = 3600 ≤ 4096, but 2 slots × nvt=4 is 7× over
        assert!(check_of(&l, sched(28, 28, 16, 64, 4)).is_plausible());
    }

    #[test]
    fn plausible_residue_contains_runtime_invalid_configs() {
        // the residue contract: the static check accepts configurations
        // the simulator rejects — exactly what model V learns to filter
        let cfg = VtaConfig::zcu102();
        let compiler = Compiler::new(cfg.clone());
        let sim = Simulator::new(cfg.clone());
        let l = resnet18::layer("conv1").unwrap();
        let s = sched(28, 28, 16, 64, 4);
        let a = analyze(&cfg, &l, &s);
        assert!(static_check(&cfg, &a).is_plausible());
        let compiled = compiler.compile(&l, &s);
        assert!(!sim.check(&compiled.program).is_valid(),
                "plausible-but-crashes residue config ran validly");
    }

    #[test]
    fn prop_hopeless_implies_runtime_invalid() {
        // property: everything the static check rejects must also fail
        // at (simulated) runtime — Hopeless is a sound subset of
        // invalid. Swept over a stride of both spaces on two layers
        // with very different capacity profiles.
        let cfg = VtaConfig::zcu102();
        let compiler = Compiler::new(cfg.clone());
        let sim = Simulator::new(cfg.clone());
        let layers = [
            resnet18::layer("conv1").unwrap(),
            vgg16::LAYERS
                .iter()
                .find(|l| l.c == 512 && l.kc == 512)
                .copied()
                .unwrap(),
        ];
        let mut hopeless_seen = 0usize;
        for l in layers {
            for kind in [SpaceKind::Paper, SpaceKind::Extended] {
                let space = space_for(&l, kind);
                // cap per (layer, kind) so the sweep stays fast in debug
                // builds; the stride already spreads it across the space
                let mut budget = 12usize;
                for i in (0..space.len()).step_by(97) {
                    if budget == 0 {
                        break;
                    }
                    let s = space.schedule(i);
                    let a = analyze(&cfg, &l, &s);
                    if static_check(&cfg, &a).is_plausible() {
                        continue;
                    }
                    hopeless_seen += 1;
                    budget -= 1;
                    let compiled = compiler.compile(&l, &s);
                    let verdict = sim.check(&compiled.program);
                    assert!(
                        !verdict.is_valid(),
                        "{} {s}: Hopeless statically but ran validly",
                        l.name
                    );
                }
            }
        }
        assert!(hopeless_seen > 20,
                "sweep found too few Hopeless configs ({hopeless_seen}) \
                 to mean anything");
    }
}
