//! Analysis passes: schedule legalization and tile-geometry resolution.
//!
//! This is where the paper's *hidden features* come from — "values derived
//! from visible features or collected through internal branching mechanisms"
//! (§B.2): resolved tile sizes, boundary/remainder geometry, halo extents,
//! per-thread scratchpad slices. The backend compiler (codegen) consumes the
//! analysis; `features.rs` exports it to Model A.

use super::schedule::Schedule;
use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;

/// Resolved tile geometry for one (layer, schedule) pair.
#[derive(Clone, Debug, PartialEq)]
pub struct TileAnalysis {
    /// Legalized tile height (clamped to the layer).
    pub th: usize,
    /// Legalized tile width.
    pub tw: usize,
    /// Legalized output-channel tile.
    pub toc: usize,
    /// Legalized input-channel tile (snapped to a divisor of `C`).
    pub tic: usize,
    /// Legalized virtual-thread count.
    pub nvt: usize,

    /// Tile-grid extent along output height.
    pub tiles_h: usize,
    /// Tile-grid extent along output width.
    pub tiles_w: usize,
    /// Tile-grid extent along output channels.
    pub tiles_oc: usize,
    /// Channel chunks per tile (`C / tic`).
    pub n_ci: usize,

    /// Output-channel blocks per tile (`toc/16`).
    pub nbc: usize,
    /// Input-channel blocks per chunk (`tic/16`).
    pub cbc: usize,
    /// Output-channel blocks of the whole layer (`KC/16`).
    pub kcb: usize,
    /// Input-channel blocks of the whole layer (`C/16`).
    pub cb_total: usize,

    /// Boundary-tile height remainder (0 ⇒ exact division; the
    /// `b0 != 0` branch of the paper's feature names is "this tile is a
    /// boundary tile").
    pub th_last: usize,
    /// Boundary-tile width remainder.
    pub tw_last: usize,
    /// Boundary-tile output-channel-block remainder.
    pub nbc_last: usize,

    /// Input halo height of an interior (full-size) tile.
    pub in_tile_h: usize,
    /// Input halo width of an interior tile.
    pub in_tile_w: usize,
    /// Input halo height of the boundary (remainder) tile.
    pub in_tile_h_last: usize,
    /// Input halo width of the boundary tile.
    pub in_tile_w_last: usize,

    /// Accumulator footprint (elements) of a full-size tile.
    pub acc_tile: usize,
    /// Input footprint (elements) of a full-size tile.
    pub inp_tile: usize,
    /// Weight-chunk footprint (elements).
    pub wgt_chunk: usize,
    /// Micro-op table entries one tile needs.
    pub uop_count: usize,

    /// Per-virtual-thread input scratchpad slice the compiler assumes.
    pub inp_slice: usize,
    /// Per-virtual-thread weight scratchpad slice.
    pub wgt_slice: usize,
    /// Per-virtual-thread accumulator slice.
    pub acc_slice: usize,

    /// Load-buffer slots per thread (2 = double buffering, paper-fixed;
    /// 1 = single-buffered). Effective INP/WGT footprint per thread is
    /// `slots × tile`, which is what actually hits the slice at runtime.
    pub slots: usize,
    /// Resolved kernel-position unroll factor (clamped to `kh·kw`).
    pub unroll: usize,
    /// Kernel positions (`kh·kw`).
    pub n_pos: usize,
    /// GEMM instructions per channel chunk: `ceil(n_pos / unroll)`.
    pub n_chunks: usize,
    /// Uop-table variants an unrolled kernel needs (interior vs
    /// boundary-width tiles differ in input-halo row pitch).
    pub uop_variants: usize,
}

impl TileAnalysis {
    /// Total tiles in the grid.
    pub fn n_tiles(&self) -> usize {
        self.tiles_h * self.tiles_w * self.tiles_oc
    }
}

/// Legalize a schedule against a layer and resolve the tile geometry.
pub fn analyze(
    cfg: &VtaConfig,
    layer: &ConvLayer,
    sched: &Schedule,
) -> TileAnalysis {
    let blk = cfg.block();
    assert_eq!(layer.c % blk, 0, "C must be a block multiple");
    assert_eq!(layer.kc % blk, 0, "KC must be a block multiple");

    let th = sched.tile_h.clamp(1, layer.oh);
    let tw = sched.tile_w.clamp(1, layer.ow);
    let toc = snap_block(sched.tile_oc.clamp(blk, layer.kc), blk);
    // tic must divide C so channel chunks tile exactly: snap to the largest
    // block-multiple divisor ≤ requested.
    let tic = largest_divisor_le(
        layer.c,
        snap_block(sched.tile_ic.clamp(blk, layer.c), blk),
        blk,
    );
    let nvt = sched.n_vthreads.max(1);

    let tiles_h = layer.oh.div_ceil(th);
    let tiles_w = layer.ow.div_ceil(tw);
    let tiles_oc = layer.kc.div_ceil(toc);
    let n_ci = layer.c / tic;

    let nbc = toc / blk;
    let cbc = tic / blk;
    let kcb = layer.kc / blk;
    let cb_total = layer.c / blk;

    let rem = |total: usize, tile: usize| {
        let r = total % tile;
        if r == 0 { tile } else { r }
    };
    let th_last = rem(layer.oh, th);
    let tw_last = rem(layer.ow, tw);
    let nbc_last = rem(kcb, nbc);

    let halo = |t: usize, k: usize| (t - 1) * layer.stride + k;
    let in_tile_h = halo(th, layer.kh);
    let in_tile_w = halo(tw, layer.kw);
    let in_tile_h_last = halo(th_last, layer.kh);
    let in_tile_w_last = halo(tw_last, layer.kw);

    // extension knobs: load-slot count and kernel unroll. `unroll == 1`
    // reproduces the paper-fixed lowering exactly; `unroll > 1` packs
    // kernel positions into shared-uop GEMM instructions, which needs a
    // position-expanded uop table — one copy per distinct input-halo row
    // pitch (interior vs boundary-width tiles).
    let n_pos = layer.kh * layer.kw;
    let slots = sched.n_load_slots.clamp(1, 2);
    let unroll = sched.k_unroll.clamp(1, n_pos);
    let n_chunks = n_pos.div_ceil(unroll);
    let uop_variants =
        if unroll > 1 && in_tile_w != in_tile_w_last { 2 } else { 1 };
    let uop_count = if unroll == 1 {
        nbc * cbc + nbc // shared gemm uops + reset uops
    } else {
        uop_variants * n_pos * nbc * cbc + nbc
    };

    TileAnalysis {
        th, tw, toc, tic, nvt,
        tiles_h, tiles_w, tiles_oc, n_ci,
        nbc, cbc, kcb, cb_total,
        th_last, tw_last, nbc_last,
        in_tile_h, in_tile_w, in_tile_h_last, in_tile_w_last,
        acc_tile: th * tw * nbc,
        inp_tile: in_tile_h * in_tile_w * cbc,
        wgt_chunk: nbc * layer.kh * layer.kw * cbc,
        uop_count,
        inp_slice: cfg.inp_capacity() / nvt,
        wgt_slice: cfg.wgt_capacity() / nvt,
        acc_slice: cfg.acc_capacity() / nvt,
        slots, unroll, n_pos, n_chunks, uop_variants,
    }
}

fn snap_block(v: usize, blk: usize) -> usize {
    (v / blk).max(1) * blk
}

/// Largest divisor of `c` that is a multiple of `blk` and ≤ `want`.
fn largest_divisor_le(c: usize, want: usize, blk: usize) -> usize {
    let mut best = blk;
    let mut d = blk;
    while d <= c {
        if c % d == 0 && d <= want {
            best = d;
        }
        d += blk;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    fn sched(th: usize, tw: usize, oc: usize, ic: usize, vt: usize)
        -> Schedule
    {
        Schedule { tile_h: th, tile_w: tw, tile_oc: oc, tile_ic: ic,
                   n_vthreads: vt, ..Default::default() }
    }

    #[test]
    fn exact_division_no_remainder() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap(); // 56×56, KC=64
        let a = analyze(&cfg, &l, &sched(8, 8, 32, 32, 2));
        assert_eq!((a.tiles_h, a.tiles_w, a.tiles_oc), (7, 7, 2));
        assert_eq!((a.th_last, a.tw_last), (8, 8)); // exact → full size
        assert_eq!(a.n_ci, 2);
        assert_eq!(a.in_tile_h, (8 - 1) * 1 + 3);
    }

    #[test]
    fn boundary_remainders() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        let a = analyze(&cfg, &l, &sched(24, 24, 48, 64, 1));
        assert_eq!(a.tiles_h, 3); // 24+24+8
        assert_eq!(a.th_last, 8);
        assert_eq!(a.tiles_oc, 2); // 48+16
        assert_eq!(a.nbc_last, 1);
    }

    #[test]
    fn tic_snaps_to_divisor() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv4").unwrap(); // C=128
        let a = analyze(&cfg, &l, &sched(4, 4, 32, 48, 1));
        assert_eq!(a.tic, 32, "48 does not divide 128 → snap down to 32");
        assert_eq!(l.c % a.tic, 0);
    }

    #[test]
    fn clamps_oversized_tiles() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv5").unwrap(); // 14×14
        let a = analyze(&cfg, &l, &sched(100, 100, 512, 512, 4));
        assert_eq!((a.th, a.tw), (14, 14));
        assert_eq!(a.toc, l.kc);
        assert_eq!(a.tic, l.c);
        assert_eq!(a.n_tiles(), 1);
    }

    #[test]
    fn stride_widens_halo() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv3").unwrap(); // 3×3 stride 2
        let a = analyze(&cfg, &l, &sched(4, 4, 32, 32, 1));
        assert_eq!(a.in_tile_h, (4 - 1) * 2 + 3); // = 9
        assert_eq!(a.in_tile_w, 9);
    }

    #[test]
    fn extension_knobs_resolve_and_clamp() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap(); // 3x3 kernel
        let base = sched(8, 8, 32, 32, 1);
        let a = analyze(&cfg, &l, &base);
        assert_eq!((a.slots, a.unroll, a.n_chunks), (2, 1, 9));
        assert_eq!(a.uop_count, a.nbc * a.cbc + a.nbc, "paper layout");

        let u4 = Schedule { k_unroll: 4, ..base };
        let a4 = analyze(&cfg, &l, &u4);
        assert_eq!(a4.unroll, 4);
        assert_eq!(a4.n_chunks, 3); // ceil(9/4)
        assert_eq!(a4.uop_variants, 1, "8 divides 56: no boundary pitch");
        assert_eq!(a4.uop_count, 9 * a4.nbc * a4.cbc + a4.nbc);

        // 24 does not divide 56 → boundary tiles have a narrower halo →
        // a second uop-table variant
        let ragged = Schedule { k_unroll: 2, ..sched(8, 24, 32, 32, 1) };
        let ar = analyze(&cfg, &l, &ragged);
        assert_eq!(ar.uop_variants, 2);
        assert_eq!(ar.uop_count, 2 * 9 * ar.nbc * ar.cbc + ar.nbc);

        // 1x1 kernels have a single position: unroll clamps back to the
        // paper lowering
        let pw = resnet18::layer("conv5").unwrap();
        let ap = analyze(&cfg, &pw, &Schedule { k_unroll: 4,
                                                ..sched(7, 7, 32, 32, 1) });
        assert_eq!((ap.unroll, ap.n_chunks), (1, 1));
        assert_eq!(ap.uop_count, ap.nbc * ap.cbc + ap.nbc);

        // slot toggle resolves, and 0/oversized values clamp
        let single = Schedule { n_load_slots: 1, ..base };
        assert_eq!(analyze(&cfg, &l, &single).slots, 1);
        let wild = Schedule { n_load_slots: 9, k_unroll: 0, ..base };
        let aw = analyze(&cfg, &l, &wild);
        assert_eq!((aw.slots, aw.unroll), (2, 1));
    }

    #[test]
    fn slices_divide_capacity() {
        let cfg = VtaConfig::zcu102();
        let l = resnet18::layer("conv1").unwrap();
        let a = analyze(&cfg, &l, &sched(8, 8, 32, 32, 4));
        assert_eq!(a.inp_slice, cfg.inp_capacity() / 4);
        assert_eq!(a.acc_slice, cfg.acc_capacity() / 4);
    }
}
