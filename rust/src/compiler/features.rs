//! Feature extraction for the cost models.
//!
//! * **Visible features** (models P and V): generated from the search
//!   space's knob list by the declarative registry in
//!   [`crate::compiler::schedule::SpaceKind`] — raw knob values plus
//!   derived products.
//! * **Hidden features** (model A only): quantities that exist only after
//!   the backend compiler has run — resolved/boundary tile geometry, dummy
//!   regions, branch decisions, instruction/DMA/uop statistics. Names follow
//!   paper Table 5 where the quantity matches; the compiler-statistics tail
//!   is our honest extension of "details about the optimization and internal
//!   tiling strategies during the code generation process" (§3).
//!
//! Hidden features are keyed by [`SpaceKind`] like visible features: the
//! paper space extracts exactly the paper's Table-5 list (byte-identical
//! to the original implementation), the extended space appends the
//! geometry the new primitives resolve to (load slots, unroll chunking,
//! uop-table size) so model A can see what lowering did with them.

use super::codegen::{CompileStats, Compiled};
use super::passes::TileAnalysis;
use super::schedule::SpaceKind;

/// Paper hidden-feature names, aligned with the first
/// [`hidden_len`]`(SpaceKind::Paper)` entries of [`hidden_features`].
///
/// Exactly the paper's Table 5 hidden-feature list: geometry resolved by
/// legalization, boundary/dummy regions, and branch flags. Raw codegen
/// statistics (instruction counts, DMA bytes, …) stay in `CompileStats`
/// for diagnostics but are NOT model inputs — the paper's extractor
/// collects "values affected by conditional expressions and variations
/// resulting from branch statements", not whole-program cost counters
/// (feeding those in makes model A trivially strong and collapses the
/// Table 5 importance distribution).
pub const HIDDEN_NAMES: [&str; 21] = [
    "nVirtualThread > 0 (threadIdx)",
    "nVirtualThread > 0 (threadIdx)2",
    "nFilterInLoop",
    "nFilterInLoop (b0!=0)",
    "sizeOutTileH",
    "sizeOutTileW",
    "sizeOutTileBoundaryW",
    "outDummyH (b0==0)",
    "outDummyH (b0!=0)",
    "resizedOutTileH (b0==0)",
    "resizedOutTileH (b0!=0)",
    "Kn / nFilterInLoop / nVirtualThread / 16",
    "sizeInTileW",
    "sizeInTileH",
    "resizedInTileH (b0==0)",
    "resizedInTileH (b0!=0)",
    // "iteration counts from configurations" (paper §3) — loop trip
    // counts and scratchpad footprints resolved during lowering
    "numTiles",
    "numCiChunks",
    "numDummyVecsPerTile",
    "inpTileVecs",
    "accTileVecs",
];

/// Extra hidden features of the extended space: what lowering resolved
/// the new primitives to. All are "internal branching" quantities in the
/// paper's sense — they only exist after legalization/codegen.
pub const HIDDEN_NAMES_EXTENDED: [&str; 4] = [
    "nLoadSlots (resolved)",
    "kernelUnroll (resolved)",
    "nGemmChunks",
    "uopTableLen",
];

/// Hidden-feature names for a space kind, aligned with
/// [`hidden_features`].
pub fn hidden_names(kind: SpaceKind) -> Vec<&'static str> {
    let mut v = HIDDEN_NAMES.to_vec();
    if kind == SpaceKind::Extended {
        v.extend_from_slice(&HIDDEN_NAMES_EXTENDED);
    }
    v
}

/// Hidden-feature vector length for a space kind.
pub fn hidden_len(kind: SpaceKind) -> usize {
    match kind {
        SpaceKind::Paper => HIDDEN_NAMES.len(),
        SpaceKind::Extended => {
            HIDDEN_NAMES.len() + HIDDEN_NAMES_EXTENDED.len()
        }
    }
}

/// Extract the hidden feature vector from a compilation. The paper-kind
/// prefix is identical for both kinds; the extended kind appends
/// [`HIDDEN_NAMES_EXTENDED`].
pub fn hidden_features(kind: SpaceKind, c: &Compiled) -> Vec<f64> {
    let a: &TileAnalysis = &c.analysis;
    let st: &CompileStats = &c.stats;
    let per_tile = |v: u64, tiles: usize| {
        if tiles == 0 { 0.0 } else { v as f64 / tiles as f64 }
    };
    let mut h = vec![
        st.vthread_branch_taken as u8 as f64,
        st.uneven_thread_split as u8 as f64,
        a.nbc as f64,
        a.nbc_last as f64,
        a.th as f64,
        a.tw as f64,
        (a.tw != a.tw_last) as u8 as f64 * a.tw_last as f64,
        per_tile(st.dummy_rows_interior, st.tiles_interior),
        per_tile(st.dummy_rows_boundary, st.tiles_boundary),
        a.th as f64,
        a.th_last as f64,
        a.kcb as f64 / a.nbc as f64 / a.nvt as f64,
        a.in_tile_w as f64,
        a.in_tile_h as f64,
        a.in_tile_h as f64,
        a.in_tile_h_last as f64,
        a.n_tiles() as f64,
        a.n_ci as f64,
        per_tile(
            st.dummy_vecs_interior + st.dummy_vecs_boundary,
            a.n_tiles(),
        ),
        a.inp_tile as f64,
        a.acc_tile as f64,
    ];
    if kind == SpaceKind::Extended {
        h.extend_from_slice(&[
            a.slots as f64,
            a.unroll as f64,
            a.n_chunks as f64,
            a.uop_count as f64,
        ]);
    }
    h
}

/// `visible ⊕ hidden` — the input of model A.
pub fn combined_features(visible: &[f64], hidden: &[f64]) -> Vec<f64> {
    let mut v = visible.to_vec();
    v.extend_from_slice(hidden);
    v
}

/// Names for the combined feature space (for Table 5 importance reports).
pub fn combined_names(kind: SpaceKind) -> Vec<String> {
    let mut v = kind.visible_names();
    v.extend(hidden_names(kind).iter().map(|n| n.to_string()));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::analyze;
    use crate::compiler::schedule::Schedule;
    use crate::vta::config::VtaConfig;
    use crate::workloads::resnet18;

    fn compiled(th: usize, tw: usize) -> Compiled {
        let cfg = VtaConfig::zcu102();
        let layer = resnet18::layer("conv1").unwrap();
        let s = Schedule { tile_h: th, tile_w: tw, tile_oc: 32,
                           tile_ic: 32, n_vthreads: 2,
                           ..Default::default() };
        let a = analyze(&cfg, &layer, &s);
        super::super::codegen::lower(&cfg, &layer, &a)
    }

    #[test]
    fn names_align_with_values() {
        let c = compiled(8, 8);
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let h = hidden_features(kind, &c);
            assert_eq!(h.len(), hidden_names(kind).len());
            assert_eq!(h.len(), hidden_len(kind));
        }
    }

    #[test]
    fn extended_hidden_extends_the_paper_prefix() {
        let c = compiled(8, 8);
        let paper = hidden_features(SpaceKind::Paper, &c);
        let ext = hidden_features(SpaceKind::Extended, &c);
        assert_eq!(&ext[..paper.len()], &paper[..]);
        assert_eq!(ext.len(), paper.len() + HIDDEN_NAMES_EXTENDED.len());
        // resolved defaults: 2 slots, unroll 1
        assert_eq!(ext[paper.len()], 2.0);
        assert_eq!(ext[paper.len() + 1], 1.0);
    }

    #[test]
    fn boundary_features_fire_on_non_divisor_tiles() {
        let exact = hidden_features(SpaceKind::Paper, &compiled(8, 8));
        let ragged = hidden_features(SpaceKind::Paper, &compiled(24, 24));
        let idx = HIDDEN_NAMES
            .iter()
            .position(|n| *n == "sizeOutTileBoundaryW")
            .unwrap();
        assert_eq!(exact[idx], 0.0);
        assert_eq!(ragged[idx], 8.0);
        let idx_h = HIDDEN_NAMES
            .iter()
            .position(|n| *n == "resizedOutTileH (b0!=0)")
            .unwrap();
        assert_eq!(ragged[idx_h], 8.0);
    }

    #[test]
    fn combined_concatenates() {
        let c = compiled(8, 8);
        for kind in [SpaceKind::Paper, SpaceKind::Extended] {
            let h = hidden_features(kind, &c);
            let nv = kind.n_visible();
            let v = vec![1.0; nv];
            let comb = combined_features(&v, &h);
            assert_eq!(comb.len(), nv + h.len());
            assert_eq!(combined_names(kind).len(), comb.len());
        }
    }
}
