//! Backend compiler substrate — the paper's Glow/nest-compiler analogue.
//!
//! Pipeline: [`schedule::Schedule`] → [`passes::analyze`] (legalize +
//! resolve tile geometry) → [`codegen::lower`] (emit the VTA instruction
//! stream, collecting branch/emission statistics) → [`features`] (hidden
//! feature vector for cost model A). [`validity`] is the deliberately weak
//! static check a VTA-class backend can actually perform.

pub mod codegen;
pub mod features;
pub mod passes;
pub mod schedule;
pub mod validity;

use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;
pub use codegen::Compiled;
use schedule::{Schedule, SpaceKind};

/// Compiler facade: owns the hardware config, compiles (layer, schedule)
/// pairs, and exposes visible/hidden features. The space kind selects the
/// hidden-feature layout ([`features::hidden_features`]): paper-exact for
/// [`SpaceKind::Paper`], extended geometry appended for
/// [`SpaceKind::Extended`].
#[derive(Clone, Debug)]
pub struct Compiler {
    /// The hardware target compiled for.
    pub cfg: VtaConfig,
    /// Knob-space kind selecting the hidden-feature layout.
    pub kind: SpaceKind,
}

impl Compiler {
    /// Paper-space compiler (pre-refactor behaviour).
    pub fn new(cfg: VtaConfig) -> Self {
        Compiler::with_kind(cfg, SpaceKind::Paper)
    }

    /// Compiler for an explicit space kind.
    pub fn with_kind(cfg: VtaConfig, kind: SpaceKind) -> Self {
        Compiler { cfg, kind }
    }

    /// Full compilation: analysis + lowering + stats. This is the step the
    /// ML²Tuner explorer pays `(α+1)·N` times per iteration to harvest
    /// hidden features (paper §2, "Hidden Feature Extractor").
    pub fn compile(&self, layer: &ConvLayer, sched: &Schedule) -> Compiled {
        let a = passes::analyze(&self.cfg, layer, sched);
        codegen::lower(&self.cfg, layer, &a)
    }

    /// Hidden features of a compilation (model A's extra inputs), in
    /// this compiler's space-kind layout.
    pub fn hidden_features(&self, compiled: &Compiled) -> Vec<f64> {
        features::hidden_features(self.kind, compiled)
    }

    /// The weak static check (not used to prune the search space — the
    /// paper's search spaces contain the invalid configurations).
    pub fn static_check(
        &self,
        layer: &ConvLayer,
        sched: &Schedule,
    ) -> validity::StaticCheck {
        let a = passes::analyze(&self.cfg, layer, sched);
        validity::static_check(&self.cfg, &a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn facade_compiles_and_extracts() {
        let c = Compiler::new(VtaConfig::zcu102());
        let l = resnet18::layer("conv3").unwrap();
        let s = Schedule { tile_h: 4, tile_w: 4, tile_oc: 32, tile_ic: 32,
                           n_vthreads: 2, ..Default::default() };
        let out = c.compile(&l, &s);
        assert!(!out.program.is_empty());
        let h = c.hidden_features(&out);
        assert_eq!(h.len(), features::hidden_len(SpaceKind::Paper));
        assert!(c.static_check(&l, &s).is_plausible());
        // an extended-kind compiler appends the resolved-primitive tail
        let e = Compiler::with_kind(VtaConfig::zcu102(),
                                    SpaceKind::Extended);
        assert_eq!(e.hidden_features(&e.compile(&l, &s)).len(),
                   features::hidden_len(SpaceKind::Extended));
    }

    #[test]
    fn compilation_is_deterministic() {
        let c = Compiler::new(VtaConfig::zcu102());
        let l = resnet18::layer("conv8").unwrap();
        let s = Schedule { tile_h: 7, tile_w: 14, tile_oc: 64, tile_ic: 64,
                           n_vthreads: 4, ..Default::default() };
        let a = c.compile(&l, &s);
        let b = c.compile(&l, &s);
        assert_eq!(a.program, b.program);
        assert_eq!(a.stats, b.stats);
    }
}
