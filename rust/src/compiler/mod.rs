//! Backend compiler substrate — the paper's Glow/nest-compiler analogue.
//!
//! Pipeline: [`schedule::Schedule`] → [`passes::analyze`] (legalize +
//! resolve tile geometry) → [`codegen::lower`] (emit the VTA instruction
//! stream, collecting branch/emission statistics) → [`features`] (hidden
//! feature vector for cost model A). [`validity`] is the deliberately weak
//! static check a VTA-class backend can actually perform.

pub mod codegen;
pub mod features;
pub mod passes;
pub mod schedule;
pub mod validity;

use crate::vta::config::VtaConfig;
use crate::workloads::ConvLayer;
pub use codegen::Compiled;
use schedule::Schedule;

/// Compiler facade: owns the hardware config, compiles (layer, schedule)
/// pairs, and exposes visible/hidden features.
#[derive(Clone, Debug)]
pub struct Compiler {
    pub cfg: VtaConfig,
}

impl Compiler {
    pub fn new(cfg: VtaConfig) -> Self {
        Compiler { cfg }
    }

    /// Full compilation: analysis + lowering + stats. This is the step the
    /// ML²Tuner explorer pays `(α+1)·N` times per iteration to harvest
    /// hidden features (paper §2, "Hidden Feature Extractor").
    pub fn compile(&self, layer: &ConvLayer, sched: &Schedule) -> Compiled {
        let a = passes::analyze(&self.cfg, layer, sched);
        codegen::lower(&self.cfg, layer, &a)
    }

    /// Hidden features of a compilation (model A's extra inputs).
    pub fn hidden_features(&self, compiled: &Compiled) -> Vec<f64> {
        features::hidden_features(compiled)
    }

    /// The weak static check (not used to prune the search space — the
    /// paper's search spaces contain the invalid configurations).
    pub fn static_check(
        &self,
        layer: &ConvLayer,
        sched: &Schedule,
    ) -> validity::StaticCheck {
        let a = passes::analyze(&self.cfg, layer, sched);
        validity::static_check(&self.cfg, &a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn facade_compiles_and_extracts() {
        let c = Compiler::new(VtaConfig::zcu102());
        let l = resnet18::layer("conv3").unwrap();
        let s = Schedule { tile_h: 4, tile_w: 4, tile_oc: 32, tile_ic: 32,
                           n_vthreads: 2 };
        let out = c.compile(&l, &s);
        assert!(!out.program.is_empty());
        let h = c.hidden_features(&out);
        assert_eq!(h.len(), features::HIDDEN_NAMES.len());
        assert!(c.static_check(&l, &s).is_plausible());
    }

    #[test]
    fn compilation_is_deterministic() {
        let c = Compiler::new(VtaConfig::zcu102());
        let l = resnet18::layer("conv8").unwrap();
        let s = Schedule { tile_h: 7, tile_w: 14, tile_oc: 64, tile_ic: 64,
                           n_vthreads: 4 };
        let a = c.compile(&l, &s);
        let b = c.compile(&l, &s);
        assert_eq!(a.program, b.program);
        assert_eq!(a.stats, b.stats);
    }
}
