//! VTA instruction stream — the interface between the backend compiler and
//! the simulator.
//!
//! Mirrors the real VTA ISA's structure at the level that matters for tuning:
//! 2-D strided DMA descriptors, a GEMM instruction programmed by micro-ops
//! plus two hardware loops, a requantizing ALU, and the four dependency-token
//! flags that let the LOAD / COMPUTE / STORE modules run ahead of each other.

/// Dependency-token flags (same four bits as real VTA instructions).
///
/// Queues: `l2g` (load→compute data-ready), `g2l` (compute→load buffer-free),
/// `g2s` (compute→store data-ready), `s2g` (store→compute buffer-free).
/// "prev"/"next" are relative to the pipeline order LOAD → COMPUTE → STORE.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Dep {
    /// Wait for a token from the previous module before starting.
    pub pop_prev: bool,
    /// Wait for a token from the next module before starting.
    pub pop_next: bool,
    /// Signal the previous module when done.
    pub push_prev: bool,
    /// Signal the next module when done.
    pub push_next: bool,
}

impl Dep {
    /// No dependency tokens at all.
    pub const NONE: Dep = Dep {
        pop_prev: false,
        pop_next: false,
        push_prev: false,
        push_next: false,
    };

    /// Only `pop_next` set.
    pub fn pop_next() -> Dep {
        Dep { pop_next: true, ..Dep::NONE }
    }

    /// Only `push_next` set.
    pub fn push_next() -> Dep {
        Dep { push_next: true, ..Dep::NONE }
    }

    /// Only `pop_prev` set.
    pub fn pop_prev() -> Dep {
        Dep { pop_prev: true, ..Dep::NONE }
    }

    /// Only `push_prev` set.
    pub fn push_prev() -> Dep {
        Dep { push_prev: true, ..Dep::NONE }
    }
}

/// Which scratchpad a memory instruction touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Buffer {
    /// Input-vector scratchpad.
    Inp,
    /// Weight-block scratchpad.
    Wgt,
    /// Accumulator scratchpad.
    Acc,
}

/// 2-D strided DMA descriptor (element units are buffer-native: input
/// vectors / weight blocks / accumulator vectors):
/// `sram[sram_base + r*cols + c] <-> dram[dram_base + r*dram_stride + c]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dma {
    /// First scratchpad element written/read.
    pub sram_base: usize,
    /// First DRAM element read/written.
    pub dram_base: usize,
    /// Row count of the 2-D transfer.
    pub rows: usize,
    /// Contiguous elements per row.
    pub cols: usize,
    /// DRAM elements between consecutive row starts.
    pub dram_stride: usize,
}

impl Dma {
    /// Total elements transferred.
    pub fn elems(&self) -> usize {
        self.rows * self.cols
    }

    /// Highest sram element touched + 1 (0 for empty transfers).
    pub fn sram_end(&self) -> usize {
        if self.elems() == 0 {
            self.sram_base
        } else {
            self.sram_base + self.elems()
        }
    }

    /// Highest dram element touched + 1.
    pub fn dram_end(&self) -> usize {
        if self.elems() == 0 {
            self.dram_base
        } else {
            self.dram_base + (self.rows - 1) * self.dram_stride + self.cols
        }
    }
}

/// One GEMM micro-op: `acc[acc] += inp[inp] · wgt[wgt]` at block level
/// (1×16 int8 vector × 16×16 int8 block accumulated into 1×16 int32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Uop {
    /// Accumulator-vector index written.
    pub acc: usize,
    /// Input-vector index read.
    pub inp: usize,
    /// Weight-block index read.
    pub wgt: usize,
}

/// One GEMM hardware loop level: per-iteration offsets added to every uop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GemmLoop {
    /// Iteration count of this hardware loop.
    pub extent: usize,
    /// Accumulator offset added per iteration.
    pub acc_off: usize,
    /// Input offset added per iteration.
    pub inp_off: usize,
    /// Weight offset added per iteration.
    pub wgt_off: usize,
}

/// ALU opcodes (store path of the compute module).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AluOp {
    /// `acc = clip(acc >> shift, -128, 127)` — the requantization the golden
    /// Pallas kernel performs (`kernels/vta_conv.py::_gemm_kernel`).
    ShiftClip { shift: u32 },
    /// `acc = max(acc, 0)` (ReLU; used by synthetic workloads / ablations).
    Relu,
    /// `acc += imm`.
    AddImm { imm: i32 },
}

/// One VTA instruction.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// DMA into a scratchpad (LOAD module; `Acc` loads are used by bias-style
    /// synthetic workloads).
    Load { buf: Buffer, dma: Dma, dep: Dep },
    /// Zero-fill `count` elements of a scratchpad starting at `sram_base`
    /// (LOAD module; emitted for padding halo rows, the paper's
    /// `outDummy*` regions).
    Memset { buf: Buffer, sram_base: usize, count: usize, dep: Dep },
    /// Copy `[uop_begin, uop_end)` of the program's uop table into the uop
    /// buffer at `sram_base` (LOAD module on real VTA; capacity-checked).
    LoadUop { sram_base: usize, uop_begin: usize, uop_end: usize, dep: Dep },
    /// Micro-op GEMM with two hardware loops (COMPUTE module).
    /// Executes, for `i0 < lp0.extent`, `i1 < lp1.extent`, each uop `u` in
    /// `[ubuf_begin, ubuf_end)` of the *uop buffer*:
    ///   `acc[base_acc(u,i0,i1)] (+)= inp[..] · wgt[..]`
    /// where `base_x = u.x + x_base + i0*lp0.x_off + i1*lp1.x_off`.
    /// `reset` zeroes the accumulator instead of accumulating.
    Gemm {
        ubuf_begin: usize,
        ubuf_end: usize,
        lp0: GemmLoop,
        lp1: GemmLoop,
        acc_base: usize,
        inp_base: usize,
        wgt_base: usize,
        reset: bool,
        dep: Dep,
    },
    /// ALU over a contiguous accumulator range (COMPUTE module).
    Alu { op: AluOp, acc_base: usize, count: usize, dep: Dep },
    /// DMA accumulator vectors (requantized int8 lanes) to output DRAM
    /// (STORE module). Element units: accumulator vectors.
    Store { dma: Dma, dep: Dep },
    /// Drain the pipeline (COMPUTE module).
    Finish,
}

impl Instr {
    /// Which module executes this instruction.
    pub fn module(&self) -> Module {
        match self {
            Instr::Load { .. } | Instr::Memset { .. } | Instr::LoadUop { .. } => {
                Module::Load
            }
            Instr::Gemm { .. } | Instr::Alu { .. } | Instr::Finish => {
                Module::Compute
            }
            Instr::Store { .. } => Module::Store,
        }
    }

    /// This instruction's dependency-token flags.
    pub fn dep(&self) -> Dep {
        match self {
            Instr::Load { dep, .. }
            | Instr::Memset { dep, .. }
            | Instr::LoadUop { dep, .. }
            | Instr::Gemm { dep, .. }
            | Instr::Alu { dep, .. }
            | Instr::Store { dep, .. } => *dep,
            Instr::Finish => Dep::NONE,
        }
    }
}

/// The three concurrent VTA modules.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Module {
    /// DMA-in + memset + uop-table loads.
    Load = 0,
    /// GEMM, ALU, and pipeline drain.
    Compute = 1,
    /// DMA-out of requantized results.
    Store = 2,
}

/// A compiled program: instruction stream + the uop table LoadUop draws from.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Program {
    /// The instruction stream, in issue order.
    pub instrs: Vec<Instr>,
    /// Uop table [`Instr::LoadUop`] copies slices of.
    pub uops: Vec<Uop>,
    /// DRAM sizes the program assumes (element units; validated at run).
    pub dram_inp_vecs: usize,
    /// Weight DRAM size the program assumes (blocks).
    pub dram_wgt_blocks: usize,
    /// Output DRAM size the program assumes (vectors).
    pub dram_out_vecs: usize,
}

impl Program {
    /// Total GEMM block-operations (16×16×16 MACs each) — the work the MXU
    /// actually performs; used by the cycle model and utilization reports.
    pub fn gemm_block_ops(&self) -> u64 {
        self.instrs
            .iter()
            .map(|i| match i {
                Instr::Gemm { ubuf_begin, ubuf_end, lp0, lp1, .. } => {
                    (ubuf_end - ubuf_begin) as u64
                        * lp0.extent.max(1) as u64
                        * lp1.extent.max(1) as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Bytes moved by DMA (loads + stores), for bandwidth accounting.
    pub fn dma_bytes(&self, cfg: &super::config::VtaConfig) -> u64 {
        self.instrs
            .iter()
            .map(|i| match i {
                Instr::Load { buf, dma, .. } => {
                    dma.elems() as u64 * buf_bytes(cfg, *buf) as u64
                }
                Instr::Store { dma, .. } => {
                    dma.elems() as u64 * cfg.acc_vec_bytes() as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Instruction count.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// Whether the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }
}

pub(crate) fn buf_bytes(
    cfg: &super::config::VtaConfig,
    buf: Buffer,
) -> usize {
    match buf {
        Buffer::Inp => cfg.inp_vec_bytes(),
        Buffer::Wgt => cfg.wgt_block_bytes(),
        Buffer::Acc => cfg.acc_vec_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dma_extents() {
        let d = Dma { sram_base: 10, dram_base: 100, rows: 3, cols: 4,
                      dram_stride: 20 };
        assert_eq!(d.elems(), 12);
        assert_eq!(d.sram_end(), 22);
        assert_eq!(d.dram_end(), 100 + 2 * 20 + 4);
    }

    #[test]
    fn module_assignment() {
        let dma = Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 1,
                        dram_stride: 1 };
        assert_eq!(
            Instr::Load { buf: Buffer::Inp, dma, dep: Dep::NONE }.module(),
            Module::Load
        );
        assert_eq!(Instr::Finish.module(), Module::Compute);
        assert_eq!(
            Instr::Store { dma, dep: Dep::NONE }.module(),
            Module::Store
        );
    }

    #[test]
    fn gemm_block_op_count() {
        let mut p = Program::default();
        p.instrs.push(Instr::Gemm {
            ubuf_begin: 0,
            ubuf_end: 8,
            lp0: GemmLoop { extent: 4, ..Default::default() },
            lp1: GemmLoop { extent: 2, ..Default::default() },
            acc_base: 0,
            inp_base: 0,
            wgt_base: 0,
            reset: false,
            dep: Dep::NONE,
        });
        assert_eq!(p.gemm_block_ops(), 8 * 4 * 2);
    }
}
