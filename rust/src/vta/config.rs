//! Extended-VTA hardware parameters — paper Appendix A.1, Table 1, plus
//! the wider design-point family served by the
//! [`crate::vta::targets`] registry.
//!
//! The paper adapted TVM's ZCU104 preset for the ZCU102 by bumping the four
//! buffer-size attributes by one (log2) step; those exact values are the
//! defaults here. [`VtaConfig::zcu102`]/[`VtaConfig::zcu104`] are the two
//! Table-1 design points; [`VtaConfig::edge_small`] and
//! [`VtaConfig::hiband`] extend the family toward the capacity extremes
//! (all four are routed by name through `vta::targets` and the CLI's
//! `--target` flag). The timing coefficients parameterize the cycle model
//! in [`crate::vta::timing`] (they are our calibration of a 100 MHz VTA
//! design with a DDR4 DMA engine, not Table 1 values — see ARCHITECTURE.md).

/// Table 1 + cycle-model coefficients.
#[derive(Clone, Debug, PartialEq)]
pub struct VtaConfig {
    /// `TARGET` — device target name (a registry key; owned so targets
    /// defined outside the built-in table — e.g. file-loaded custom
    /// design points — need no static string).
    pub target: String,
    /// `HW_VER` — VTA hardware version.
    pub hw_ver: &'static str,
    /// `LOG_INP_WIDTH` = 3 → int8 inputs.
    pub log_inp_width: u32,
    /// `LOG_WGT_WIDTH` = 3 → int8 weights.
    pub log_wgt_width: u32,
    /// `LOG_ACC_WIDTH` = 5 → int32 accumulators.
    pub log_acc_width: u32,
    /// `LOG_BATCH` = 0 → GEMM intrinsic batch dim 1.
    pub log_batch: u32,
    /// `LOG_BLOCK` = 4 → GEMM intrinsic inner dims 16.
    pub log_block: u32,
    /// `LOG_UOP_BUFF_SIZE` = 16 → 64 KiB micro-op buffer.
    pub log_uop_buff_size: u32,
    /// `LOG_INP_BUFF_SIZE` = 16 → 64 KiB input buffer.
    pub log_inp_buff_size: u32,
    /// `LOG_WGT_BUFF_SIZE` = 19 → 512 KiB weight buffer.
    pub log_wgt_buff_size: u32,
    /// `LOG_ACC_BUFF_SIZE` = 18 → 256 KiB accumulator buffer.
    pub log_acc_buff_size: u32,

    // ---- cycle-model coefficients (calibration, not Table 1) ----
    /// Fabric clock in MHz (ZCU102 VTA designs run 100–333 MHz).
    pub clock_mhz: f64,
    /// Fixed DMA setup latency per load/store instruction (cycles).
    pub dma_latency: u64,
    /// DMA payload bytes moved per cycle once streaming.
    pub dma_bytes_per_cycle: u64,
    /// Extra cycles per 2-D DMA row (descriptor/burst restart).
    pub dma_row_overhead: u64,
    /// Fixed issue overhead per GEMM instruction (cycles).
    pub gemm_overhead: u64,
    /// Fixed issue overhead per ALU instruction (cycles).
    pub alu_overhead: u64,
    /// Cycles per accumulator vector processed by the ALU.
    pub alu_cycles_per_vec: u64,
    /// Cycles per memset vector (on-chip fill).
    pub memset_cycles_per_vec: u64,
    /// Cycles for the FINISH handshake.
    pub finish_cycles: u64,

    /// Requantization shift applied by the ALU store path. Must match
    /// `python/compile/model.py::SHIFT` (golden artifacts).
    pub shift: u32,
}

impl Default for VtaConfig {
    fn default() -> Self {
        Self::zcu102()
    }
}

impl VtaConfig {
    /// The extended-VTA ZCU102 configuration of paper Table 1.
    pub fn zcu102() -> Self {
        VtaConfig {
            target: "zcu102".to_string(),
            hw_ver: "0.0.1",
            log_inp_width: 3,
            log_wgt_width: 3,
            log_acc_width: 5,
            log_batch: 0,
            log_block: 4,
            log_uop_buff_size: 16,
            log_inp_buff_size: 16,
            log_wgt_buff_size: 19,
            log_acc_buff_size: 18,
            clock_mhz: 100.0,
            dma_latency: 144,
            dma_bytes_per_cycle: 16,
            dma_row_overhead: 6,
            gemm_overhead: 28,
            alu_overhead: 24,
            alu_cycles_per_vec: 2,
            memset_cycles_per_vec: 1,
            finish_cycles: 16,
            shift: 8,
        }
    }

    /// TVM's stock ZCU104 preset (buffers one log2 step smaller) — used by
    /// ablations to show capacity pressure shifts the invalidity structure.
    pub fn zcu104() -> Self {
        VtaConfig {
            target: "zcu104".to_string(),
            log_uop_buff_size: 15,
            log_inp_buff_size: 15,
            log_wgt_buff_size: 18,
            log_acc_buff_size: 17,
            ..Self::zcu102()
        }
    }

    /// Edge design point: one more log2 step down on *all* buffers from
    /// the ZCU104 preset, on a narrower/slower DMA engine. Shrinks every
    /// scratchpad to a quarter of the ZCU102's — the invalid-config
    /// boundary moves far into regions that are comfortably valid on the
    /// board targets, which is what makes it a non-degenerate transfer
    /// stressor.
    pub fn edge_small() -> Self {
        VtaConfig {
            target: "edge-small".to_string(),
            log_uop_buff_size: 14,
            log_inp_buff_size: 14,
            log_wgt_buff_size: 17,
            log_acc_buff_size: 16,
            dma_latency: 192,
            dma_bytes_per_cycle: 8,
            ..Self::zcu102()
        }
    }

    /// High-bandwidth design point: ZCU102 buffers with a doubled DMA
    /// stream width, lower DMA setup latency, and a doubled micro-op
    /// buffer — compute-bound where the board targets are DMA-bound, and
    /// with uop headroom that un-binds the kernel-unroll primitive's
    /// tightest constraint.
    pub fn hiband() -> Self {
        VtaConfig {
            target: "hiband".to_string(),
            log_uop_buff_size: 17,
            dma_latency: 96,
            dma_bytes_per_cycle: 32,
            ..Self::zcu102()
        }
    }

    /// The fields that shape the *lowered program* (and hence the hidden
    /// features extracted from it). Two targets with equal signatures
    /// compile any (layer, schedule) pair to the byte-identical kernel,
    /// which is what lets the engine's compile cache be shared across
    /// such targets in a fleet run.
    pub fn codegen_sig(&self) -> CodegenSig {
        CodegenSig {
            log_inp_width: self.log_inp_width,
            log_wgt_width: self.log_wgt_width,
            log_acc_width: self.log_acc_width,
            log_batch: self.log_batch,
            log_block: self.log_block,
            log_inp_buff_size: self.log_inp_buff_size,
            log_wgt_buff_size: self.log_wgt_buff_size,
            log_acc_buff_size: self.log_acc_buff_size,
            shift: self.shift,
        }
    }

    /// GEMM intrinsic inner dimension (16).
    #[inline]
    pub fn block(&self) -> usize {
        1 << self.log_block
    }

    /// GEMM intrinsic batch dimension (1).
    #[inline]
    pub fn batch(&self) -> usize {
        1 << self.log_batch
    }

    /// Input vector size in bytes: batch × block × int8.
    #[inline]
    pub fn inp_vec_bytes(&self) -> usize {
        self.batch() * self.block() * ((1 << self.log_inp_width) / 8)
    }

    /// Weight block size in bytes: block × block × int8.
    #[inline]
    pub fn wgt_block_bytes(&self) -> usize {
        self.block() * self.block() * ((1 << self.log_wgt_width) / 8)
    }

    /// Accumulator vector size in bytes: batch × block × int32.
    #[inline]
    pub fn acc_vec_bytes(&self) -> usize {
        self.batch() * self.block() * ((1 << self.log_acc_width) / 8)
    }

    /// Micro-op size in bytes (real VTA packs one uop in 4 bytes).
    #[inline]
    pub fn uop_bytes(&self) -> usize {
        4
    }

    /// INP scratchpad capacity in input *vectors* (zcu102: 4096).
    #[inline]
    pub fn inp_capacity(&self) -> usize {
        (1usize << self.log_inp_buff_size) / self.inp_vec_bytes()
    }

    /// WGT scratchpad capacity in 16×16 *blocks* (zcu102: 2048).
    #[inline]
    pub fn wgt_capacity(&self) -> usize {
        (1usize << self.log_wgt_buff_size) / self.wgt_block_bytes()
    }

    /// ACC scratchpad capacity in accumulator *vectors* (zcu102: 4096).
    #[inline]
    pub fn acc_capacity(&self) -> usize {
        (1usize << self.log_acc_buff_size) / self.acc_vec_bytes()
    }

    /// UOP buffer capacity in micro-ops (zcu102: 16384).
    #[inline]
    pub fn uop_capacity(&self) -> usize {
        (1usize << self.log_uop_buff_size) / self.uop_bytes()
    }
}

/// Compile-shaping subset of [`VtaConfig`] (see
/// [`VtaConfig::codegen_sig`]): data widths and block/batch geometry fix
/// the tensorization, the INP/WGT/ACC buffer sizes fix the per-thread
/// scratchpad slices codegen addresses by, and `shift` is baked into the
/// requantizing store path. The uop-buffer size and every timing
/// coefficient are deliberately *absent* — lowering emits the uop table
/// unconditionally (overflow is a runtime register error the per-target
/// simulator and static check see), and DMA/clock parameters only exist
/// in the cycle model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CodegenSig {
    /// log2 input element width in bits.
    pub log_inp_width: u32,
    /// log2 weight element width in bits.
    pub log_wgt_width: u32,
    /// log2 accumulator element width in bits.
    pub log_acc_width: u32,
    /// log2 GEMM batch dimension.
    pub log_batch: u32,
    /// log2 GEMM block dimension.
    pub log_block: u32,
    /// log2 input scratchpad bytes.
    pub log_inp_buff_size: u32,
    /// log2 weight scratchpad bytes.
    pub log_wgt_buff_size: u32,
    /// log2 accumulator scratchpad bytes.
    pub log_acc_buff_size: u32,
    /// Requantization right-shift baked into the store path.
    pub shift: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu102_table1_capacities() {
        let c = VtaConfig::zcu102();
        assert_eq!(c.block(), 16);
        assert_eq!(c.batch(), 1);
        assert_eq!(c.inp_vec_bytes(), 16);
        assert_eq!(c.wgt_block_bytes(), 256);
        assert_eq!(c.acc_vec_bytes(), 64);
        // 64 KiB / 16 B, 512 KiB / 256 B, 256 KiB / 64 B, 64 KiB / 4 B
        assert_eq!(c.inp_capacity(), 4096);
        assert_eq!(c.wgt_capacity(), 2048);
        assert_eq!(c.acc_capacity(), 4096);
        assert_eq!(c.uop_capacity(), 16384);
    }

    #[test]
    fn zcu104_is_half_sized() {
        let a = VtaConfig::zcu102();
        let b = VtaConfig::zcu104();
        assert_eq!(b.inp_capacity() * 2, a.inp_capacity());
        assert_eq!(b.wgt_capacity() * 2, a.wgt_capacity());
        assert_eq!(b.acc_capacity() * 2, a.acc_capacity());
        assert_eq!(b.uop_capacity() * 2, a.uop_capacity());
    }

    #[test]
    fn edge_small_is_quarter_sized_and_narrow() {
        let a = VtaConfig::zcu102();
        let e = VtaConfig::edge_small();
        assert_eq!(e.inp_capacity() * 4, a.inp_capacity());
        assert_eq!(e.wgt_capacity() * 4, a.wgt_capacity());
        assert_eq!(e.acc_capacity() * 4, a.acc_capacity());
        assert_eq!(e.uop_capacity() * 4, a.uop_capacity());
        assert_eq!(e.dma_bytes_per_cycle * 2, a.dma_bytes_per_cycle);
        assert_eq!(e.block(), a.block(), "GEMM geometry is shared");
    }

    #[test]
    fn hiband_differs_only_off_the_codegen_path() {
        let a = VtaConfig::zcu102();
        let h = VtaConfig::hiband();
        assert_eq!(h.codegen_sig(), a.codegen_sig(),
                   "hiband must share zcu102 lowering (fleet cache reuse)");
        assert_eq!(h.uop_capacity(), 2 * a.uop_capacity());
        assert_eq!(h.dma_bytes_per_cycle, 2 * a.dma_bytes_per_cycle);
    }

    #[test]
    fn codegen_sig_separates_buffer_families() {
        assert_ne!(VtaConfig::zcu102().codegen_sig(),
                   VtaConfig::zcu104().codegen_sig());
        assert_ne!(VtaConfig::zcu104().codegen_sig(),
                   VtaConfig::edge_small().codegen_sig());
    }

    #[test]
    fn shift_matches_python_model() {
        // python/compile/model.py::SHIFT — golden artifacts are lowered with
        // this; a mismatch would make every valid config "wrong output".
        assert_eq!(VtaConfig::zcu102().shift, 8);
    }
}
