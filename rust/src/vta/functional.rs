//! Numeric execution + fault model.
//!
//! Three cooperating analyses, all driven by the *same* serialized execution
//! order produced by [`crate::vta::timing`]:
//!
//! * [`check_addresses`] — order-independent address-bounds pass. INP/WGT/UOP
//!   ranges beyond the physical buffers (or DRAM range violations) are
//!   **register errors** (crash; the paper's "requiring a manual reboot");
//!   ACC ranges beyond capacity *wrap silently* and are **corruption**.
//! * [`check_hazards`] — pipelined-execution hazard pass: with the modules
//!   running concurrently (double buffering, virtual threads), a program-
//!   later DMA that executes *before* a program-earlier reader it conflicts
//!   with clobbers live data → **corruption** ("the result differs from the
//!   expected result"). This is exactly the failure mode of schedules whose
//!   per-thread footprint exceeds the scratchpad slice the compiler assumed.
//! * [`execute`] — full numeric run in serialized order, so hazards really do
//!   corrupt the output bits, and a `check`-valid program is bit-exact
//!   against the AOT JAX/Pallas golden model (integration-tested).

use super::config::VtaConfig;
use super::isa::{buf_bytes, AluOp, Buffer, Instr, Program, Uop};
use super::timing::Schedule;
use super::Fault;

/// DRAM contents for numeric execution (element units per `layout`).
#[derive(Clone, Debug, Default)]
pub struct Dram {
    /// Input vectors, flattened int8 (`len = vecs * block`).
    pub inp: Vec<i8>,
    /// Weight blocks, flattened int8 (`len = blocks * block²`).
    pub wgt: Vec<i8>,
    /// Output size in accumulator vectors.
    pub out_vecs: usize,
}

// ------------------------------------------------------------------ bounds

/// Address-bounds pass: first crash or ACC-wrap corruption, program order.
pub fn check_addresses(cfg: &VtaConfig, prog: &Program) -> Result<(), Fault> {
    check_addresses_inner(cfg, prog, &uop_windows(prog))
}

/// The bounds pass proper, with the uop-window table supplied by the
/// caller so [`check_program`] computes it once for both passes.
fn check_addresses_inner(
    cfg: &VtaConfig,
    prog: &Program,
    windows: &UopWindows,
) -> Result<(), Fault> {
    let mut corruption: Option<Fault> = None;
    for (idx, ins) in prog.instrs.iter().enumerate() {
        match ins {
            Instr::Load { buf, dma, .. } => {
                let cap = capacity(cfg, *buf);
                let dram_cap = match buf {
                    Buffer::Inp => prog.dram_inp_vecs,
                    Buffer::Wgt => prog.dram_wgt_blocks,
                    Buffer::Acc => prog.dram_inp_vecs, // acc loads read inp space
                };
                if dma.dram_end() > dram_cap {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: load DMA reads past DRAM \
                         ({} > {dram_cap})",
                        dma.dram_end()
                    )));
                }
                if dma.sram_end() > cap {
                    match buf {
                        Buffer::Acc => hold_corruption(
                            &mut corruption,
                            format!(
                                "instr {idx}: ACC load wraps ({} > {cap})",
                                dma.sram_end()
                            ),
                        ),
                        _ => {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: {buf:?} load overflows \
                                 scratchpad ({} > {cap})",
                                dma.sram_end()
                            )))
                        }
                    }
                }
            }
            Instr::Memset { buf, sram_base, count, .. } => {
                let cap = capacity(cfg, *buf);
                if sram_base + count > cap {
                    match buf {
                        Buffer::Acc => hold_corruption(
                            &mut corruption,
                            format!("instr {idx}: ACC memset wraps"),
                        ),
                        _ => {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: {buf:?} memset overflows \
                                 scratchpad ({} > {cap})",
                                sram_base + count
                            )))
                        }
                    }
                }
            }
            Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => {
                if *uop_end > prog.uops.len() || uop_begin > uop_end {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: uop table range [{uop_begin},{uop_end}) \
                         out of bounds"
                    )));
                }
                if sram_base + (uop_end - uop_begin) > cfg.uop_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: uop buffer overflow \
                         ({} > {})",
                        sram_base + (uop_end - uop_begin),
                        cfg.uop_capacity()
                    )));
                }
            }
            Instr::Gemm { reset, .. } => {
                let r = gemm_ranges(prog, ins, idx, windows)?;
                if !reset && r.inp.1 > cfg.inp_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM reads INP past scratchpad \
                         ({} > {})",
                        r.inp.1,
                        cfg.inp_capacity()
                    )));
                }
                if !reset && r.wgt.1 > cfg.wgt_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM reads WGT past scratchpad \
                         ({} > {})",
                        r.wgt.1,
                        cfg.wgt_capacity()
                    )));
                }
                if r.ubuf.1 > cfg.uop_capacity() {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: GEMM uop range past uop buffer"
                    )));
                }
                if r.acc.1 > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!(
                            "instr {idx}: GEMM ACC index wraps ({} > {})",
                            r.acc.1,
                            cfg.acc_capacity()
                        ),
                    );
                }
            }
            Instr::Alu { acc_base, count, .. } => {
                if acc_base + count > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!("instr {idx}: ALU ACC range wraps"),
                    );
                }
            }
            Instr::Store { dma, .. } => {
                if dma.dram_end() > prog.dram_out_vecs {
                    return Err(Fault::RegisterError(format!(
                        "instr {idx}: store DMA writes past DRAM \
                         ({} > {})",
                        dma.dram_end(),
                        prog.dram_out_vecs
                    )));
                }
                if dma.sram_end() > cfg.acc_capacity() {
                    hold_corruption(
                        &mut corruption,
                        format!("instr {idx}: store reads wrapped ACC"),
                    );
                }
            }
            Instr::Finish => {}
        }
    }
    match corruption {
        Some(f) => Err(f),
        None => Ok(()),
    }
}

fn hold_corruption(slot: &mut Option<Fault>, msg: String) {
    if slot.is_none() {
        *slot = Some(Fault::Corruption(msg));
    }
}

fn capacity(cfg: &VtaConfig, buf: Buffer) -> usize {
    match buf {
        Buffer::Inp => cfg.inp_capacity(),
        Buffer::Wgt => cfg.wgt_capacity(),
        Buffer::Acc => cfg.acc_capacity(),
    }
}

// ----------------------------------------------------------------- ranges

/// Address spaces for hazard tracking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Space {
    Inp,
    Wgt,
    Acc,
    Ubuf,
}

/// One access: half-open element range with a write flag.
#[derive(Clone, Copy, Debug)]
struct Access {
    space: Space,
    lo: usize,
    hi: usize,
    write: bool,
}

struct GemmRanges {
    acc: (usize, usize),
    inp: (usize, usize),
    wgt: (usize, usize),
    ubuf: (usize, usize),
}

/// Uop-buffer windows established by LoadUop instructions, in program
/// order: `(instr_idx, sram_base, uop_begin, uop_end)`. Precomputed once so
/// range analysis is O(instrs × windows) instead of quadratic.
type UopWindows = Vec<(usize, usize, usize, usize)>;

fn uop_windows(prog: &Program) -> UopWindows {
    let mut w = UopWindows::new();
    uop_windows_into(prog, &mut w);
    w
}

/// [`uop_windows`] into a reused buffer (cleared first).
fn uop_windows_into(prog: &Program, out: &mut UopWindows) {
    out.clear();
    for (i, ins) in prog.instrs.iter().enumerate() {
        if let Instr::LoadUop { sram_base, uop_begin, uop_end, .. } = ins {
            out.push((i, *sram_base, *uop_begin, *uop_end));
        }
    }
}

/// Bounding element ranges a GEMM instruction touches (exact for the dense
/// loops our compiler emits).
fn gemm_ranges(
    prog: &Program,
    ins: &Instr,
    idx: usize,
    windows: &UopWindows,
) -> Result<GemmRanges, Fault> {
    let Instr::Gemm {
        ubuf_begin, ubuf_end, lp0, lp1, acc_base, inp_base, wgt_base, ..
    } = ins
    else {
        unreachable!()
    };
    // The uop-buffer contents are whatever the last covering LoadUop put
    // there (our compiler emits one LoadUop up front).
    let table = windows
        .iter()
        .rev()
        .filter(|(i, ..)| *i < idx)
        .find(|(_, sram, b, e)| {
            *sram <= *ubuf_begin && *ubuf_end <= sram + (e - b)
        })
        .map(|(_, sram, b, e)| (*sram, *b, *e));
    let Some((sram, tb, _te)) = table else {
        return Err(Fault::RegisterError(format!(
            "instr {idx}: GEMM reads uop buffer range \
             [{ubuf_begin},{ubuf_end}) never loaded"
        )));
    };
    let uops = &prog.uops[tb + (ubuf_begin - sram)..tb + (ubuf_end - sram)];
    if uops.is_empty() || lp0.extent == 0 || lp1.extent == 0 {
        return Ok(GemmRanges {
            acc: (*acc_base, *acc_base),
            inp: (*inp_base, *inp_base),
            wgt: (*wgt_base, *wgt_base),
            ubuf: (*ubuf_begin, *ubuf_end),
        });
    }
    let span0 = |off: usize| (lp0.extent - 1) * off;
    let span1 = |off: usize| (lp1.extent - 1) * off;
    // single pass over the (small) uop window for all six extrema
    let mut mins = [usize::MAX; 3];
    let mut maxs = [0usize; 3];
    for u in uops {
        for (k, v) in [u.acc, u.inp, u.wgt].into_iter().enumerate() {
            mins[k] = mins[k].min(v);
            maxs[k] = maxs[k].max(v);
        }
    }
    Ok(GemmRanges {
        acc: (
            acc_base + mins[0],
            acc_base + maxs[0] + span0(lp0.acc_off) + span1(lp1.acc_off)
                + 1,
        ),
        inp: (
            inp_base + mins[1],
            inp_base + maxs[1] + span0(lp0.inp_off) + span1(lp1.inp_off)
                + 1,
        ),
        wgt: (
            wgt_base + mins[2],
            wgt_base + maxs[2] + span0(lp0.wgt_off) + span1(lp1.wgt_off)
                + 1,
        ),
        ubuf: (*ubuf_begin, *ubuf_end),
    })
}

/// Fixed-capacity access set — an instruction touches at most 4 ranges.
/// Inline storage keeps the hazard pass allocation-free (EXPERIMENTS.md
/// §Perf: ~25% of check() time was Vec allocation here).
#[derive(Clone, Copy, Debug)]
struct AccessVec {
    len: u8,
    items: [Access; 4],
}

const NO_ACCESS: Access =
    Access { space: Space::Acc, lo: 0, hi: 0, write: false };

impl AccessVec {
    fn new() -> Self {
        AccessVec { len: 0, items: [NO_ACCESS; 4] }
    }

    fn clear(&mut self) {
        self.len = 0;
    }

    fn push(&mut self, a: Access) {
        self.items[self.len as usize] = a;
        self.len += 1;
    }

    fn as_slice(&self) -> &[Access] {
        &self.items[..self.len as usize]
    }
}

/// Collect the SRAM ranges instruction `idx` touches straight into the
/// caller's fixed-capacity buffer — no per-instruction `vec!`. An
/// instruction touches at most 4 ranges, so no spill path exists.
fn accesses_into(
    prog: &Program,
    idx: usize,
    windows: &UopWindows,
    out: &mut AccessVec,
) {
    out.clear();
    match &prog.instrs[idx] {
        Instr::Load { buf, dma, .. } => out.push(Access {
            space: space_of(*buf),
            lo: dma.sram_base,
            hi: dma.sram_end(),
            write: true,
        }),
        Instr::Memset { buf, sram_base, count, .. } => out.push(Access {
            space: space_of(*buf),
            lo: *sram_base,
            hi: sram_base + count,
            write: true,
        }),
        Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => {
            out.push(Access {
                space: Space::Ubuf,
                lo: *sram_base,
                hi: sram_base + (uop_end - uop_begin),
                write: true,
            })
        }
        ins @ Instr::Gemm { reset, .. } => {
            match gemm_ranges(prog, ins, idx, windows) {
                Ok(r) => {
                    out.push(Access {
                        space: Space::Acc,
                        lo: r.acc.0,
                        hi: r.acc.1,
                        write: true,
                    });
                    // reset-mode GEMM only zero-fills ACC: no INP/WGT
                    // reads.
                    if !*reset {
                        out.push(Access {
                            space: Space::Inp,
                            lo: r.inp.0,
                            hi: r.inp.1,
                            write: false,
                        });
                        out.push(Access {
                            space: Space::Wgt,
                            lo: r.wgt.0,
                            hi: r.wgt.1,
                            write: false,
                        });
                    }
                    out.push(Access {
                        space: Space::Ubuf,
                        lo: r.ubuf.0,
                        hi: r.ubuf.1,
                        write: false,
                    });
                }
                Err(_) => {} // bounds pass reports this as a crash
            }
        }
        Instr::Alu { acc_base, count, .. } => out.push(Access {
            space: Space::Acc,
            lo: *acc_base,
            hi: acc_base + count,
            write: true,
        }),
        Instr::Store { dma, .. } => out.push(Access {
            space: Space::Acc,
            lo: dma.sram_base,
            hi: dma.sram_end(),
            write: false,
        }),
        Instr::Finish => {}
    }
}

fn space_of(buf: Buffer) -> Space {
    match buf {
        Buffer::Inp => Space::Inp,
        Buffer::Wgt => Space::Wgt,
        Buffer::Acc => Space::Acc,
    }
}

// ----------------------------------------------------------------- hazard

/// One SRAM access range flattened for the interval sweep: the owning
/// instruction rides along so overlapping entries map back to a pair.
#[derive(Clone, Copy, Debug)]
struct SpanEntry {
    lo: usize,
    hi: usize,
    idx: u32,
    write: bool,
}

/// Reusable hazard/bounds-check arena: the uop-window table, the
/// per-instruction access cache, the execution-position map, and the
/// four per-space interval lists all keep their backing storage across
/// [`check_program`] / [`check_hazards_with`] calls. One scratch
/// belongs to one worker thread (`&mut` API, never shared).
#[derive(Debug, Default)]
pub struct HazardScratch {
    windows: UopWindows,
    acc: Vec<AccessVec>,
    pos: Vec<u32>,
    spans: [Vec<SpanEntry>; 4],
}

impl HazardScratch {
    /// Fresh (cold) scratch; buffers grow on first use and are then
    /// reused forever.
    pub fn new() -> HazardScratch {
        HazardScratch::default()
    }
}

/// Pipelined-execution hazard pass. `schedule.order` is the serialized
/// execution order (by start time) from the timing model; any conflicting
/// pair that executes out of *program* order corrupts data.
///
/// Thin allocating wrapper over [`check_hazards_with`] — pinned
/// bit-identical against a frozen copy of the pre-sweep pending-list
/// implementation by `tests/sim_scratch.rs`.
pub fn check_hazards(
    _cfg: &VtaConfig,
    prog: &Program,
    schedule: &Schedule,
) -> Result<(), Fault> {
    let mut scratch = HazardScratch::new();
    uop_windows_into(prog, &mut scratch.windows);
    check_hazards_with(prog, &schedule.order, &mut scratch)
}

/// Bounds pass + hazard pass back to back, sharing one scratch and one
/// uop-window table — the full-fidelity verdict core that
/// [`crate::vta::Simulator::check_with`] runs after the timing
/// simulation. Fault precedence matches running [`check_addresses`]
/// then [`check_hazards`] (the bounds fault wins).
pub fn check_program(
    cfg: &VtaConfig,
    prog: &Program,
    order: &[(u64, usize)],
    scratch: &mut HazardScratch,
) -> Result<(), Fault> {
    uop_windows_into(prog, &mut scratch.windows);
    check_addresses_inner(cfg, prog, &scratch.windows)?;
    check_hazards_with(prog, order, scratch)
}

/// The hazard pass proper, on a caller-maintained scratch whose
/// `windows` table is already filled for `prog`. Allocation-free once
/// the scratch buffers have grown to the largest program seen.
///
/// Instead of the pending-list scan (for each executing instruction,
/// walk every not-yet-executed program-earlier instruction), this
/// flattens every access range into a per-space list sorted by range
/// start and enumerates overlapping pairs with a forward sweep. A pair
/// `(j, k)` with `j < k` in program order is a hazard iff it conflicts
/// (same space, ≥1 write, ranges overlap) and executes inverted
/// (`pos[k] < pos[j]`). The legacy scan reports the fault minimizing
/// `(pos[k], j)` — first by execution time of the jumper, ties by
/// earliest clobbered instruction — so the sweep minimizes the same
/// key over all inverted conflicting pairs, making the two
/// implementations answer-identical by construction.
fn check_hazards_with(
    prog: &Program,
    order: &[(u64, usize)],
    scratch: &mut HazardScratch,
) -> Result<(), Fault> {
    let n = prog.instrs.len();
    let HazardScratch { windows, acc, pos, spans } = scratch;
    acc.clear();
    acc.resize(n, AccessVec::new());
    for (i, slot) in acc.iter_mut().enumerate() {
        accesses_into(prog, i, windows, slot);
    }
    // execution position of each instruction; an instruction missing
    // from `order` never executes and sorts after everything.
    pos.clear();
    pos.resize(n, u32::MAX);
    for (p, &(_, k)) in order.iter().enumerate() {
        pos[k] = p as u32;
    }
    for s in spans.iter_mut() {
        s.clear();
    }
    for (i, av) in acc.iter().enumerate() {
        for a in av.as_slice() {
            if a.lo < a.hi {
                spans[a.space as usize].push(SpanEntry {
                    lo: a.lo,
                    hi: a.hi,
                    idx: i as u32,
                    write: a.write,
                });
            }
        }
    }
    // (pos[k], j, k) of the best (= legacy-first) hazard found so far
    let mut best: Option<(u32, u32, u32)> = None;
    for list in spans.iter_mut() {
        list.sort_unstable_by_key(|e| e.lo);
        for i in 0..list.len() {
            let a = list[i];
            for b in &list[i + 1..] {
                if b.lo >= a.hi {
                    break; // sorted by lo: nothing further overlaps a
                }
                // overlap is established (b.hi > b.lo >= a.lo); filter
                // to real conflicts executing out of program order
                if a.idx == b.idx || !(a.write || b.write) {
                    continue;
                }
                let (j, k) = (a.idx.min(b.idx), a.idx.max(b.idx));
                if pos[k as usize] >= pos[j as usize] {
                    continue; // program order preserved
                }
                let key = (pos[k as usize], j, k);
                if best.map_or(true, |cur| key < cur) {
                    best = Some(key);
                }
            }
        }
    }
    match best {
        Some((_, j, k)) => Err(Fault::Corruption(format!(
            "instr {k} executes before conflicting instr {j} \
             (cross-thread/double-buffer scratchpad aliasing)"
        ))),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------- numeric

/// Scratchpad state for numeric execution.
struct Chip {
    inp: Vec<i8>,
    wgt: Vec<i8>,
    acc: Vec<i32>,
    ubuf: Vec<Uop>,
    blk: usize,
}

/// Full numeric execution in serialized (pipelined) order. Returns the
/// output DRAM int8 image; crashes abort with the fault. Silent corruption
/// is *not* reported here — it manifests as wrong output bits, exactly as on
/// hardware; compare against the golden model to detect it.
pub fn execute(
    cfg: &VtaConfig,
    prog: &Program,
    dram: &Dram,
) -> Result<Vec<i8>, Fault> {
    let schedule = super::timing::simulate_schedule(cfg, prog)?;
    execute_in_order(cfg, prog, dram, schedule.order.iter().map(|&(_, i)| i))
}

/// Numeric execution in program order (no pipelining) — reference semantics
/// used by unit tests.
pub fn execute_program_order(
    cfg: &VtaConfig,
    prog: &Program,
    dram: &Dram,
) -> Result<Vec<i8>, Fault> {
    execute_in_order(cfg, prog, dram, 0..prog.instrs.len())
}

fn execute_in_order(
    cfg: &VtaConfig,
    prog: &Program,
    dram: &Dram,
    order: impl Iterator<Item = usize>,
) -> Result<Vec<i8>, Fault> {
    let blk = cfg.block();
    assert_eq!(dram.inp.len(), prog.dram_inp_vecs * blk, "input DRAM size");
    assert_eq!(
        dram.wgt.len(),
        prog.dram_wgt_blocks * blk * blk,
        "weight DRAM size"
    );
    let mut chip = Chip {
        inp: vec![0; cfg.inp_capacity() * blk],
        wgt: vec![0; cfg.wgt_capacity() * blk * blk],
        acc: vec![0; cfg.acc_capacity() * blk],
        ubuf: vec![Uop { acc: 0, inp: 0, wgt: 0 }; cfg.uop_capacity()],
        blk,
    };
    let mut out = vec![0i8; prog.dram_out_vecs * blk];
    for idx in order {
        step(cfg, prog, dram, &mut chip, &mut out, idx)?;
    }
    Ok(out)
}

fn step(
    cfg: &VtaConfig,
    prog: &Program,
    dram: &Dram,
    chip: &mut Chip,
    out: &mut [i8],
    idx: usize,
) -> Result<(), Fault> {
    let blk = chip.blk;
    match &prog.instrs[idx] {
        Instr::Load { buf, dma, .. } => {
            let (cap, esz) = (capacity(cfg, *buf), buf_bytes(cfg, *buf));
            let dram_src: &[i8] = match buf {
                Buffer::Inp | Buffer::Acc => &dram.inp,
                Buffer::Wgt => &dram.wgt,
            };
            if dma.dram_end() * esz > dram_src.len() {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: load DMA past DRAM"
                )));
            }
            if dma.sram_end() > cap && !matches!(buf, Buffer::Acc) {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: {buf:?} load overflows scratchpad"
                )));
            }
            for r in 0..dma.rows {
                for c in 0..dma.cols {
                    let s = (dma.sram_base + r * dma.cols + c) % cap;
                    let d = dma.dram_base + r * dma.dram_stride + c;
                    match buf {
                        Buffer::Inp => chip.inp[s * esz..(s + 1) * esz]
                            .copy_from_slice(&dram_src[d * esz..(d + 1) * esz]),
                        Buffer::Wgt => chip.wgt[s * esz..(s + 1) * esz]
                            .copy_from_slice(&dram_src[d * esz..(d + 1) * esz]),
                        Buffer::Acc => {
                            // bias-style load: int8 dram widened into acc
                            for l in 0..blk {
                                chip.acc[s * blk + l] =
                                    dram_src[d * esz + l] as i32;
                            }
                        }
                    }
                }
            }
        }
        Instr::Memset { buf, sram_base, count, .. } => {
            let cap = capacity(cfg, *buf);
            if sram_base + count > cap && !matches!(buf, Buffer::Acc) {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: {buf:?} memset overflows scratchpad"
                )));
            }
            for i in 0..*count {
                let s = (sram_base + i) % cap;
                match buf {
                    Buffer::Inp => {
                        chip.inp[s * blk..(s + 1) * blk].fill(0)
                    }
                    Buffer::Wgt => chip.wgt
                        [s * blk * blk..(s + 1) * blk * blk]
                        .fill(0),
                    Buffer::Acc => {
                        chip.acc[s * blk..(s + 1) * blk].fill(0)
                    }
                }
            }
        }
        Instr::LoadUop { sram_base, uop_begin, uop_end, .. } => {
            if *uop_end > prog.uops.len()
                || sram_base + (uop_end - uop_begin) > cfg.uop_capacity()
            {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: uop load out of bounds"
                )));
            }
            chip.ubuf[*sram_base..sram_base + (uop_end - uop_begin)]
                .copy_from_slice(&prog.uops[*uop_begin..*uop_end]);
        }
        Instr::Gemm {
            ubuf_begin, ubuf_end, lp0, lp1,
            acc_base, inp_base, wgt_base, reset, ..
        } => {
            if *ubuf_end > cfg.uop_capacity() {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: GEMM uop range past uop buffer"
                )));
            }
            let acc_cap = cfg.acc_capacity();
            for i0 in 0..lp0.extent {
                for i1 in 0..lp1.extent {
                    for u in *ubuf_begin..*ubuf_end {
                        let uop = chip.ubuf[u];
                        let ai = (acc_base + uop.acc
                            + i0 * lp0.acc_off + i1 * lp1.acc_off)
                            % acc_cap; // ACC wraps silently
                        if *reset {
                            // real-VTA reset pass: zero ACC, no MAC
                            chip.acc[ai * blk..(ai + 1) * blk].fill(0);
                            continue;
                        }
                        let ii = inp_base + uop.inp
                            + i0 * lp0.inp_off + i1 * lp1.inp_off;
                        let wi = wgt_base + uop.wgt
                            + i0 * lp0.wgt_off + i1 * lp1.wgt_off;
                        if ii >= cfg.inp_capacity() {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: GEMM INP index {ii} OOB"
                            )));
                        }
                        if wi >= cfg.wgt_capacity() {
                            return Err(Fault::RegisterError(format!(
                                "instr {idx}: GEMM WGT index {wi} OOB"
                            )));
                        }
                        let x = &chip.inp[ii * blk..(ii + 1) * blk];
                        let w = &chip.wgt[wi * blk * blk..(wi + 1) * blk * blk];
                        let a = &mut chip.acc[ai * blk..(ai + 1) * blk];
                        gemm_block(x, w, a, blk);
                    }
                }
            }
        }
        Instr::Alu { op, acc_base, count, .. } => {
            let acc_cap = cfg.acc_capacity();
            for i in 0..*count {
                let s = (acc_base + i) % acc_cap;
                let v = &mut chip.acc[s * blk..(s + 1) * blk];
                match op {
                    AluOp::ShiftClip { shift } => {
                        for x in v.iter_mut() {
                            *x = (*x >> shift).clamp(-128, 127);
                        }
                    }
                    AluOp::Relu => {
                        for x in v.iter_mut() {
                            *x = (*x).max(0);
                        }
                    }
                    AluOp::AddImm { imm } => {
                        for x in v.iter_mut() {
                            *x = x.wrapping_add(*imm);
                        }
                    }
                }
            }
        }
        Instr::Store { dma, .. } => {
            if dma.dram_end() > prog.dram_out_vecs {
                return Err(Fault::RegisterError(format!(
                    "instr {idx}: store past output DRAM"
                )));
            }
            let acc_cap = cfg.acc_capacity();
            for r in 0..dma.rows {
                for c in 0..dma.cols {
                    let s = (dma.sram_base + r * dma.cols + c) % acc_cap;
                    let d = dma.dram_base + r * dma.dram_stride + c;
                    for l in 0..blk {
                        // store path truncates to 8 bits (ALU is expected
                        // to have clipped already)
                        out[d * blk + l] = chip.acc[s * blk + l] as i8;
                    }
                }
            }
        }
        Instr::Finish => {}
    }
    Ok(())
}

/// `acc[0..blk] += x[0..blk] · w[blk×blk]` — w is `[n_lane][k_lane]`.
/// The inner 16×16×16 MAC mirrors one MXU / VTA GEMM intrinsic issue.
#[inline]
fn gemm_block(x: &[i8], w: &[i8], acc: &mut [i32], blk: usize) {
    for n in 0..blk {
        let mut sum = 0i32;
        let wrow = &w[n * blk..(n + 1) * blk];
        for k in 0..blk {
            sum += x[k] as i32 * wrow[k] as i32;
        }
        acc[n] = acc[n].wrapping_add(sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::isa::{Dep, Dma, GemmLoop};

    fn cfg() -> VtaConfig {
        VtaConfig::zcu102()
    }

    /// Tiny hand-built program: load 1 input vector + 1 weight block,
    /// GEMM into acc[0], shift-clip, store.
    fn tiny_program() -> (Program, Dram) {
        let blk = 16usize;
        let mut prog = Program {
            dram_inp_vecs: 1,
            dram_wgt_blocks: 1,
            dram_out_vecs: 1,
            ..Default::default()
        };
        prog.uops.push(Uop { acc: 0, inp: 0, wgt: 0 });
        let d1 = Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 1,
                       dram_stride: 1 };
        prog.instrs = vec![
            Instr::LoadUop { sram_base: 0, uop_begin: 0, uop_end: 1,
                             dep: Dep::NONE },
            Instr::Load { buf: Buffer::Inp, dma: d1, dep: Dep::NONE },
            Instr::Load { buf: Buffer::Wgt, dma: d1,
                          dep: Dep::push_next() },
            Instr::Gemm {
                ubuf_begin: 0, ubuf_end: 1,
                lp0: GemmLoop { extent: 1, ..Default::default() },
                lp1: GemmLoop { extent: 1, ..Default::default() },
                acc_base: 0, inp_base: 0, wgt_base: 0, reset: false,
                dep: Dep::pop_prev(),
            },
            Instr::Alu { op: AluOp::ShiftClip { shift: 0 }, acc_base: 0,
                         count: 1, dep: Dep::push_next() },
            Instr::Store { dma: d1, dep: Dep::pop_prev() },
            Instr::Finish,
        ];
        let mut inp = vec![0i8; blk];
        inp[0] = 2;
        inp[1] = 3;
        let mut wgt = vec![0i8; blk * blk];
        // w[n=0][k=0] = 5, w[n=1][k=1] = -4
        wgt[0] = 5;
        wgt[blk + 1] = -4;
        (prog, Dram { inp, wgt, out_vecs: 1 })
    }

    #[test]
    fn tiny_gemm_numeric() {
        let (prog, dram) = tiny_program();
        let out = execute_program_order(&cfg(), &prog, &dram).unwrap();
        assert_eq!(out[0], 10); // 2*5
        assert_eq!(out[1], -12); // 3*-4
        assert!(out[2..16].iter().all(|&v| v == 0));
    }

    #[test]
    fn pipelined_matches_program_order_when_hazard_free() {
        let (prog, dram) = tiny_program();
        let a = execute_program_order(&cfg(), &prog, &dram).unwrap();
        let b = execute(&cfg(), &prog, &dram).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn address_check_ok_for_tiny() {
        let (prog, _) = tiny_program();
        assert!(check_addresses(&cfg(), &prog).is_ok());
    }

    #[test]
    fn inp_overflow_is_register_error() {
        let (mut prog, _) = tiny_program();
        let cap = cfg().inp_capacity();
        prog.instrs[1] = Instr::Load {
            buf: Buffer::Inp,
            dma: Dma { sram_base: cap - 1, dram_base: 0, rows: 1, cols: 2,
                       dram_stride: 2 },
            dep: Dep::NONE,
        };
        prog.dram_inp_vecs = 2;
        match check_addresses(&cfg(), &prog) {
            Err(Fault::RegisterError(_)) => {}
            other => panic!("expected RegisterError, got {other:?}"),
        }
    }

    #[test]
    fn acc_overflow_is_corruption() {
        let (mut prog, _) = tiny_program();
        let cap = cfg().acc_capacity();
        if let Instr::Gemm { acc_base, .. } = &mut prog.instrs[3] {
            *acc_base = cap; // wraps to 0
        }
        match check_addresses(&cfg(), &prog) {
            Err(Fault::Corruption(_)) => {}
            other => panic!("expected Corruption, got {other:?}"),
        }
    }

    #[test]
    fn acc_wrap_actually_aliases_in_numeric_mode() {
        let (mut prog, dram) = tiny_program();
        let cap = cfg().acc_capacity();
        if let Instr::Gemm { acc_base, .. } = &mut prog.instrs[3] {
            *acc_base = cap; // acc index cap → wraps to 0
        }
        // ALU + store still read acc[0]: result identical because the wrap
        // aliases exactly slot 0 — numeric mode executes, no crash.
        let out = execute_program_order(&cfg(), &prog, &dram).unwrap();
        assert_eq!(out[0], 10);
    }

    #[test]
    fn gemm_without_loaduop_is_register_error() {
        let (mut prog, _) = tiny_program();
        prog.instrs.remove(0);
        match check_addresses(&cfg(), &prog) {
            Err(Fault::RegisterError(m)) => {
                assert!(m.contains("never loaded"), "{m}")
            }
            other => panic!("expected RegisterError, got {other:?}"),
        }
    }

    #[test]
    fn dram_oob_load_is_register_error() {
        let (mut prog, _) = tiny_program();
        if let Instr::Load { dma, .. } = &mut prog.instrs[1] {
            dma.dram_base = 5;
        }
        match check_addresses(&cfg(), &prog) {
            Err(Fault::RegisterError(_)) => {}
            other => panic!("expected RegisterError, got {other:?}"),
        }
    }

    #[test]
    fn saturation_in_alu() {
        let blk = 16usize;
        let (mut prog, mut dram) = tiny_program();
        // large products: 127 * 127 * 1 = 16129 → shift 0 → clip to 127
        dram.inp = vec![127i8; blk];
        dram.wgt = vec![127i8; blk * blk];
        if let Instr::Alu { op, .. } = &mut prog.instrs[4] {
            *op = AluOp::ShiftClip { shift: 0 };
        }
        let out = execute_program_order(&cfg(), &prog, &dram).unwrap();
        assert!(out.iter().all(|&v| v == 127));
    }

    #[test]
    fn gemm_loops_apply_offsets() {
        // 2 input vectors, 1 weight block; loop0 over 2 pixels writing
        // acc 0 and 1.
        let blk = 16usize;
        let mut prog = Program {
            dram_inp_vecs: 2,
            dram_wgt_blocks: 1,
            dram_out_vecs: 2,
            ..Default::default()
        };
        prog.uops.push(Uop { acc: 0, inp: 0, wgt: 0 });
        prog.instrs = vec![
            Instr::LoadUop { sram_base: 0, uop_begin: 0, uop_end: 1,
                             dep: Dep::NONE },
            Instr::Load {
                buf: Buffer::Inp,
                dma: Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 2,
                           dram_stride: 2 },
                dep: Dep::NONE,
            },
            Instr::Load {
                buf: Buffer::Wgt,
                dma: Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 1,
                           dram_stride: 1 },
                dep: Dep::push_next(),
            },
            Instr::Gemm {
                ubuf_begin: 0, ubuf_end: 1,
                lp0: GemmLoop { extent: 2, acc_off: 1, inp_off: 1,
                                wgt_off: 0 },
                lp1: GemmLoop { extent: 1, ..Default::default() },
                acc_base: 0, inp_base: 0, wgt_base: 0, reset: false,
                dep: Dep::pop_prev(),
            },
            Instr::Alu { op: AluOp::ShiftClip { shift: 0 }, acc_base: 0,
                         count: 2, dep: Dep::push_next() },
            Instr::Store {
                dma: Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 2,
                           dram_stride: 2 },
                dep: Dep::pop_prev(),
            },
            Instr::Finish,
        ];
        let mut inp = vec![0i8; 2 * blk];
        inp[0] = 1; // vector 0
        inp[blk] = 2; // vector 1
        let mut wgt = vec![0i8; blk * blk];
        wgt[0] = 7; // w[n=0][k=0]
        let dram = Dram { inp, wgt, out_vecs: 2 };
        let out = execute_program_order(&cfg(), &prog, &dram).unwrap();
        assert_eq!(out[0], 7); // pixel 0: 1*7
        assert_eq!(out[blk], 14); // pixel 1: 2*7
    }
}
