//! Cycle-approximate timing model.
//!
//! VTA runs three concurrent modules — LOAD, COMPUTE, STORE — decoupled by
//! dependency-token FIFOs (`l2g`/`g2l` between load and compute, `g2s`/`s2g`
//! between compute and store). The backend compiler encodes double buffering
//! and virtual threads purely through the pop/push flags on instructions;
//! the timing model is a conservative co-simulation of the three timelines:
//!
//! * each module executes its own instructions in order;
//! * an instruction starts at `max(module_free, required_token_push_times)`;
//! * its duration comes from the DMA / GEMM / ALU cost model
//!   ([`instr_cycles`]);
//! * tokens it pushes become visible at its end time.
//!
//! The result is both the cycle count (the tuner's performance metric) and
//! the serialized execution order (start-time order) that
//! [`crate::vta::functional`] uses for numeric execution and hazard
//! detection — one source of truth for "what the pipeline actually did".

use super::config::VtaConfig;
use super::isa::{buf_bytes, Instr, Module, Program};
use super::Fault;

/// Result of a timing run: total cycles + serialized execution order
/// (ascending `(start_cycle, program_index)`).
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Total pipeline cycles from first issue to drain.
    pub cycles: u64,
    /// Execution order as ascending `(start_cycle, program_index)`.
    pub order: Vec<(u64, usize)>,
    /// Per-module busy cycles (utilization reporting).
    pub busy: [u64; 3],
}

/// Duration of one instruction in cycles. Purely local to the
/// instruction: the cost model never consults the rest of the program.
pub fn instr_cycles(cfg: &VtaConfig, ins: &Instr) -> u64 {
    match ins {
        Instr::Load { buf, dma, .. } => {
            let bytes = (dma.elems() * buf_bytes(cfg, *buf)) as u64;
            cfg.dma_latency
                + bytes.div_ceil(cfg.dma_bytes_per_cycle)
                + dma.rows as u64 * cfg.dma_row_overhead
        }
        Instr::Memset { count, .. } => {
            8 + *count as u64 * cfg.memset_cycles_per_vec
        }
        Instr::LoadUop { uop_begin, uop_end, .. } => {
            let bytes = ((uop_end - uop_begin) * cfg.uop_bytes()) as u64;
            cfg.dma_latency + bytes.div_ceil(cfg.dma_bytes_per_cycle)
        }
        Instr::Gemm { ubuf_begin, ubuf_end, lp0, lp1, .. } => {
            // MXU issues one block-op per cycle once streaming.
            let ops = (ubuf_end - ubuf_begin) as u64
                * lp0.extent.max(1) as u64
                * lp1.extent.max(1) as u64;
            cfg.gemm_overhead + ops
        }
        Instr::Alu { count, .. } => {
            cfg.alu_overhead + *count as u64 * cfg.alu_cycles_per_vec
        }
        Instr::Store { dma, .. } => {
            // store path writes int8 lanes: block bytes per vector
            let bytes = (dma.elems() * cfg.block()) as u64;
            cfg.dma_latency
                + bytes.div_ceil(cfg.dma_bytes_per_cycle)
                + dma.rows as u64 * cfg.dma_row_overhead
        }
        Instr::Finish => cfg.finish_cycles,
    }
}

/// The four token FIFOs, as (queue of push-times).
#[derive(Default)]
struct Queues {
    l2g: std::collections::VecDeque<u64>, // load → compute (data ready)
    g2l: std::collections::VecDeque<u64>, // compute → load (buffer free)
    g2s: std::collections::VecDeque<u64>, // compute → store (data ready)
    s2g: std::collections::VecDeque<u64>, // store → compute (buffer free)
}

impl Queues {
    fn clear(&mut self) {
        self.l2g.clear();
        self.g2l.clear();
        self.g2s.clear();
        self.s2g.clear();
    }
}

/// Reusable timing-simulation arena: the per-module instruction
/// streams, the four token queues, and the result (order/cycles/busy)
/// all keep their backing storage across [`simulate_into`] calls, so a
/// warmed scratch runs the co-simulation with zero heap allocations
/// per trial. One scratch belongs to one worker thread — it is `Send`
/// but deliberately not shared (`&mut` API).
#[derive(Debug, Default)]
pub struct TimingScratch {
    streams: [Vec<usize>; 3],
    q: Queues,
    order: Vec<(u64, usize)>,
    cycles: u64,
    busy: [u64; 3],
}

impl std::fmt::Debug for Queues {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Queues")
            .field("l2g", &self.l2g.len())
            .field("g2l", &self.g2l.len())
            .field("g2s", &self.g2s.len())
            .field("s2g", &self.s2g.len())
            .finish()
    }
}

impl TimingScratch {
    /// Fresh (cold) scratch; buffers grow on first use and are then
    /// reused forever.
    pub fn new() -> TimingScratch {
        TimingScratch::default()
    }

    /// Serialized execution order of the last successful
    /// [`simulate_into`] run (ascending `(start_cycle, program_index)`).
    pub fn order(&self) -> &[(u64, usize)] {
        &self.order
    }

    /// Total pipeline cycles of the last successful run.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-module busy cycles of the last successful run.
    pub fn busy(&self) -> [u64; 3] {
        self.busy
    }

    /// Copy the last run's results out as an owned [`Schedule`]
    /// (allocates; the profiling hot path reads the borrowing getters
    /// instead).
    pub fn to_schedule(&self) -> Schedule {
        Schedule {
            cycles: self.cycles,
            order: self.order.clone(),
            busy: self.busy,
        }
    }
}

/// Run the co-simulation; returns the schedule or a deadlock fault.
///
/// Thin allocating wrapper over [`simulate_into`] — bit-identical by
/// construction (it returns the scratch's result buffers), pinned by
/// `tests/sim_scratch.rs` against a frozen copy of the pre-scratch
/// implementation.
pub fn simulate_schedule(
    cfg: &VtaConfig,
    prog: &Program,
) -> Result<Schedule, Fault> {
    let mut scratch = TimingScratch::new();
    simulate_into(cfg, prog, &mut scratch)?;
    Ok(Schedule {
        cycles: scratch.cycles,
        order: scratch.order,
        busy: scratch.busy,
    })
}

/// Run the co-simulation into a reusable scratch arena. On `Ok`, the
/// schedule lives in the scratch ([`TimingScratch::order`] /
/// [`TimingScratch::cycles`] / [`TimingScratch::busy`]) until the next
/// call. Allocation-free once the scratch buffers have grown to the
/// largest program seen.
pub fn simulate_into(
    cfg: &VtaConfig,
    prog: &Program,
    scratch: &mut TimingScratch,
) -> Result<(), Fault> {
    // split instruction indices per module (order preserved)
    let streams = &mut scratch.streams;
    for s in streams.iter_mut() {
        s.clear();
    }
    for (i, ins) in prog.instrs.iter().enumerate() {
        streams[ins.module() as usize].push(i);
    }
    let mut ptr = [0usize; 3]; // next instruction per module
    let mut free = [0u64; 3]; // module-ready times
    let mut busy = [0u64; 3];
    let q = &mut scratch.q;
    q.clear();
    let order = &mut scratch.order;
    order.clear();
    order.reserve(prog.instrs.len());
    let mut done = 0usize;
    let total = prog.instrs.len();
    while done < total {
        let mut advanced = false;
        // pick, among runnable modules, the one that can start earliest
        let mut best: Option<(u64, usize)> = None; // (start, module)
        for m in 0..3 {
            if ptr[m] >= streams[m].len() {
                continue;
            }
            let idx = streams[m][ptr[m]];
            let dep = prog.instrs[idx].dep();
            // peek required tokens
            let mut start = free[m];
            let mut ok = true;
            let (prev_q, next_q): (
                Option<&std::collections::VecDeque<u64>>,
                Option<&std::collections::VecDeque<u64>>,
            ) = match module_of(m) {
                Module::Load => (None, Some(&q.g2l)),
                Module::Compute => (Some(&q.l2g), Some(&q.s2g)),
                Module::Store => (Some(&q.g2s), None),
            };
            if dep.pop_prev {
                match prev_q.and_then(|qq| qq.front()) {
                    Some(&t) => start = start.max(t),
                    None => ok = false,
                }
            }
            if dep.pop_next {
                match next_q.and_then(|qq| qq.front()) {
                    Some(&t) => start = start.max(t),
                    None => ok = false,
                }
            }
            let earliest = match best {
                None => true,
                Some((s, _)) => start < s,
            };
            if ok && earliest {
                best = Some((start, m));
            }
        }
        if let Some((start, m)) = best {
            let idx = streams[m][ptr[m]];
            let ins = &prog.instrs[idx];
            let dep = ins.dep();
            // consume tokens
            match module_of(m) {
                Module::Load => {
                    if dep.pop_next {
                        q.g2l.pop_front();
                    }
                }
                Module::Compute => {
                    if dep.pop_prev {
                        q.l2g.pop_front();
                    }
                    if dep.pop_next {
                        q.s2g.pop_front();
                    }
                }
                Module::Store => {
                    if dep.pop_prev {
                        q.g2s.pop_front();
                    }
                }
            }
            let dur = instr_cycles(cfg, ins);
            let end = start + dur;
            free[m] = end;
            busy[m] += dur;
            // publish tokens at end time
            match module_of(m) {
                Module::Load => {
                    if dep.push_next {
                        q.l2g.push_back(end);
                    }
                }
                Module::Compute => {
                    if dep.push_prev {
                        q.g2l.push_back(end);
                    }
                    if dep.push_next {
                        q.g2s.push_back(end);
                    }
                }
                Module::Store => {
                    if dep.push_prev {
                        q.s2g.push_back(end);
                    }
                }
            }
            order.push((start, idx));
            ptr[m] += 1;
            done += 1;
            advanced = true;
        }
        if !advanced {
            let stuck: Vec<String> = (0..3)
                .filter(|&m| ptr[m] < streams[m].len())
                .map(|m| format!("{:?}@{}", module_of(m), ptr[m]))
                .collect();
            return Err(Fault::Deadlock(format!(
                "dependency tokens never arrive: {}",
                stuck.join(", ")
            )));
        }
    }
    // serialized order = (start, program index); the index makes every
    // key distinct, so the unstable (in-place, allocation-free) sort is
    // deterministic and identical to a stable one
    order.sort_unstable();
    scratch.cycles = free.iter().copied().max().unwrap_or(0);
    scratch.busy = busy;
    Ok(())
}

/// Cycle count only.
pub fn simulate(cfg: &VtaConfig, prog: &Program) -> Result<u64, Fault> {
    simulate_schedule(cfg, prog).map(|s| s.cycles)
}

fn module_of(m: usize) -> Module {
    match m {
        0 => Module::Load,
        1 => Module::Compute,
        _ => Module::Store,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::isa::{Buffer, Dep, Dma, GemmLoop, Uop};

    fn cfg() -> VtaConfig {
        VtaConfig::zcu102()
    }

    fn dma1() -> Dma {
        Dma { sram_base: 0, dram_base: 0, rows: 1, cols: 1, dram_stride: 1 }
    }

    fn mini(dep_load: Dep, dep_gemm: Dep) -> Program {
        let mut p = Program {
            dram_inp_vecs: 4,
            dram_wgt_blocks: 4,
            dram_out_vecs: 4,
            ..Default::default()
        };
        p.uops.push(Uop { acc: 0, inp: 0, wgt: 0 });
        p.instrs = vec![
            Instr::LoadUop { sram_base: 0, uop_begin: 0, uop_end: 1,
                             dep: Dep::NONE },
            Instr::Load { buf: Buffer::Inp, dma: dma1(), dep: dep_load },
            Instr::Gemm {
                ubuf_begin: 0, ubuf_end: 1,
                lp0: GemmLoop { extent: 1, ..Default::default() },
                lp1: GemmLoop { extent: 1, ..Default::default() },
                acc_base: 0, inp_base: 0, wgt_base: 0, reset: false,
                dep: dep_gemm,
            },
            Instr::Finish,
        ];
        p
    }

    #[test]
    fn tokens_serialize_dependent_work() {
        // gemm pops the token the load pushes → gemm.start >= load.end
        let p = mini(Dep::push_next(), Dep::pop_prev());
        let s = simulate_schedule(&cfg(), &p).unwrap();
        let t = |idx: usize| {
            s.order.iter().find(|&&(_, i)| i == idx).unwrap().0
        };
        let load_end = t(1) + instr_cycles(&cfg(), &p.instrs[1]);
        assert!(t(2) >= load_end, "gemm must wait for load");
    }

    #[test]
    fn no_tokens_means_overlap() {
        // without deps, gemm can start while the load is still streaming
        let p = mini(Dep::NONE, Dep::NONE);
        let s = simulate_schedule(&cfg(), &p).unwrap();
        let t = |idx: usize| {
            s.order.iter().find(|&&(_, i)| i == idx).unwrap().0
        };
        let load_end = t(1) + instr_cycles(&cfg(), &p.instrs[1]);
        assert!(t(2) < load_end, "gemm should overlap the load");
    }

    #[test]
    fn missing_token_deadlocks() {
        // gemm pops a token nobody pushes
        let p = mini(Dep::NONE, Dep::pop_prev());
        match simulate_schedule(&cfg(), &p) {
            Err(Fault::Deadlock(_)) => {}
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn cycles_cover_all_modules() {
        let p = mini(Dep::push_next(), Dep::pop_prev());
        let s = simulate_schedule(&cfg(), &p).unwrap();
        assert_eq!(s.order.len(), p.instrs.len());
        assert!(s.cycles > 0);
        assert!(s.busy[0] > 0 && s.busy[1] > 0);
    }

    #[test]
    fn gemm_cost_scales_with_loops() {
        let c = cfg();
        let mk = |e0: usize, e1: usize| Instr::Gemm {
            ubuf_begin: 0, ubuf_end: 4,
            lp0: GemmLoop { extent: e0, ..Default::default() },
            lp1: GemmLoop { extent: e1, ..Default::default() },
            acc_base: 0, inp_base: 0, wgt_base: 0, reset: false,
            dep: Dep::NONE,
        };
        let small = instr_cycles(&c, &mk(1, 1));
        let big = instr_cycles(&c, &mk(8, 4));
        assert_eq!(big - c.gemm_overhead, (small - c.gemm_overhead) * 32);
    }

    #[test]
    fn dma_cost_scales_with_bytes_and_rows() {
        let c = cfg();
        let mk = |rows: usize, cols: usize| Instr::Load {
            buf: Buffer::Inp,
            dma: Dma { sram_base: 0, dram_base: 0, rows, cols,
                       dram_stride: cols },
            dep: Dep::NONE,
        };
        let one = instr_cycles(&c, &mk(1, 1));
        let wide = instr_cycles(&c, &mk(1, 64));
        let tall = instr_cycles(&c, &mk(64, 1));
        assert!(wide > one);
        assert!(tall > wide, "row overhead should make tall DMAs slower");
    }
}
